"""Layer-1 Pallas kernel: fused linear-model SGD gradient/step.

The paper's Section-5 workload is SGD on a 1000-parameter linear model; the
per-worker compute hot-spot is the fused gradient

    r = X w - y            (residual,   (n,))
    g = X^T r / n          (gradient,   (d,))

optionally followed by the parameter update ``w' = w - lr * g``. We fuse all
of it into a single Pallas kernel so one HBM pass over X produces the new
parameter vector — the same fusion a hand-written CUDA kernel would do, but
expressed as a TPU HBM<->VMEM schedule via ``BlockSpec``.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid over row-blocks of X; each step stages an ``(bn, d)`` tile of X into
    VMEM and issues two MXU matmuls (``x_blk @ w`` and ``x_blk^T @ r_blk``);
  * the gradient accumulator lives in the output VMEM block across grid
    steps (TPU grids execute sequentially, so read-modify-write of the same
    output block across steps is the canonical accumulation pattern);
  * the final grid step applies the SGD update, so ``w'`` never round-trips
    through HBM in a separate kernel.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which the Rust runtime
(xla crate) runs. Correctness vs ``ref.py`` is asserted by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block size: one (BLOCK_N, d) tile of X in VMEM per grid step. With
# d = 1000 (f32) a 128-row tile is 128*1000*4 B = 500 KiB — comfortably
# inside the ~16 MiB VMEM budget together with w, r and the accumulator.
BLOCK_N = 128


def _grad_kernel(x_ref, w_ref, y_ref, g_ref, *, nblocks: int, n_total: int):
    """Grid step i: accumulate x_blk^T (x_blk @ w - y_blk) into g_ref.

    g_ref maps to the same (d, 1) output block for every grid step; step 0
    initialises it, the last step scales by 1/n.
    """
    i = pl.program_id(0)
    x_blk = x_ref[...]                      # (BLOCK_N, d)   VMEM tile
    w = w_ref[...]                          # (d, 1)
    y_blk = y_ref[...]                      # (BLOCK_N, 1)
    # MXU matmul 1: residual of this row block.
    r_blk = jnp.dot(x_blk, w, preferred_element_type=jnp.float32) - y_blk
    # MXU matmul 2: partial gradient contribution.
    g_part = jnp.dot(x_blk.T, r_blk, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    g_ref[...] += g_part

    @pl.when(i == nblocks - 1)
    def _finalise():
        g_ref[...] = g_ref[...] / n_total


def _pad_rows(x: jax.Array, y: jax.Array, block_n: int):
    """Zero-pad rows to a multiple of block_n (zero rows contribute 0 to g)."""
    n = x.shape[0]
    rem = (-n) % block_n
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem, x.shape[1]), x.dtype)], axis=0)
        y = jnp.concatenate([y, jnp.zeros((rem,), y.dtype)], axis=0)
    return x, y


@functools.partial(jax.jit, static_argnames=("block_n",))
def linear_grad(
    x: jax.Array, w: jax.Array, y: jax.Array, *, block_n: int = BLOCK_N
) -> jax.Array:
    """Fused MSE gradient ``x^T (x w - y) / n`` as a Pallas kernel.

    Args:
      x: (n, d) f32 design matrix.
      w: (d,) f32 parameters.
      y: (n,) f32 targets.
      block_n: rows of X staged into VMEM per grid step.
    Returns:
      (d,) f32 gradient, numerically matching ``ref.linear_grad_ref``.
    """
    n, d = x.shape
    xp, yp = _pad_rows(x, y, block_n)
    nblocks = xp.shape[0] // block_n
    kernel = functools.partial(_grad_kernel, nblocks=nblocks, n_total=n)
    g = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # X row tile
            pl.BlockSpec((d, 1), lambda i: (0, 0)),          # w (resident)
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),    # y row tile
        ],
        out_specs=pl.BlockSpec((d, 1), lambda i: (0, 0)),    # g accumulator
        out_shape=jax.ShapeDtypeStruct((d, 1), jnp.float32),
        interpret=True,
    )(xp, w.reshape(d, 1), yp.reshape(-1, 1))
    return g.reshape(d)


def _step_kernel(
    x_ref, w_ref, y_ref, lr_ref, w_out_ref, loss_ref, g_ref,
    *, nblocks: int, n_total: int,
):
    """Fused grad + loss + SGD update.

    The gradient accumulates in the ``g_ref`` output block (resident in VMEM
    across sequential grid steps); the final step applies the update into
    ``w_out_ref`` so X is read from HBM exactly once per step.
    """
    i = pl.program_id(0)
    x_blk = x_ref[...]
    w = w_ref[...]
    y_blk = y_ref[...]
    r_blk = jnp.dot(x_blk, w, preferred_element_type=jnp.float32) - y_blk
    g_part = jnp.dot(x_blk.T, r_blk, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    g_ref[...] += g_part / n_total
    # 0.5 * sum(r^2) / n accumulated blockwise (padded rows contribute 0).
    loss_ref[...] += 0.5 * jnp.sum(r_blk * r_blk).reshape(1, 1) / n_total

    @pl.when(i == nblocks - 1)
    def _update():
        w_out_ref[...] = w - lr_ref[0, 0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n",))
def linear_sgd_step(
    x: jax.Array,
    w: jax.Array,
    y: jax.Array,
    lr: jax.Array,
    *,
    block_n: int = BLOCK_N,
):
    """One fused SGD step on the linear model.

    Returns ``(w - lr * grad, loss_before_step)`` in a single Pallas kernel —
    one HBM pass over X. This is the executable the Rust workers call via
    PJRT on the paper's own workload (see artifacts manifest).
    """
    n, d = x.shape
    xp, yp = _pad_rows(x, y, block_n)
    nblocks = xp.shape[0] // block_n
    kernel = functools.partial(_step_kernel, nblocks=nblocks, n_total=n)
    w_new, loss, _g = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),          # lr scalar
        ],
        out_specs=[
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),          # g accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((d, 1), jnp.float32),
        ],
        interpret=True,
    )(xp, w.reshape(d, 1), yp.reshape(-1, 1), lr.reshape(1, 1))
    return w_new.reshape(d), loss.reshape(())
