"""Layer-1 Pallas kernel: blocked (flash-style) attention with custom VJP.

Used by the L2 transformer (``python/compile/model.py``) so that the
end-to-end training artifact exercises a Pallas hot-spot in both the forward
and backward pass. The design follows the FlashAttention decomposition,
re-thought for TPU (DESIGN.md §Hardware-Adaptation):

  * forward: grid ``(batch*heads, q_blocks)``; each step holds one q tile in
    VMEM and streams k/v tiles through an online-softmax accumulation
    (running max ``m``, normaliser ``l``, un-normalised accumulator) —
    the HBM<->VMEM schedule a CUDA implementation expresses with
    threadblocks is expressed here with ``BlockSpec`` + an in-kernel loop;
  * the forward also emits the row-wise logsumexp so the backward can
    recompute probabilities without materialising the (s, s) score matrix
    in HBM;
  * backward: grid ``(batch*heads,)``; recomputes p tiles from (q, k, lse)
    and accumulates dq/dk/dv with MXU matmuls, looping over q tiles.

Causal masking is supported and is the mode the transformer uses.
``interpret=True`` throughout (CPU PJRT cannot run Mosaic custom-calls);
numerics are pinned to ``ref.attention_ref`` by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

_NEG_INF = -1e30  # large-negative instead of -inf: keeps masked softmax NaN-free


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_k, seq_k, scale, causal):
    """One (head, q-tile) program: online softmax over k tiles."""
    qi = pl.program_id(1)
    q = q_ref[0, :, :]                                   # (bq, dh)
    bq = q.shape[0]
    dh = q.shape[1]
    nkb = seq_k // block_k

    q_rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    def body(j, carry):
        m, l, acc = carry
        k_tile = k_ref[0, pl.dslice(j * block_k, block_k), :]   # (bk, dh)
        v_tile = v_ref[0, pl.dslice(j * block_k, block_k), :]
        s = jax.lax.dot_general(
            q, k_tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                               # (bq, bk)
        if causal:
            k_cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1
            )
            s = jnp.where(q_rows >= k_cols, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))              # (bq,)
        alpha = jnp.exp(m - m_new)                              # rescale old
        p = jnp.exp(s - m_new[:, None])                         # (bq, bk)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v_tile, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    # Causal: tiles strictly above the diagonal band contribute nothing.
    if causal:
        # Tiles strictly above the causal diagonal band are all-masked: the
        # last k tile that can intersect rows [qi*bq, (qi+1)*bq) is the one
        # containing column (qi+1)*bq - 1.
        upper = ((qi + 1) * bq + block_k - 1) // block_k
    else:
        upper = nkb
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, acc0))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, :, :] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0, :] = m + jnp.log(l_safe)


def _bwd_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, do_ref,
    dq_ref, dk_ref, dv_ref,
    *, block_q, scale, causal,
):
    """One head program: recompute p tiles from lse, accumulate dq/dk/dv."""
    q_all = q_ref[0, :, :]                               # (s, dh)
    k_all = k_ref[0, :, :]
    v_all = v_ref[0, :, :]
    o_all = o_ref[0, :, :]
    do_all = do_ref[0, :, :]
    lse = lse_ref[0, :]                                  # (s,)
    seq, dh = q_all.shape
    nqb = seq // block_q

    # D_i = rowsum(dO ∘ O) — the softmax-jacobian diagonal term.
    delta = jnp.sum(do_all * o_all, axis=1)              # (s,)

    def body(i, carry):
        dk, dv = carry
        q = jax.lax.dynamic_slice(q_all, (i * block_q, 0), (block_q, dh))
        do = jax.lax.dynamic_slice(do_all, (i * block_q, 0), (block_q, dh))
        lse_i = jax.lax.dynamic_slice(lse, (i * block_q,), (block_q,))
        delta_i = jax.lax.dynamic_slice(delta, (i * block_q,), (block_q,))
        s = jax.lax.dot_general(
            q, k_all, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                        # (bq, s)
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, seq), 0
            )
            cols = jax.lax.broadcasted_iota(jnp.int32, (block_q, seq), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        p = jnp.exp(s - lse_i[:, None])                  # (bq, s)
        dv = dv + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (s, dh)
        dp = jax.lax.dot_general(
            do, v_all, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (bq, s)
        ds = p * (dp - delta_i[:, None]) * scale         # (bq, s)
        dq_i = jax.lax.dot_general(
            ds, k_all, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (bq, dh)
        dq_ref[0, pl.dslice(i * block_q, block_q), :] = dq_i.astype(dq_ref.dtype)
        dk = dk + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (s, dh)
        return dk, dv

    dk0 = jnp.zeros((seq, dh), jnp.float32)
    dv0 = jnp.zeros((seq, dh), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, nqb, body, (dk0, dv0))
    dk_ref[0, :, :] = dk.astype(dk_ref.dtype)
    dv_ref[0, :, :] = dv.astype(dv_ref.dtype)


def _flatten_heads(x):
    b, h, s, d = x.shape
    return x.reshape(b * h, s, d)


def _fwd_impl(q, k, v, *, causal, block_q, block_k):
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    assert sq % block_q == 0, f"seq_q={sq} not a multiple of block_q={block_q}"
    assert sk % block_k == 0, f"seq_k={sk} not a multiple of block_k={block_k}"
    scale = 1.0 / (dh ** 0.5)
    qf, kf, vf = _flatten_heads(q), _flatten_heads(k), _flatten_heads(v)
    bh = b * h
    nqb = sq // block_q
    kernel = functools.partial(
        _fwd_kernel, block_k=block_k, seq_k=sk, scale=scale, causal=causal
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=(bh, nqb),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bhi, qi: (bhi, qi, 0)),
            pl.BlockSpec((1, sk, dh), lambda bhi, qi: (bhi, 0, 0)),
            pl.BlockSpec((1, sk, dh), lambda bhi, qi: (bhi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bhi, qi: (bhi, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bhi, qi: (bhi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        interpret=True,
    )(qf, kf, vf)
    return o.reshape(b, h, sq, dh), lse.reshape(b, h, sq)


def _bwd_impl(q, k, v, o, lse, do, *, causal, block_q):
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    scale = 1.0 / (dh ** 0.5)
    bh = b * h
    kernel = functools.partial(
        _bwd_kernel, block_q=block_q, scale=scale, causal=causal
    )
    full = lambda s: pl.BlockSpec((1, s, dh), lambda bhi: (bhi, 0, 0))
    dq, dk, dv = pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            full(sq), full(sk), full(sk), full(sq),
            pl.BlockSpec((1, sq), lambda bhi: (bhi, 0)),
            full(sq),
        ],
        out_specs=[full(sq), full(sk), full(sk)],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, sk, dh), k.dtype),
            jax.ShapeDtypeStruct((bh, sk, dh), v.dtype),
        ],
        interpret=True,
    )(
        _flatten_heads(q), _flatten_heads(k), _flatten_heads(v),
        _flatten_heads(o), lse.reshape(bh, sq), _flatten_heads(do),
    )
    rs = lambda x, s: x.reshape(b, h, s, dh)
    return rs(dq, sq), rs(dk, sk), rs(dv, sk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Blocked attention over (batch, heads, seq, head_dim) tensors.

    Differentiable: the VJP runs the Pallas backward kernel (recompute from
    logsumexp), so the whole train step lowers to plain HLO for the Rust
    runtime. Matches ``ref.attention_ref`` to float32 tolerance.
    """
    o, _ = _fwd_impl(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    return o


def _attention_fwd(q, k, v, causal, block_q, block_k):
    o, lse = _fwd_impl(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    return o, (q, k, v, o, lse)


def _attention_bwd(causal, block_q, block_k, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _bwd_impl(q, k, v, o, lse, do, causal=causal, block_q=block_q)
    return dq, dk, dv


attention.defvjp(_attention_fwd, _attention_bwd)
