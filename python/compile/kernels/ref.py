"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

Every kernel in this package must agree with the function of the same name
here to within float tolerance; `python/tests/test_kernels.py` sweeps shapes
and dtypes (hypothesis) and asserts allclose. These references are also used
directly by the L2 model tests as the ground truth for the transformer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_grad_ref(x: jax.Array, w: jax.Array, y: jax.Array) -> jax.Array:
    """Gradient of the mean-squared-error linear model.

    f(w) = 1/(2n) * ||x @ w - y||^2          (the paper's Section 5 workload)
    grad = 1/n * x^T (x @ w - y)

    Args:
      x: (n, d) design matrix.
      w: (d,) parameter vector.
      y: (n,) targets.
    Returns:
      (d,) gradient.
    """
    n = x.shape[0]
    r = x @ w - y
    return x.T @ r / n


def linear_loss_ref(x: jax.Array, w: jax.Array, y: jax.Array) -> jax.Array:
    """MSE loss matching `linear_grad_ref` (scalar)."""
    n = x.shape[0]
    r = x @ w - y
    return 0.5 * jnp.sum(r * r) / n


def linear_sgd_step_ref(x, w, y, lr):
    """One fused SGD step: returns (w - lr * grad, loss-before-step)."""
    g = linear_grad_ref(x, w, y)
    return w - lr * g, linear_loss_ref(x, w, y)


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """Scaled dot-product attention oracle.

    Args:
      q, k, v: (batch, heads, seq, head_dim).
      causal: apply a lower-triangular mask.
    Returns:
      (batch, heads, seq, head_dim) attention output.
    """
    head_dim = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        seq_q, seq_k = q.shape[-2], k.shape[-2]
        mask = jnp.tril(jnp.ones((seq_q, seq_k), dtype=bool))
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
