"""AOT compile path: lower every L2 entry point to HLO **text** + manifest.

Run once at build time (``make artifacts``); the Rust runtime
(``rust/src/runtime/``) loads ``artifacts/manifest.json``, compiles each
``*.hlo.txt`` on the PJRT CPU client and executes it on the request path —
Python never runs after this script finishes.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly. Lowering goes jitted-fn -> stablehlo ->
XlaComputation (``return_tuple=True``) -> ``as_hlo_text()``; the Rust side
unwraps the tuple.

Usage:
    cd python && python -m compile.aot [--out-dir ../artifacts] [--full]

``--full`` additionally lowers the `mid` (~10M-param) transformer set;
the `gpt2s` (~100M-class) set is lowered only with --gpt2s (the HLO is
cheap to produce but CPU-interpret training of it is impractically slow,
so it is excluded from the default build).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import sgd_linear

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return jnp.dtype(dt).name


def _spec(name: str, aval) -> dict:
    return {
        "name": name,
        "shape": list(aval.shape),
        "dtype": _dtype_name(aval.dtype),
    }


class ArtifactWriter:
    """Accumulates lowered artifacts + their manifest entries."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: list[dict] = []
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name: str, fn, example_args: list, arg_names: list[str],
            output_names: list[str], kind: str, meta: dict | None = None):
        """Lower ``fn(*example_args)`` and record a manifest entry.

        ``example_args`` are ShapeDtypeStructs (or arrays); outputs are
        described from the lowered signature so the manifest is always
        consistent with the artifact.
        """
        specs = [
            jax.ShapeDtypeStruct(a.shape, a.dtype) for a in example_args
        ]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        flat_outs = jax.tree_util.tree_leaves(out_avals)
        assert len(flat_outs) == len(output_names), (
            f"{name}: {len(flat_outs)} outputs, {len(output_names)} names"
        )
        entry = {
            "name": name,
            "path": path,
            "kind": kind,
            "inputs": [_spec(n, a) for n, a in zip(arg_names, specs)],
            "outputs": [_spec(n, a) for n, a in zip(output_names, flat_outs)],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "meta": meta or {},
        }
        self.entries.append(entry)
        print(f"  {name}: {len(text)} chars, "
              f"{len(entry['inputs'])} in / {len(entry['outputs'])} out")

    def finish(self):
        manifest = {
            "version": MANIFEST_VERSION,
            "generated_by": "python/compile/aot.py",
            "jax_version": jax.__version__,
            "artifacts": self.entries,
        }
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        print(f"wrote {len(self.entries)} artifacts -> "
              f"{self.out_dir}/manifest.json")


def add_linear(w: ArtifactWriter, n: int, d: int):
    """The paper's workload: fused SGD step + standalone gradient, (n, d)."""
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((n, d), f32),   # x
        jax.ShapeDtypeStruct((d,), f32),     # w
        jax.ShapeDtypeStruct((n,), f32),     # y
        jax.ShapeDtypeStruct((), f32),       # lr
    ]
    w.add(
        f"linear_step_n{n}_d{d}",
        lambda x, wp, y, lr: sgd_linear.linear_sgd_step(x, wp, y, lr),
        args, ["x", "w", "y", "lr"], ["w_new", "loss"],
        kind="linear_step", meta={"n": n, "d": d},
    )
    w.add(
        f"linear_grad_n{n}_d{d}",
        lambda x, wp, y: sgd_linear.linear_grad(x, wp, y),
        args[:3], ["x", "w", "y"], ["grad"],
        kind="linear_grad", meta={"n": n, "d": d},
    )


def add_transformer(w: ArtifactWriter, cfg: model.TransformerConfig,
                    batch: int):
    """init / train_step / eval_loss artifact triple for one config."""
    pspecs = cfg.param_specs()
    param_args = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in pspecs
    ]
    param_names = [name for name, _ in pspecs]
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq + 1), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    meta = {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "seq": cfg.seq,
            "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers, "d_ff": cfg.d_ff, "batch": batch,
            "param_count": cfg.param_count(),
        }
    }
    w.add(
        f"tf_{cfg.name}_init",
        lambda s: model.init_params(cfg, s),
        [seed], ["seed"], param_names, kind="tf_init", meta=meta,
    )
    w.add(
        f"tf_{cfg.name}_step",
        lambda *a: model.train_step(cfg, a[:-2], a[-2], a[-1]),
        param_args + [tokens, lr],
        param_names + ["tokens", "lr"],
        param_names + ["loss"],
        kind="tf_step", meta=meta,
    )
    w.add(
        f"tf_{cfg.name}_loss",
        lambda *a: model.loss_fn(cfg, a[:-1], a[-1]),
        param_args + [tokens],
        param_names + ["tokens"],
        ["loss"],
        kind="tf_loss", meta=meta,
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--full", action="store_true",
                    help="also lower the mid (~10M) transformer set")
    ap.add_argument("--gpt2s", action="store_true",
                    help="also lower the ~100M-class transformer set")
    args = ap.parse_args()

    w = ArtifactWriter(args.out_dir)
    print("lowering linear-model artifacts (paper Section 5 workload)...")
    add_linear(w, n=32, d=1000)     # the paper's 1000-parameter model
    add_linear(w, n=128, d=100)     # small sweep variant
    print("lowering transformer artifacts...")
    add_transformer(w, model.CONFIGS["tiny"], batch=8)
    add_transformer(w, model.CONFIGS["small"], batch=4)
    if args.full:
        add_transformer(w, model.CONFIGS["mid"], batch=2)
    if args.gpt2s:
        add_transformer(w, model.CONFIGS["gpt2s"], batch=1)
    w.finish()
    return 0


if __name__ == "__main__":
    sys.exit(main())
