"""Layer-2 JAX model definitions (build-time only; never on the request path).

Two workloads, both calling the Layer-1 Pallas kernels:

  * the paper's Section-5 workload — SGD on a d-parameter linear model —
    via ``kernels.sgd_linear.linear_sgd_step`` (fused grad+loss+update);
  * a decoder-only transformer LM for the end-to-end example, whose
    attention (forward *and* backward) is ``kernels.attention.attention``.

Everything here is pure-functional over explicit parameter lists so that
``aot.py`` can lower each entry point to a single HLO-text artifact with a
flat, manifest-described signature the Rust runtime can drive via PJRT.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from compile.kernels import attention as attn_kernel
from compile.kernels import sgd_linear


# --------------------------------------------------------------------------
# Linear model (the paper's evaluation workload)
# --------------------------------------------------------------------------

def linear_grad(x, w, y):
    """MSE gradient via the fused Pallas kernel (see kernels/sgd_linear.py)."""
    return sgd_linear.linear_grad(x, w, y)


def linear_sgd_step(x, w, y, lr):
    """Fused SGD step: (w', loss) in one HBM pass over x."""
    return sgd_linear.linear_sgd_step(x, w, y, lr)


# --------------------------------------------------------------------------
# Transformer LM (end-to-end example workload)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Decoder-only transformer hyper-parameters.

    ``name`` keys the artifact set in the manifest. ``block_q``/``block_k``
    are the Pallas attention tile sizes (must divide ``seq``).
    """

    name: str
    vocab: int
    seq: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    block_q: int = 64
    block_k: int = 64

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_specs(self) -> list[tuple[str, tuple[int, ...]]]:
        """Flat, ordered (name, shape) list — the AOT interchange contract."""
        specs: list[tuple[str, tuple[int, ...]]] = [
            ("embed", (self.vocab, self.d_model)),
            ("pos_embed", (self.seq, self.d_model)),
        ]
        for i in range(self.n_layers):
            p = f"layer{i}."
            specs += [
                (p + "ln1_scale", (self.d_model,)),
                (p + "ln1_bias", (self.d_model,)),
                (p + "wqkv", (self.d_model, 3 * self.d_model)),
                (p + "wo", (self.d_model, self.d_model)),
                (p + "ln2_scale", (self.d_model,)),
                (p + "ln2_bias", (self.d_model,)),
                (p + "w1", (self.d_model, self.d_ff)),
                (p + "b1", (self.d_ff,)),
                (p + "w2", (self.d_ff, self.d_model)),
                (p + "b2", (self.d_model,)),
            ]
        specs += [
            ("lnf_scale", (self.d_model,)),
            ("lnf_bias", (self.d_model,)),
        ]
        return specs

    def param_count(self) -> int:
        total = 0
        for _, shape in self.param_specs():
            n = 1
            for s in shape:
                n *= s
            total += n
        return total


# Named configurations. `tiny` is the default e2e run (CPU interpret-mode
# wall-clock); `mid` ~10M params; `gpt2s` is the ~100M-class config — same
# code path, lowered on demand (aot.py --full).
CONFIGS: dict[str, TransformerConfig] = {
    c.name: c
    for c in [
        TransformerConfig("tiny", vocab=256, seq=64, d_model=64, n_heads=4,
                          n_layers=2, d_ff=256, block_q=32, block_k=32),
        TransformerConfig("small", vocab=256, seq=128, d_model=128, n_heads=4,
                          n_layers=4, d_ff=512, block_q=64, block_k=64),
        TransformerConfig("mid", vocab=1024, seq=128, d_model=256, n_heads=8,
                          n_layers=12, d_ff=1024, block_q=64, block_k=64),
        TransformerConfig("gpt2s", vocab=32768, seq=256, d_model=768,
                          n_heads=12, n_layers=12, d_ff=3072,
                          block_q=64, block_k=64),
    ]
}


def init_params(cfg: TransformerConfig, seed: jax.Array) -> tuple[jax.Array, ...]:
    """Initialise the flat parameter tuple from an int32 seed (lowerable)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if base.endswith("_scale"):
            p = jnp.ones(shape, jnp.float32)
        elif base.endswith("_bias") or base.startswith("b"):
            p = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0]
            std = 0.02 if base in ("embed", "pos_embed") else fan_in ** -0.5
            p = jax.random.normal(sub, shape, jnp.float32) * std
        params.append(p)
    return tuple(params)


def _layer_norm(x, scale, bias, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def _unflatten(cfg: TransformerConfig, params: Sequence[jax.Array]):
    return {name: p for (name, _), p in zip(cfg.param_specs(), params)}


def forward(
    cfg: TransformerConfig, params: Sequence[jax.Array], tokens: jax.Array
) -> jax.Array:
    """Logits for ``tokens`` (batch, seq) int32 → (batch, seq, vocab)."""
    p = _unflatten(cfg, params)
    b, s = tokens.shape
    h = p["embed"][tokens] + p["pos_embed"][None, :s, :]
    for i in range(cfg.n_layers):
        lp = lambda k: p[f"layer{i}.{k}"]
        x = _layer_norm(h, lp("ln1_scale"), lp("ln1_bias"))
        qkv = x @ lp("wqkv")                              # (b, s, 3d)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        def heads(t):
            return t.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        # Layer-1 Pallas kernel: causal blocked attention, custom VJP.
        o = attn_kernel.attention(
            heads(q), heads(k), heads(v), True, cfg.block_q, cfg.block_k
        )
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        h = h + o @ lp("wo")
        x = _layer_norm(h, lp("ln2_scale"), lp("ln2_bias"))
        x = jax.nn.gelu(x @ lp("w1") + lp("b1"))
        h = h + x @ lp("w2") + lp("b2")
    h = _layer_norm(h, p["lnf_scale"], p["lnf_bias"])
    return h @ p["embed"].T                               # tied embedding


def loss_fn(
    cfg: TransformerConfig, params: Sequence[jax.Array], tokens: jax.Array
) -> jax.Array:
    """Next-token cross-entropy. ``tokens``: (batch, seq+1) int32."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(
    cfg: TransformerConfig,
    params: Sequence[jax.Array],
    tokens: jax.Array,
    lr: jax.Array,
) -> tuple[tuple[jax.Array, ...], jax.Array]:
    """One SGD step: returns (new flat params, loss before the step)."""
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, tokens))(
        tuple(params)
    )
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return new_params, loss
