"""AOT pipeline tests: HLO-text interchange + manifest schema."""

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_trivial_fn():
    """The interchange path itself: jit -> stablehlo -> HLO text."""
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[4]" in text


def test_to_hlo_text_is_text_not_proto():
    lowered = jax.jit(lambda x: (x + 1,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    # must be parseable ascii, not serialized proto bytes
    text.encode("ascii")
    assert "ENTRY" in text


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_schema(manifest):
    assert manifest["version"] == aot.MANIFEST_VERSION
    assert len(manifest["artifacts"]) >= 10
    for e in manifest["artifacts"]:
        for key in ("name", "path", "kind", "inputs", "outputs", "sha256"):
            assert key in e, e.get("name")
        for io in e["inputs"] + e["outputs"]:
            assert set(io) == {"name", "shape", "dtype"}
            assert io["dtype"] in ("float32", "int32")


def test_artifact_files_exist_and_hash(manifest):
    for e in manifest["artifacts"]:
        p = os.path.join(ART_DIR, e["path"])
        assert os.path.exists(p), e["name"]
        text = open(p).read()
        assert text.startswith("HloModule"), e["name"]
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]


def test_linear_step_artifact_signature(manifest):
    [e] = [a for a in manifest["artifacts"] if a["name"] == "linear_step_n32_d1000"]
    assert [i["name"] for i in e["inputs"]] == ["x", "w", "y", "lr"]
    assert e["inputs"][0]["shape"] == [32, 1000]
    assert e["inputs"][1]["shape"] == [1000]     # the paper's 1000 parameters
    assert [o["name"] for o in e["outputs"]] == ["w_new", "loss"]


def test_tf_step_artifact_signature(manifest):
    [e] = [a for a in manifest["artifacts"] if a["name"] == "tf_tiny_step"]
    cfg = model.CONFIGS["tiny"]
    n_params = len(cfg.param_specs())
    assert len(e["inputs"]) == n_params + 2          # params + tokens + lr
    assert len(e["outputs"]) == n_params + 1         # params' + loss
    assert e["inputs"][-2]["dtype"] == "int32"       # tokens
    assert e["meta"]["config"]["param_count"] == cfg.param_count()
    # init outputs must exactly mirror step param inputs
    [init] = [a for a in manifest["artifacts"] if a["name"] == "tf_tiny_init"]
    assert [o["shape"] for o in init["outputs"]] == [
        i["shape"] for i in e["inputs"][:n_params]
    ]
