"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps shapes (and attention masking modes) and pins the Pallas
kernels to the pure-jnp oracles in ``compile/kernels/ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn
from compile.kernels import ref, sgd_linear

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------- linear --

@given(
    n=st.integers(min_value=1, max_value=300),
    d=st.integers(min_value=1, max_value=192),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(**SETTINGS)
def test_linear_grad_matches_ref(n, d, seed):
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w, y = _rand(kx, (n, d)), _rand(kw, (d,)), _rand(ky, (n,))
    got = sgd_linear.linear_grad(x, w, y)
    want = ref.linear_grad_ref(x, w, y)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)


@given(
    n=st.integers(min_value=1, max_value=300),
    d=st.integers(min_value=1, max_value=192),
    lr=st.floats(min_value=1e-4, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(**SETTINGS)
def test_linear_step_matches_ref(n, d, lr, seed):
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w, y = _rand(kx, (n, d)), _rand(kw, (d,)), _rand(ky, (n,))
    w_new, loss = sgd_linear.linear_sgd_step(x, w, y, jnp.float32(lr))
    w_ref, loss_ref = ref.linear_sgd_step_ref(x, w, y, lr)
    np.testing.assert_allclose(w_new, w_ref, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(loss, loss_ref, rtol=3e-4)


def test_linear_grad_block_boundary():
    """n exactly at / just above / just below the VMEM tile boundary."""
    d = 64
    for n in (
        sgd_linear.BLOCK_N - 1,
        sgd_linear.BLOCK_N,
        sgd_linear.BLOCK_N + 1,
        2 * sgd_linear.BLOCK_N,
    ):
        kx, kw, ky = jax.random.split(jax.random.PRNGKey(n), 3)
        x, w, y = _rand(kx, (n, d)), _rand(kw, (d,)), _rand(ky, (n,))
        np.testing.assert_allclose(
            sgd_linear.linear_grad(x, w, y),
            ref.linear_grad_ref(x, w, y),
            rtol=3e-4, atol=3e-5,
        )


def test_linear_grad_paper_shape():
    """The paper's exact workload: 1000-parameter linear model."""
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(42), 3)
    x, w, y = _rand(kx, (32, 1000)), _rand(kw, (1000,)), _rand(ky, (32,))
    np.testing.assert_allclose(
        sgd_linear.linear_grad(x, w, y),
        ref.linear_grad_ref(x, w, y),
        rtol=3e-4, atol=3e-5,
    )


def test_linear_grad_zero_residual():
    """Exact fit => zero gradient (no catastrophic cancellation)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x, w = _rand(kx, (64, 32)), _rand(kw, (32,))
    y = x @ w
    g = sgd_linear.linear_grad(x, w, y)
    np.testing.assert_allclose(g, np.zeros(32), atol=1e-4)


def test_linear_step_custom_block_n():
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(3), 3)
    x, w, y = _rand(kx, (96, 48)), _rand(kw, (48,)), _rand(ky, (96,))
    for bn in (16, 32, 64):
        got = sgd_linear.linear_grad(x, w, y, block_n=bn)
        np.testing.assert_allclose(
            got, ref.linear_grad_ref(x, w, y), rtol=3e-4, atol=3e-5
        )


# ------------------------------------------------------------- attention --

ATTN_CASES = [
    # (batch, heads, seq, head_dim, causal, block_q, block_k)
    (1, 1, 32, 16, True, 16, 16),
    (1, 2, 64, 32, True, 32, 32),
    (2, 2, 64, 16, True, 64, 64),
    (1, 1, 64, 8, False, 16, 32),
    (2, 4, 128, 16, True, 64, 64),
    (1, 2, 96, 16, True, 32, 32),   # blocks not dividing each other's count
]


@pytest.mark.parametrize("b,h,s,dh,causal,bq,bk", ATTN_CASES)
def test_attention_forward_matches_ref(b, h, s, dh, causal, bq, bk):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(s * dh + b), 3)
    q, k, v = _rand(kq, (b, h, s, dh)), _rand(kk, (b, h, s, dh)), _rand(kv, (b, h, s, dh))
    got = attn.attention(q, k, v, causal, bq, bk)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("b,h,s,dh,causal,bq,bk", ATTN_CASES[:4])
def test_attention_grads_match_ref(b, h, s, dh, causal, bq, bk):
    keys = jax.random.split(jax.random.PRNGKey(1000 + s + dh), 4)
    q, k, v = (_rand(keys[i], (b, h, s, dh)) for i in range(3))
    do = _rand(keys[3], (b, h, s, dh))

    def f(q, k, v):
        return jnp.sum(attn.attention(q, k, v, causal, bq, bk) * do)

    def fr(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=causal) * do)

    got = jax.grad(f, (0, 1, 2))(q, k, v)
    want = jax.grad(fr, (0, 1, 2))(q, k, v)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=5e-3, atol=5e-4)


@given(
    seq_pow=st.integers(min_value=5, max_value=7),     # seq in {32, 64, 128}
    dh=st.sampled_from([8, 16, 32]),
    heads=st.integers(min_value=1, max_value=3),
    causal=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_attention_forward_hypothesis(seq_pow, dh, heads, causal, seed):
    s = 2 ** seq_pow
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(kq, (1, heads, s, dh))
    k = _rand(kk, (1, heads, s, dh))
    v = _rand(kv, (1, heads, s, dh))
    got = attn.attention(q, k, v, causal, 32, 32)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)


def test_attention_causal_first_row_is_v0():
    """Causal row 0 can only attend to position 0 => output row 0 == v[0]."""
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (_rand(x, (1, 1, 32, 16)) for x in (kq, kk, kv))
    out = attn.attention(q, k, v, True, 16, 16)
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=1e-5, atol=1e-6)


def test_attention_uniform_v_invariance():
    """If all v rows are identical, output equals that row regardless of p."""
    kq, kk = jax.random.split(jax.random.PRNGKey(6))
    q, k = _rand(kq, (1, 2, 64, 16)), _rand(kk, (1, 2, 64, 16))
    row = jnp.arange(16, dtype=jnp.float32)
    v = jnp.broadcast_to(row, (1, 2, 64, 16))
    out = attn.attention(q, k, v, True, 32, 32)
    np.testing.assert_allclose(
        out, jnp.broadcast_to(row, out.shape), rtol=1e-5, atol=1e-5
    )


def test_attention_rejects_misaligned_blocks():
    q = jnp.zeros((1, 1, 48, 8), jnp.float32)
    with pytest.raises(AssertionError):
        attn.attention(q, q, q, True, 32, 32)
