"""L2 model tests: transformer shapes, loss behaviour, SGD progress."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

# A micro config so interpret-mode tests stay fast.
MICRO = model.TransformerConfig(
    "micro", vocab=61, seq=32, d_model=32, n_heads=2, n_layers=1, d_ff=64,
    block_q=16, block_k=16,
)


@pytest.fixture(scope="module")
def micro_params():
    return model.init_params(MICRO, jnp.int32(0))


def _batch(key, cfg, batch=4):
    return jax.random.randint(key, (batch, cfg.seq + 1), 0, cfg.vocab)


def test_param_specs_match_init(micro_params):
    specs = MICRO.param_specs()
    assert len(specs) == len(micro_params)
    for (name, shape), p in zip(specs, micro_params):
        assert tuple(shape) == p.shape, name
        assert p.dtype == jnp.float32, name


def test_param_count_matches_arrays(micro_params):
    total = sum(int(np.prod(p.shape)) for p in micro_params)
    assert total == MICRO.param_count()


def test_configs_registry_consistent():
    for name, cfg in model.CONFIGS.items():
        assert cfg.name == name
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.seq % cfg.block_q == 0
        assert cfg.seq % cfg.block_k == 0
    # the ~100M-class config really is ~100M
    assert 80e6 < model.CONFIGS["gpt2s"].param_count() < 200e6


def test_forward_shapes(micro_params):
    tokens = _batch(jax.random.PRNGKey(1), MICRO)[:, :-1]
    logits = model.forward(MICRO, micro_params, tokens)
    assert logits.shape == (4, MICRO.seq, MICRO.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform(micro_params):
    """Fresh init => loss ~= ln(vocab)."""
    tokens = _batch(jax.random.PRNGKey(2), MICRO)
    loss = model.loss_fn(MICRO, micro_params, tokens)
    assert abs(float(loss) - np.log(MICRO.vocab)) < 0.5


def test_train_step_reduces_loss(micro_params):
    """A few SGD steps on a fixed batch must reduce the loss (memorise)."""
    tokens = _batch(jax.random.PRNGKey(3), MICRO)
    params = micro_params
    losses = []
    for _ in range(8):
        params, loss = model.train_step(MICRO, params, tokens, jnp.float32(0.5))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses
    assert all(np.isfinite(l) for l in losses)


def test_train_step_param_shapes_preserved(micro_params):
    tokens = _batch(jax.random.PRNGKey(4), MICRO)
    new_params, _ = model.train_step(MICRO, micro_params, tokens, jnp.float32(0.1))
    assert len(new_params) == len(micro_params)
    for old, new in zip(micro_params, new_params):
        assert old.shape == new.shape
        assert old.dtype == new.dtype


def test_train_step_zero_lr_is_identity(micro_params):
    tokens = _batch(jax.random.PRNGKey(5), MICRO)
    new_params, _ = model.train_step(MICRO, micro_params, tokens, jnp.float32(0.0))
    for old, new in zip(micro_params, new_params):
        np.testing.assert_allclose(old, new)


def test_loss_is_permutation_sensitive(micro_params):
    """Causal LM: shuffling target order must change the loss."""
    key = jax.random.PRNGKey(6)
    tokens = _batch(key, MICRO)
    loss_a = float(model.loss_fn(MICRO, micro_params, tokens))
    shuffled = tokens[:, ::-1]
    loss_b = float(model.loss_fn(MICRO, micro_params, shuffled))
    assert loss_a != pytest.approx(loss_b, abs=1e-9)


def test_init_deterministic():
    a = model.init_params(MICRO, jnp.int32(7))
    b = model.init_params(MICRO, jnp.int32(7))
    c = model.init_params(MICRO, jnp.int32(8))
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    assert any(
        not np.array_equal(pa, pc) for pa, pc in zip(a, c)
    ), "different seeds must differ"
