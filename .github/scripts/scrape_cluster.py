#!/usr/bin/env python3
"""Scrape the monitor endpoints of a local `actor node`/`actor join` cluster.

Polls each monitor port until it reports status "done" (or the deadline
passes), then asserts the deployment plane's durability contract:
zero dropped deltas, zero missing rumors, and identical per-origin
applied-rumor counts on every process. Stdlib only.

Usage: scrape_cluster.py PORT [PORT ...]
"""

import json
import sys
import time
import urllib.request

DEADLINE_SECS = 120.0


def fetch(port):
    url = f"http://127.0.0.1:{port}/"
    with urllib.request.urlopen(url, timeout=2) as resp:
        return json.loads(resp.read().decode("utf-8"))


def main():
    ports = [int(p) for p in sys.argv[1:]]
    if not ports:
        sys.exit("usage: scrape_cluster.py PORT [PORT ...]")

    deadline = time.monotonic() + DEADLINE_SECS
    docs = {}
    while time.monotonic() < deadline and len(docs) < len(ports):
        for port in ports:
            if port in docs:
                continue
            try:
                doc = fetch(port)
            except (OSError, ValueError):
                continue  # not up yet, or mid-run restartable read
            if doc.get("status") == "done":
                docs[port] = doc
        time.sleep(0.3)

    missing = [p for p in ports if p not in docs]
    if missing:
        sys.exit(f"monitors never reported status=done: {missing}")

    applied = None
    for port in ports:
        doc = docs[port]
        rep = doc["report"]
        print(
            f"monitor :{port} id={doc['id']} ring={doc['ring']} "
            f"applied_of={doc['applied_of']} dropped={rep['dropped_deltas']} "
            f"drain_polls={rep['drain_polls']}"
        )
        if rep["dropped_deltas"] != 0 or rep["missing_rumors"] != 0:
            sys.exit(f"monitor :{port}: lost updates — report {rep}")
        if applied is None:
            applied = doc["applied_of"]
        elif doc["applied_of"] != applied:
            sys.exit(
                f"monitor :{port}: applied_of diverges across processes: "
                f"{doc['applied_of']} != {applied}"
            )

    print(
        f"cluster clean: {len(ports)} processes done, "
        f"applied_of={applied}, zero dropped deltas"
    )


if __name__ == "__main__":
    main()
