#!/usr/bin/env python3
"""Scrape the monitor endpoints of a local `actor node`/`actor join` cluster.

Polls each monitor port until it reports status "done" (or the deadline
passes), then asserts the deployment plane's durability contract:
zero dropped deltas, zero missing rumors, and identical per-origin
applied-rumor counts on every process. Stdlib only.

Chaos mode (the `cluster-chaos` CI job): pass only the *survivor*
ports plus `--expect-dead ID` for a process that was SIGKILL'd mid-run.
Every survivor must then list ID in its membership verdicts as
confirmed dead, at least one survivor must have sent custody-repair
traffic, and — with `--max-wall S` — the whole scrape must finish in S
seconds, proving the crash cost ~suspect+confirm rather than the drain
timeout.

Usage: scrape_cluster.py [--expect-dead ID] [--max-wall S] PORT [PORT ...]
"""

import json
import sys
import time
import urllib.request

DEADLINE_SECS = 120.0


def fetch(port):
    url = f"http://127.0.0.1:{port}/"
    with urllib.request.urlopen(url, timeout=2) as resp:
        return json.loads(resp.read().decode("utf-8"))


def parse_args(argv):
    expect_dead = None
    max_wall = None
    ports = []
    it = iter(argv)
    for arg in it:
        if arg == "--expect-dead":
            expect_dead = int(next(it))
        elif arg == "--max-wall":
            max_wall = float(next(it))
        else:
            ports.append(int(arg))
    return expect_dead, max_wall, ports


def main():
    try:
        expect_dead, max_wall, ports = parse_args(sys.argv[1:])
    except (StopIteration, ValueError):
        sys.exit(
            "usage: scrape_cluster.py [--expect-dead ID] [--max-wall S] "
            "PORT [PORT ...]"
        )
    if not ports:
        sys.exit("usage: scrape_cluster.py PORT [PORT ...]")

    t0 = time.monotonic()
    deadline = t0 + (max_wall if max_wall is not None else DEADLINE_SECS)
    docs = {}
    while time.monotonic() < deadline and len(docs) < len(ports):
        for port in ports:
            if port in docs:
                continue
            try:
                doc = fetch(port)
            except (OSError, ValueError):
                continue  # not up yet, or mid-run restartable read
            if doc.get("status") == "done":
                docs[port] = doc
        time.sleep(0.3)
    wall = time.monotonic() - t0

    missing = [p for p in ports if p not in docs]
    if missing:
        sys.exit(
            f"monitors never reported status=done within {wall:.1f}s: {missing}"
        )

    applied = None
    repair_msgs = 0
    for port in ports:
        doc = docs[port]
        rep = doc["report"]
        print(
            f"monitor :{port} id={doc['id']} ring={doc['ring']} "
            f"applied_of={doc['applied_of']} dropped={rep['dropped_deltas']} "
            f"drain_polls={rep['drain_polls']}"
        )
        if rep["dropped_deltas"] != 0 or rep["missing_rumors"] != 0:
            sys.exit(f"monitor :{port}: lost updates — report {rep}")
        bar = doc.get("barrier")
        if bar is not None:
            # -1 encodes ASP's unbounded staleness (u64::MAX) — JSON
            # numbers could not carry the sentinel.
            theta = [("inf" if t == -1 else int(t)) for t in bar["eff_staleness"]]
            print(
                f"monitor :{port} barrier: method={bar['method']} "
                f"adaptive={bar['adaptive']} waits={bar['barrier_waits']} "
                f"stalls={bar['stall_ticks']} eff_theta={theta} "
                f"eff_beta={[int(b) for b in bar['eff_sample']]}"
            )
            if not bar["adaptive"]:
                base = theta[0] if theta else None
                if any(t != base for t in theta):
                    sys.exit(
                        f"monitor :{port}: adaptation is off but effective "
                        f"staleness diverges across workers: {theta}"
                    )
        comp = doc.get("compress")
        if comp is not None and comp["mode"] != "dense":
            print(
                f"monitor :{port} compress: mode={comp['mode']} "
                f"payload_bytes={int(comp['payload_bytes'])} "
                f"fed_back_mass={comp['fed_back_mass']:.3f}"
            )
        if applied is None:
            applied = doc["applied_of"]
        elif doc["applied_of"] != applied:
            sys.exit(
                f"monitor :{port}: applied_of diverges across processes: "
                f"{doc['applied_of']} != {applied}"
            )
        if expect_dead is not None:
            mem = doc.get("membership")
            if mem is None:
                sys.exit(
                    f"monitor :{port}: --expect-dead given but the status "
                    f"JSON has no membership section (membership plane off?)"
                )
            print(
                f"monitor :{port} membership: alive={mem['alive']} "
                f"suspect={mem['suspect']} confirmed_dead={mem['confirmed_dead']} "
                f"repair_msgs={mem['repair_msgs']} "
                f"repaired_rumors={mem['repaired_rumors']}"
            )
            if expect_dead not in mem["confirmed_dead"]:
                sys.exit(
                    f"monitor :{port}: node {expect_dead} was killed but is "
                    f"not confirmed dead: {mem}"
                )
            if doc["id"] in mem["confirmed_dead"]:
                sys.exit(f"monitor :{port}: survivor thinks itself dead: {mem}")
            repair_msgs += mem["repair_msgs"]

    if expect_dead is not None and repair_msgs == 0:
        sys.exit(
            f"node {expect_dead} confirmed dead but no survivor sent any "
            f"custody-repair traffic — its rumors cannot have been re-announced"
        )
    if max_wall is not None and wall > max_wall:
        sys.exit(f"cluster took {wall:.1f}s, over the --max-wall {max_wall}s bound")

    verdict = (
        f"crash of node {expect_dead} detected + repaired ({repair_msgs} repair msgs)"
        if expect_dead is not None
        else "zero dropped deltas"
    )
    print(
        f"cluster clean in {wall:.1f}s: {len(ports)} processes done, "
        f"applied_of={applied}, {verdict}"
    )


if __name__ == "__main__":
    main()
