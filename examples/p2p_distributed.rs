//! Fully-distributed barrier control (paper §4.1 case 4): the p2p engine
//! on real OS threads — every worker holds a model replica, samples the
//! chord-like overlay for its *own* barrier decision, and no global state
//! exists anywhere in the system.
//!
//! ```text
//! cargo run --release --example p2p_distributed
//! ```

use std::sync::{Arc, Mutex};

use actor_psp::barrier::Method;
use actor_psp::engine::p2p::{self, P2pConfig};
use actor_psp::engine::GradFn;
use actor_psp::model::linear::{Dataset, LinearModel};
use actor_psp::util::rng::Rng;
use actor_psp::util::stats::l2_dist;

fn main() {
    let dim = 64;
    let mut rng = Rng::new(31);
    let data = Arc::new(Dataset::synthetic(1024, dim, 0.05, &mut rng));
    let w_true = data.w_true.clone();

    println!(
        "p2p engine: 12 worker threads, replicated d={dim} linear model, \
         overlay-sampled barriers\n"
    );
    println!(
        "{:>10} {:>9} {:>12} {:>12} {:>12} {:>9}",
        "method", "steps", "updates", "ctrl msgs", "final err", "wall(s)"
    );
    for method in [
        Method::Asp,
        Method::Pbsp { sample: 3 },
        Method::Pssp { sample: 3, staleness: 2 },
    ] {
        let cfg = P2pConfig {
            n_workers: 12,
            steps_per_worker: 30,
            method,
            lr: 0.01,
            dim,
            seed: 5,
            ..P2pConfig::default()
        };
        let data = Arc::clone(&data);
        let model = Mutex::new(LinearModel::new(dim));
        let grad: GradFn = Arc::new(move |w, seed| {
            model.lock().unwrap().minibatch_grad(&data, w, seed, 32).to_vec()
        });
        let r = p2p::run(&cfg, vec![0.0; dim], grad);
        println!(
            "{:>10} {:>9} {:>12} {:>12} {:>12.4} {:>9.2}",
            method.to_string(),
            r.steps.iter().sum::<u64>(),
            r.update_msgs,
            r.control_msgs,
            l2_dist(&r.model, &w_true),
            r.wall_secs,
        );
    }
    println!(
        "\nnote: BSP/SSP cannot run here at all — they need a global view; \
         the engine rejects them\nat construction. That asymmetry is the \
         paper's core systems claim."
    );
}
