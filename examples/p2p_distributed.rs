//! Fully-distributed barrier control (paper §4.1 case 4): the p2p engine
//! on real OS threads — every worker holds a model replica, samples the
//! chord-like overlay for its *own* barrier decision, and no global state
//! exists anywhere in the system.
//!
//! The model plane runs twice per method: over the legacy **full-mesh**
//! broadcast (every delta to every peer, n·(n−1) messages per step) and
//! over the **gossip plane** (sequence-numbered rumors, per-link
//! batching, ring-successor chain + TTL'd overlay shortcuts) — same
//! convergence, an order of magnitude fewer physical messages.
//!
//! ```text
//! cargo run --release --example p2p_distributed
//! ```

use std::sync::{Arc, Mutex};

use actor_psp::barrier::Method;
use actor_psp::engine::gossip::GossipConfig;
use actor_psp::engine::p2p::{self, Dissemination, P2pConfig};
use actor_psp::engine::GradFn;
use actor_psp::model::linear::{Dataset, LinearModel};
use actor_psp::util::rng::Rng;
use actor_psp::util::stats::l2_dist;

fn main() {
    let dim = 64;
    let n_workers = 16;
    let mut rng = Rng::new(31);
    let data = Arc::new(Dataset::synthetic(1024, dim, 0.05, &mut rng));
    let w_true = data.w_true.clone();

    println!(
        "p2p engine: {n_workers} worker threads, replicated d={dim} linear \
         model, overlay-sampled barriers\n"
    );
    println!(
        "{:>10} {:>8} {:>9} {:>12} {:>9} {:>10} {:>12} {:>9}",
        "method", "plane", "steps", "updates", "upd/step", "ctrl msgs", "final err",
        "wall(s)"
    );
    for method in [
        Method::Asp,
        Method::Pbsp { sample: 3 },
        Method::Pssp { sample: 3, staleness: 2 },
    ] {
        for (plane, dissemination) in [
            ("mesh", Dissemination::FullMesh),
            (
                "gossip",
                Dissemination::Gossip(GossipConfig {
                    fanout: 2,
                    flush_every: 1,
                    ttl: 6,
                }),
            ),
        ] {
            let cfg = P2pConfig {
                n_workers,
                steps_per_worker: 30,
                method,
                lr: 0.01,
                dim,
                seed: 5,
                dissemination,
                ..P2pConfig::default()
            };
            let data = Arc::clone(&data);
            let model = Mutex::new(LinearModel::new(dim));
            let grad: GradFn = Arc::new(move |w, seed| {
                model.lock().unwrap().minibatch_grad(&data, w, seed, 32).to_vec()
            });
            let r = p2p::run(&cfg, vec![0.0; dim], grad);
            let steps: u64 = r.steps.iter().sum();
            if r.dropped_deltas > 0 {
                eprintln!("warning: {} late delta(s) dropped", r.dropped_deltas);
            }
            println!(
                "{:>10} {:>8} {:>9} {:>12} {:>9.2} {:>10} {:>12.4} {:>9.2}",
                method.to_string(),
                plane,
                steps,
                r.update_msgs,
                r.update_msgs as f64 / steps.max(1) as f64,
                r.control_msgs,
                l2_dist(&r.model, &w_true),
                r.wall_secs,
            );
        }
    }
    // Crash-fault demo: one worker crash-stops mid-run — no Done, no
    // handoff. The membership plane (SWIM-style suspect/confirm over the
    // heartbeat table) detects it, the dead node's ring successor
    // re-announces its rumor count from the custody store, and the
    // survivors drain promptly with nothing lost.
    println!("\ncrash-stop demo: worker 5 dies silently at step 10 of 30");
    let cfg = P2pConfig {
        n_workers,
        steps_per_worker: 30,
        method: Method::Pssp { sample: 3, staleness: 2 },
        lr: 0.01,
        dim,
        seed: 5,
        churn: vec![p2p::Departure { worker: 5, at_step: 10, graceful: false }],
        ..P2pConfig::default()
    };
    let data = Arc::clone(&data);
    let model = Mutex::new(LinearModel::new(dim));
    let grad: GradFn = Arc::new(move |w, seed| {
        model.lock().unwrap().minibatch_grad(&data, w, seed, 32).to_vec()
    });
    let r = p2p::run(&cfg, vec![0.0; dim], grad);
    println!(
        "  survivors finished {} steps; {} death confirmation(s), {} repair \
         msg(s), {} rumor(s)\n  repaired; {} missing / {} dropped; drained in \
         {:.2}s (drain_timeout is {:.0}s) — final err {:.4}",
        r.steps.iter().sum::<u64>(),
        r.confirmed_dead,
        r.repair_msgs,
        r.repaired_rumors,
        r.missing_rumors,
        r.dropped_deltas,
        r.wall_secs,
        cfg.drain_timeout.as_secs_f64(),
        l2_dist(&r.model, &w_true),
    );
    println!(
        "\nnotes: the mesh sends n-1 = {} updates per worker-step; gossip \
         batches rumors per link\nand rides the overlay (successor chain + \
         fanout sampled shortcuts), applying every delta\nexactly once via \
         per-origin sequence dedup. BSP/SSP cannot run here at all — they \
         need a\nglobal view; the engine rejects them at construction. That \
         asymmetry is the paper's core\nsystems claim.",
        n_workers - 1
    );
}
