//! The paper's motivating scenario (§1, §3): a large, heterogeneous,
//! unreliable wide-area deployment — heavy-tailed iteration times
//! (Pareto), non-negligible churn, and stragglers — where deterministic
//! barrier control breaks down.
//!
//! ```text
//! cargo run --release --example heterogeneous_edge
//! ```
//!
//! Compares all five barrier methods on the same hostile cluster and
//! prints progress, dispersion, error and the communication bill.

use actor_psp::barrier::Method;
use actor_psp::sim::{
    ChurnConfig, ClusterConfig, SgdConfig, Simulator, StragglerConfig, TimeDist,
};
use actor_psp::util::stats::Summary;

fn main() {
    let edge = ClusterConfig {
        n_nodes: 500,
        duration: 40.0,
        seed: 2024,
        mean_iter_time: 1.0,
        speed_jitter: 0.5,
        // heavy-tailed compute: some iterations take many times the mean
        iter_dist: TimeDist::Pareto { shape: 2.2 },
        stragglers: Some(StragglerConfig { fraction: 0.05, slowdown: 4.0 }),
        churn: Some(ChurnConfig { join_rate: 1.0, leave_rate: 1.0, crash_rate: 0.0 }),
        net_delay_mean: 0.15, // wide-area RTTs
        sgd: Some(SgdConfig { dim: 500, ..SgdConfig::default() }),
        ..ClusterConfig::default()
    };

    println!(
        "heterogeneous edge: 500 nodes, Pareto(2.2) iteration times, 5% 4x \
         stragglers,\nchurn ~1 join + 1 leave/s, 150ms mean delay, 40 \
         simulated seconds\n"
    );
    println!(
        "{:>10} {:>8} {:>8} {:>9} {:>10} {:>12} {:>12}",
        "method", "mean", "iqr", "nodes@end", "updates", "ctrl msgs", "final error"
    );
    for method in Method::paper_five(5, 4) {
        let r = Simulator::new(edge.clone(), method).run();
        let steps: Vec<f64> = r.final_steps.iter().map(|&s| s as f64).collect();
        let s = Summary::of(&steps);
        println!(
            "{:>10} {:>8.1} {:>8.1} {:>9} {:>10} {:>12} {:>12.4}",
            method.to_string(),
            s.mean,
            s.iqr(),
            r.final_steps.len(),
            r.update_msgs,
            r.control_msgs,
            r.final_error().unwrap_or(f64::NAN),
        );
    }
    println!(
        "\nBSP/SSP progress collapses under the heavy tail + churn; ASP \
         races ahead but pays in error;\npBSP/pSSP keep near-ASP progress \
         with bounded dispersion — and their control traffic is O(β) per\n\
         decision instead of the global state a BSP/SSP server must \
         maintain."
    );
}
