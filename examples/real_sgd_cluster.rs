//! Three layers composing on the paper's own workload: the parameter-
//! server engine (L3, real threads) computing every worker gradient
//! through the **AOT Pallas kernel artifact** via PJRT (L1+L2).
//!
//! ```text
//! make artifacts && cargo run --release --example real_sgd_cluster
//! ```
//!
//! Python is nowhere in this process: the gradient executable was lowered
//! once at build time (`python/compile/aot.py`) to HLO text; here Rust
//! loads, compiles and executes it on the PJRT CPU client.

use std::sync::Arc;

use actor_psp::barrier::Method;
use actor_psp::engine::paramserver::{self, PsConfig};
use actor_psp::model::linear::Dataset;
use actor_psp::runtime::{linear_grad_fn, RuntimeService};
use actor_psp::util::rng::Rng;
use actor_psp::util::stats::l2_dist;

fn main() -> anyhow::Result<()> {
    // The paper's workload shape: the linear_grad_n128_d100 artifact.
    let (rows, dim) = (128usize, 100usize);
    let mut rng = Rng::new(11);
    let data = Arc::new(Dataset::synthetic(2048, dim, 0.05, &mut rng));
    let w_true = data.w_true.clone();

    let svc = Arc::new(RuntimeService::spawn()?);
    println!("PJRT service up; gradients run the Pallas kernel artifact\n");

    println!(
        "{:>10} {:>9} {:>12} {:>12} {:>12} {:>9}",
        "method", "steps", "updates", "ctrl msgs", "final err", "wall(s)"
    );
    for method in Method::paper_five(3, 2) {
        let grad = linear_grad_fn(
            Arc::clone(&svc),
            "linear_grad_n128_d100",
            Arc::clone(&data),
            rows,
        )?;
        let cfg = PsConfig {
            n_workers: 6,
            steps_per_worker: 12,
            method,
            lr: 0.05,
            dim,
            seed: 3,
            ..PsConfig::default()
        };
        let r = paramserver::run(&cfg, vec![0.0; dim], grad);
        println!(
            "{:>10} {:>9} {:>12} {:>12} {:>12.4} {:>9.2}",
            method.to_string(),
            r.steps.iter().sum::<u64>(),
            r.update_msgs,
            r.control_msgs,
            l2_dist(&r.model, &w_true),
            r.wall_secs,
        );
    }
    println!("\nall five barrier methods drive the same PJRT-backed gradient.");
    Ok(())
}
