//! Three layers composing on the paper's own workload: the **sharded**
//! parameter-server engine (L3, real threads) computing every worker
//! gradient through the AOT Pallas kernel artifact via PJRT (L1+L2),
//! swept across shard counts and push-batch sizes.
//!
//! ```text
//! cargo run --release --example real_sgd_cluster
//! ```
//!
//! With PJRT available (the `pjrt` feature plus a vendored `xla` crate —
//! see rust/Cargo.toml — and `make artifacts`), Python is nowhere in
//! this process: the gradient
//! executable was lowered once at build time (`python/compile/aot.py`) to
//! HLO text; Rust loads, compiles and executes it on the PJRT CPU client.
//! Without artifacts (or without the `pjrt` feature) the example falls
//! back to the pure-Rust gradient for the same workload shape, so the
//! engine sweep itself runs anywhere — including CI.

use std::sync::Arc;

use actor_psp::barrier::Method;
use actor_psp::engine::paramserver::{self, PsConfig};
use actor_psp::engine::GradFn;
use actor_psp::model::linear::{minibatch_grad_fn, Dataset};
use actor_psp::runtime::{linear_grad_fn, RuntimeService};
use actor_psp::util::rng::Rng;
use actor_psp::util::stats::l2_dist;

fn main() -> anyhow::Result<()> {
    // The paper's workload shape: the linear_grad_n128_d100 artifact.
    let (rows, dim) = (128usize, 100usize);
    let mut rng = Rng::new(11);
    let data = Arc::new(Dataset::synthetic(2048, dim, 0.05, &mut rng));
    let w_true = data.w_true.clone();

    // PJRT if we can, pure Rust if we must.
    let svc = if cfg!(feature = "pjrt") {
        match RuntimeService::spawn() {
            Ok(svc) => {
                println!("PJRT service up; gradients run the Pallas kernel artifact\n");
                Some(Arc::new(svc))
            }
            Err(e) => {
                println!("PJRT unavailable ({e:#}); using pure-Rust gradients\n");
                None
            }
        }
    } else {
        println!("built without the `pjrt` feature; using pure-Rust gradients\n");
        None
    };
    let make_grad = || -> anyhow::Result<GradFn> {
        match &svc {
            Some(svc) => linear_grad_fn(
                Arc::clone(svc),
                "linear_grad_n128_d100",
                Arc::clone(&data),
                rows,
            ),
            None => Ok(minibatch_grad_fn(Arc::clone(&data), rows)),
        }
    };

    println!(
        "{:>10} {:>7} {:>6} {:>9} {:>12} {:>12} {:>12} {:>9}",
        "method", "shards", "batch", "steps", "updates", "ctrl msgs", "final err",
        "wall(s)"
    );
    for method in Method::paper_five(3, 2) {
        for (n_shards, push_batch) in [(1usize, 1usize), (4, 1), (4, 4)] {
            let grad = make_grad()?;
            let cfg = PsConfig {
                n_workers: 6,
                steps_per_worker: 12,
                method,
                lr: 0.05,
                dim,
                seed: 3,
                n_shards,
                push_batch,
                ..PsConfig::default()
            };
            let r = paramserver::run(&cfg, vec![0.0; dim], grad);
            println!(
                "{:>10} {:>7} {:>6} {:>9} {:>12} {:>12} {:>12.4} {:>9.2}",
                method.to_string(),
                n_shards,
                push_batch,
                r.steps.iter().sum::<u64>(),
                r.update_msgs,
                r.control_msgs,
                l2_dist(&r.model, &w_true),
                r.wall_secs,
            );
        }
    }
    println!(
        "\nall five barrier methods drive the same gradient kernel across \
         every shard layout:\nsharding the model plane never touches barrier \
         semantics — the paper's sampling\nprimitive needs only the \
         coordinator's step table."
    );
    Ok(())
}
