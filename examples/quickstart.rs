//! Quickstart: compare BSP against pSSP on a small simulated cluster.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! A 64-node cluster runs SGD on a 200-parameter linear model for 60
//! simulated seconds under both barriers; the table shows PSP's trade-off:
//! near-ASP progress with bounded spread and better final error per
//! update message.

use actor_psp::barrier::Method;
use actor_psp::sim::{ClusterConfig, SgdConfig, Simulator};
use actor_psp::util::stats::Summary;

fn main() {
    let base = ClusterConfig {
        n_nodes: 64,
        duration: 60.0,
        seed: 7,
        sgd: Some(SgdConfig { dim: 200, ..SgdConfig::default() }),
        ..ClusterConfig::default()
    };

    println!("quickstart: 64 nodes, 60 simulated seconds, linear SGD d=200\n");
    println!(
        "{:>10} {:>8} {:>8} {:>8} {:>10} {:>10} {:>12}",
        "method", "mean", "iqr", "max", "updates", "control", "final error"
    );
    for method in [
        Method::Bsp,
        Method::Ssp { staleness: 4 },
        Method::Asp,
        Method::Pbsp { sample: 6 },
        Method::Pssp { sample: 6, staleness: 4 },
    ] {
        let r = Simulator::new(base.clone(), method).run();
        let steps: Vec<f64> = r.final_steps.iter().map(|&s| s as f64).collect();
        let s = Summary::of(&steps);
        println!(
            "{:>10} {:>8.1} {:>8.1} {:>8.0} {:>10} {:>10} {:>12.4}",
            method.to_string(),
            s.mean,
            s.iqr(),
            s.max,
            r.update_msgs,
            r.control_msgs,
            r.final_error().unwrap_or(f64::NAN),
        );
    }
    println!(
        "\nreading the table: pssp iterates ~as fast as asp but keeps the \
         step spread (iqr) bounded,\nand reaches a lower error than bsp/ssp \
         in the same 60 seconds — the paper's Fig 1 in miniature."
    );
}
