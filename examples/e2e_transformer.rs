//! END-TO-END DRIVER: train a decoder-only transformer LM for a few
//! hundred steps through the full three-layer stack, under PSP pacing.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_transformer
//! ARGS: [config] [steps] [workers]   (defaults: tiny 300 8)
//! ```
//!
//! * L1: the attention forward *and* backward are the Pallas kernels in
//!   `python/compile/kernels/attention.py` (interpret-lowered to HLO);
//! * L2: the fused train step (fwd + bwd + SGD update) was lowered once
//!   by `python/compile/aot.py`;
//! * L3: this Rust process initialises parameters from a seed artifact,
//!   streams batches from a synthetic corpus, and paces 8 heterogeneous
//!   logical workers with pSSP — then compares against BSP and ASP
//!   pacing on the same budget.
//!
//! The loss curve is logged below and recorded in EXPERIMENTS.md.

use actor_psp::barrier::Method;
use actor_psp::runtime::Runtime;
use actor_psp::train::{psp_train_lm, Corpus, TransformerTrainer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg = args.first().map(|s| s.as_str()).unwrap_or("tiny").to_string();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let seed = 42u64;

    let rt = Runtime::new()?;
    println!("platform: {}", rt.platform());
    let mut trainer = TransformerTrainer::new(rt, &cfg, seed as i32)?;
    let meta = trainer.meta.clone();
    println!(
        "transformer '{}': {} parameters in {} tensors | vocab {} seq {} \
         batch {} | uniform baseline loss {:.3}\n",
        meta.name,
        meta.param_count,
        meta.n_params,
        meta.vocab,
        meta.seq,
        meta.batch,
        trainer.uniform_loss()
    );
    let corpus = Corpus::synthetic(1 << 16, meta.vocab, seed ^ 0xC0);

    // Held-out batch for honest evaluation.
    let mut eval_rng = actor_psp::util::rng::Rng::new(seed ^ 0xEE);
    let eval_batch = corpus.next_batch(meta.batch, meta.seq, &mut eval_rng);

    let mut summary = Vec::new();
    for (label, method) in [
        ("pssp", Method::Pssp { sample: 3, staleness: 2 }),
        ("bsp", Method::Bsp),
        ("asp", Method::Asp),
    ] {
        // fresh parameters per run (same seed => same init)
        let rt = Runtime::new()?;
        trainer = TransformerTrainer::new(rt, &cfg, seed as i32)?;
        println!(
            "== {label}: {workers} heterogeneous workers (10% are 4x \
             stragglers), {steps} steps"
        );
        let log = psp_train_lm(
            &mut trainer,
            &corpus,
            method,
            workers,
            steps,
            0.25,
            seed,
            Some((0.1, 4.0)),
            1,
        )?;
        for (s, l) in log.losses.iter().step_by((steps as usize / 10).max(1)) {
            println!("   step {s:>5}  train loss {l:.4}");
        }
        let eval = trainer.eval_loss(&eval_batch)?;
        println!(
            "   done in {:.1}s ({:.2} steps/s) | loss {:.3} -> {:.3} | \
             held-out {eval:.3} | worker steps {:?}\n",
            log.wall_secs,
            log.steps_per_sec,
            log.first_loss(),
            log.last_loss(),
            log.worker_steps,
        );
        summary.push((label, log.first_loss(), log.tail_mean(20), eval));
    }

    println!("summary (train-first, train-tail, held-out):");
    for (label, first, tail, eval) in summary {
        println!("  {label:>6}  {first:.3}  {tail:.3}  {eval:.3}");
    }
    println!(
        "\nall three runs share L1/L2 executables; only the L3 barrier \
         policy differs."
    );
    Ok(())
}
