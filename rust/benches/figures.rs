//! End-to-end figure regeneration benches: one timed run per paper
//! table/figure (the harness DESIGN.md §5 maps). Validates that the full
//! reproduction sweep stays cheap enough to iterate on, and IS the code
//! path that regenerates every figure (same as `actor exp <id>`).
//!
//! Pass `--full` for paper-scale (1000 nodes, 40 s); default is the quick
//! profile so `cargo bench` completes in minutes.

use actor_psp::exp::{self, ExpOpts};
use actor_psp::util::bench::bench_once;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let opts = ExpOpts {
        quick: !full,
        nodes: if full { 1000 } else { 200 },
        duration: if full { 40.0 } else { 15.0 },
        sample: if full { 10 } else { 5 },
        out_dir: Some(std::path::PathBuf::from("results")),
        ..ExpOpts::default()
    };
    println!(
        "figure regeneration ({} profile) — tables land in results/",
        if full { "paper-scale" } else { "quick" }
    );
    println!("{}", "-".repeat(110));
    let mut total = 0.0;
    for id in exp::ALL {
        let (res, secs) = bench_once(&format!("exp {id}"), || exp::run(id, &opts));
        if let Err(e) = res {
            eprintln!("  exp {id} FAILED: {e:#}");
            std::process::exit(1);
        }
        total += secs;
    }
    println!("{}", "-".repeat(110));
    println!("all {} experiments regenerated in {total:.1}s", exp::ALL.len());
}
