//! Hot-path microbenches: the barrier decision, the sampling primitive,
//! and the sharded parameter-server push path.
//!
//! The paper's scalability argument is quantitative: a PSP decision costs
//! O(β) regardless of system size, while global methods need O(P) state.
//! These benches measure exactly that (and feed EXPERIMENTS.md §Perf),
//! plus the engine-level consequence: splitting the model plane across
//! shard actors multiplies push throughput because nothing in the barrier
//! path ever serialised through the model queue.

use std::sync::Arc;
use std::time::Duration;

use actor_psp::barrier::{decide_with_oracle, BarrierControl, Bsp, Method, Probabilistic, Ssp};
use actor_psp::engine::paramserver::{self, PsConfig};
use actor_psp::engine::GradFn;
use actor_psp::overlay::Ring;
use actor_psp::sampling::StepTracker;
use actor_psp::util::bench::{bench, bench_once};
use actor_psp::util::rng::Rng;

fn main() {
    let budget = Duration::from_millis(400);
    println!("barrier decision + sampling primitive microbenches");
    println!("{}", "-".repeat(110));

    // A realistic mid-training step table: 10k nodes spread over 20 steps.
    let mut rng = Rng::new(1);
    for &n in &[1_000usize, 10_000] {
        let mut tracker = StepTracker::new(n);
        for _ in 0..(n * 10) {
            let node = rng.next_below(n as u64) as usize;
            if tracker.step_of(node) < tracker.min_step() + 20 {
                tracker.advance(node);
            }
        }
        let steps = tracker.all_steps();
        let mut scratch = Vec::new();

        // Global predicates: O(P) over the raw view, O(1) via the tracker.
        let bsp = Bsp;
        bench(&format!("bsp predicate, raw view P={n}"), budget, || {
            std::hint::black_box(bsp.can_advance(10, &steps));
        });
        bench(&format!("bsp predicate via tracker min P={n}"), budget, || {
            std::hint::black_box(tracker.min_step() >= 10);
        });

        // The sampling primitive at the paper's β=10.
        for &beta in &[1usize, 10, 100] {
            bench(
                &format!("sample_min β={beta} P={n} (PSP decision)"),
                budget,
                || {
                    std::hint::black_box(tracker.sample_min(
                        0,
                        beta,
                        &mut rng,
                        &mut scratch,
                    ));
                },
            );
        }

        // Full composed decisions through the trait object.
        let pssp = Probabilistic::new(Ssp::new(4), 10);
        bench(&format!("pssp(10,4) decide_with_oracle P={n}"), budget, || {
            std::hint::black_box(decide_with_oracle(
                &pssp,
                10,
                &steps,
                &mut rng,
                &mut scratch,
            ));
        });
    }

    // Overlay-based distributed sampling (routing + window + acceptance).
    for &n in &[100usize, 1_000] {
        let ring = Ring::with_nodes(n, 7);
        bench(&format!("overlay sample_nodes β=10 n={n}"), budget, || {
            std::hint::black_box(ring.sample_nodes(0, 10, &mut rng));
        });
    }

    // Method construction (config path, not hot, for completeness).
    bench("Method::parse + build", budget, || {
        let m = Method::parse("pssp:10:4").unwrap();
        std::hint::black_box(m.build().staleness());
    });

    // ---- sharded parameter-server push throughput ----
    //
    // 16 workers hammer the model plane with cheap (precomputed) gradients
    // so the server side is the bottleneck: one shard must apply + serve
    // the full d-dimensional vector per worker-step, K shards split both
    // the arithmetic and the mailbox contention. The PR's acceptance bar
    // is >= 1.5x worker-step throughput at 4 shards vs 1.
    println!();
    println!("sharded parameter-server push path (16 workers, d=8192, ASP)");
    let dim = 8192usize;
    let fixed: Arc<Vec<f32>> =
        Arc::new((0..dim).map(|j| (j as f32).sin() * 1e-4).collect());
    let grad: GradFn = {
        let fixed = Arc::clone(&fixed);
        Arc::new(move |_w, _seed| fixed.as_ref().clone())
    };
    let mut baseline = 0.0f64;
    for &shards in &[1usize, 2, 4, 8] {
        let cfg = PsConfig {
            n_workers: 16,
            steps_per_worker: 120,
            method: Method::Asp,
            lr: 1e-6,
            dim,
            seed: 1,
            n_shards: shards,
            ..PsConfig::default()
        };
        let grad = grad.clone();
        let (r, _) = bench_once(&format!("ps push 16w x 120 steps, {shards} shard(s)"), || {
            paramserver::run(&cfg, vec![0.0; dim], grad)
        });
        let steps: u64 = r.steps.iter().sum();
        let rate = steps as f64 / r.wall_secs.max(1e-9);
        if shards == 1 {
            baseline = rate;
        }
        println!(
            "    -> {:.1}k worker-steps/s, {} push msgs{}",
            rate / 1e3,
            r.update_msgs,
            if shards == 1 {
                String::new()
            } else {
                format!("  ({:.2}x vs 1 shard)", rate / baseline.max(1e-9))
            },
        );
    }
    // Batched pushes on top of sharding: fewer, fatter scatter messages.
    for &(shards, push_batch) in &[(4usize, 4usize), (4, 8)] {
        let cfg = PsConfig {
            n_workers: 16,
            steps_per_worker: 120,
            method: Method::Asp,
            lr: 1e-6,
            dim,
            seed: 1,
            n_shards: shards,
            push_batch,
            ..PsConfig::default()
        };
        let grad = grad.clone();
        let (r, _) = bench_once(
            &format!("ps push 16w x 120 steps, {shards} shards, batch {push_batch}"),
            || paramserver::run(&cfg, vec![0.0; dim], grad),
        );
        let steps: u64 = r.steps.iter().sum();
        println!(
            "    -> {:.1}k worker-steps/s, {} push msgs ({:.2}x vs 1 shard unbatched)",
            steps as f64 / r.wall_secs.max(1e-9) / 1e3,
            r.update_msgs,
            steps as f64 / r.wall_secs.max(1e-9) / baseline.max(1e-9),
        );
    }
}
