//! Hot-path microbenches: the barrier decision and the sampling primitive.
//!
//! The paper's scalability argument is quantitative: a PSP decision costs
//! O(β) regardless of system size, while global methods need O(P) state.
//! These benches measure exactly that (and feed EXPERIMENTS.md §Perf).

use std::time::Duration;

use actor_psp::barrier::{decide_with_oracle, BarrierControl, Bsp, Method, Probabilistic, Ssp};
use actor_psp::overlay::Ring;
use actor_psp::sampling::StepTracker;
use actor_psp::util::bench::bench;
use actor_psp::util::rng::Rng;

fn main() {
    let budget = Duration::from_millis(400);
    println!("barrier decision + sampling primitive microbenches");
    println!("{}", "-".repeat(110));

    // A realistic mid-training step table: 10k nodes spread over 20 steps.
    let mut rng = Rng::new(1);
    for &n in &[1_000usize, 10_000] {
        let mut tracker = StepTracker::new(n);
        for _ in 0..(n * 10) {
            let node = rng.next_below(n as u64) as usize;
            if tracker.step_of(node) < tracker.min_step() + 20 {
                tracker.advance(node);
            }
        }
        let steps = tracker.all_steps();
        let mut scratch = Vec::new();

        // Global predicates: O(P) over the raw view, O(1) via the tracker.
        let bsp = Bsp;
        bench(&format!("bsp predicate, raw view P={n}"), budget, || {
            std::hint::black_box(bsp.can_advance(10, &steps));
        });
        bench(&format!("bsp predicate via tracker min P={n}"), budget, || {
            std::hint::black_box(tracker.min_step() + 0 >= 10);
        });

        // The sampling primitive at the paper's β=10.
        for &beta in &[1usize, 10, 100] {
            bench(
                &format!("sample_min β={beta} P={n} (PSP decision)"),
                budget,
                || {
                    std::hint::black_box(tracker.sample_min(
                        0,
                        beta,
                        &mut rng,
                        &mut scratch,
                    ));
                },
            );
        }

        // Full composed decisions through the trait object.
        let pssp = Probabilistic::new(Ssp::new(4), 10);
        bench(&format!("pssp(10,4) decide_with_oracle P={n}"), budget, || {
            std::hint::black_box(decide_with_oracle(
                &pssp,
                10,
                &steps,
                &mut rng,
                &mut scratch,
            ));
        });
    }

    // Overlay-based distributed sampling (routing + window + acceptance).
    for &n in &[100usize, 1_000] {
        let ring = Ring::with_nodes(n, 7);
        bench(&format!("overlay sample_nodes β=10 n={n}"), budget, || {
            std::hint::black_box(ring.sample_nodes(0, 10, &mut rng));
        });
    }

    // Method construction (config path, not hot, for completeness).
    bench("Method::parse + build", budget, || {
        let m = Method::parse("pssp:10:4").unwrap();
        std::hint::black_box(m.build().staleness());
    });
}
