//! Hot-path microbenches: the barrier decision, the sampling primitive,
//! the p2p model plane (full-mesh vs gossip), and the sharded
//! parameter-server push path. The overlay-sampling block asserts the
//! cost stays ~logarithmic in n (guards the reverse-index fix).
//!
//! The paper's scalability argument is quantitative: a PSP decision costs
//! O(β) regardless of system size, while global methods need O(P) state.
//! These benches measure exactly that (and feed EXPERIMENTS.md §Perf),
//! plus the engine-level consequence: splitting the model plane across
//! shard actors multiplies push throughput because nothing in the barrier
//! path ever serialised through the model queue.

use std::sync::Arc;
use std::time::Duration;

use actor_psp::barrier::{decide_with_oracle, BarrierControl, Bsp, Method, Probabilistic, Ssp};
use actor_psp::engine::gossip::GossipConfig;
use actor_psp::engine::p2p::{self, Dissemination, P2pConfig};
use actor_psp::engine::paramserver::{self, PsConfig};
use actor_psp::engine::GradFn;
use actor_psp::overlay::Ring;
use actor_psp::sampling::StepTracker;
use actor_psp::util::bench::{bench, bench_once};
use actor_psp::util::rng::Rng;

fn main() {
    let budget = Duration::from_millis(400);
    println!("barrier decision + sampling primitive microbenches");
    println!("{}", "-".repeat(110));

    // A realistic mid-training step table: 10k nodes spread over 20 steps.
    let mut rng = Rng::new(1);
    for &n in &[1_000usize, 10_000] {
        let mut tracker = StepTracker::new(n);
        for _ in 0..(n * 10) {
            let node = rng.next_below(n as u64) as usize;
            if tracker.step_of(node) < tracker.min_step() + 20 {
                tracker.advance(node);
            }
        }
        let steps = tracker.all_steps();
        let mut scratch = Vec::new();

        // Global predicates: O(P) over the raw view, O(1) via the tracker.
        let bsp = Bsp;
        bench(&format!("bsp predicate, raw view P={n}"), budget, || {
            std::hint::black_box(bsp.can_advance(10, &steps));
        });
        bench(&format!("bsp predicate via tracker min P={n}"), budget, || {
            std::hint::black_box(tracker.min_step() >= 10);
        });

        // The sampling primitive at the paper's β=10.
        for &beta in &[1usize, 10, 100] {
            bench(
                &format!("sample_min β={beta} P={n} (PSP decision)"),
                budget,
                || {
                    std::hint::black_box(tracker.sample_min(
                        0,
                        beta,
                        &mut rng,
                        &mut scratch,
                    ));
                },
            );
        }

        // Full composed decisions through the trait object.
        let pssp = Probabilistic::new(Ssp::new(4), 10);
        bench(&format!("pssp(10,4) decide_with_oracle P={n}"), budget, || {
            std::hint::black_box(decide_with_oracle(
                &pssp,
                10,
                &steps,
                &mut rng,
                &mut scratch,
            ));
        });
    }

    // Overlay-based distributed sampling (routing + window + acceptance).
    // The reverse node->id index keeps owner recovery O(log n); the
    // scaling assertion below holds the line — before it, an O(n) scan
    // per draw made 16x more nodes cost ~16x more per sample.
    let mut sample_cost = Vec::new();
    for &n in &[100usize, 1_000, 16_000] {
        let ring = Ring::with_nodes(n, 7);
        let r = bench(&format!("overlay sample_nodes β=10 n={n}"), budget, || {
            std::hint::black_box(ring.sample_nodes(0, 10, &mut rng));
        });
        sample_cost.push((n, r.mean_ns));
    }
    {
        let (n0, t0) = sample_cost[1];
        let (n1, t1) = sample_cost[2];
        let mut ratio = t1 / t0.max(1e-9);
        // Wall-clock gate, so shrug off one noisy-neighbour measurement
        // before failing: re-time the large ring and keep the better
        // ratio. Expected ~1.5-2.5x (log growth); the old O(n) owner
        // scan measures >=16x here, so 8.0 separates the regimes with
        // margin on both sides even on a loaded CI runner.
        if ratio >= 8.0 {
            let ring = Ring::with_nodes(n1, 7);
            let retry = bench(
                &format!("overlay sample_nodes β=10 n={n1} (retry)"),
                budget,
                || {
                    std::hint::black_box(ring.sample_nodes(0, 10, &mut rng));
                },
            );
            ratio = ratio.min(retry.mean_ns / t0.max(1e-9));
        }
        println!(
            "    -> {}x nodes cost {ratio:.2}x per sample (linear scan would \
             be ~{}x)",
            n1 / n0,
            n1 / n0
        );
        assert!(
            ratio < 8.0,
            "overlay sampling cost grew {ratio:.1}x from n={n0} to n={n1} — \
             it must stay ~logarithmic in n (reverse-index regression?)"
        );
    }

    // Method construction (config path, not hot, for completeness).
    bench("Method::parse + build", budget, || {
        let m = Method::parse("pssp:10:4").unwrap();
        std::hint::black_box(m.build().staleness());
    });

    // ---- sharded parameter-server push throughput ----
    //
    // 16 workers hammer the model plane with cheap (precomputed) gradients
    // so the server side is the bottleneck: one shard must apply + serve
    // the full d-dimensional vector per worker-step, K shards split both
    // the arithmetic and the mailbox contention. The PR's acceptance bar
    // is >= 1.5x worker-step throughput at 4 shards vs 1.
    println!();
    println!("sharded parameter-server push path (16 workers, d=8192, ASP)");
    let dim = 8192usize;
    let fixed: Arc<Vec<f32>> =
        Arc::new((0..dim).map(|j| (j as f32).sin() * 1e-4).collect());
    let grad: GradFn = {
        let fixed = Arc::clone(&fixed);
        Arc::new(move |_w, _seed| fixed.as_ref().clone())
    };
    let mut baseline = 0.0f64;
    for &shards in &[1usize, 2, 4, 8] {
        let cfg = PsConfig {
            n_workers: 16,
            steps_per_worker: 120,
            method: Method::Asp,
            lr: 1e-6,
            dim,
            seed: 1,
            n_shards: shards,
            ..PsConfig::default()
        };
        let grad = grad.clone();
        let (r, _) = bench_once(&format!("ps push 16w x 120 steps, {shards} shard(s)"), || {
            paramserver::run(&cfg, vec![0.0; dim], grad)
        });
        let steps: u64 = r.steps.iter().sum();
        let rate = steps as f64 / r.wall_secs.max(1e-9);
        if shards == 1 {
            baseline = rate;
        }
        println!(
            "    -> {:.1}k worker-steps/s, {} push msgs{}",
            rate / 1e3,
            r.update_msgs,
            if shards == 1 {
                String::new()
            } else {
                format!("  ({:.2}x vs 1 shard)", rate / baseline.max(1e-9))
            },
        );
    }
    // ---- p2p model plane: full-mesh vs gossip dissemination ----
    //
    // Same engine, same workload, two transports. The mesh pays
    // n·(n-1) physical messages per step; the gossip plane batches
    // rumors per link and pays O(n·fanout), trading bounded rumor-copy
    // redundancy for an O(n) cut in message count.
    println!();
    println!("p2p model plane: full-mesh vs gossip (32 workers, d=256, ASP)");
    let p2p_dim = 256usize;
    let p2p_fixed: Arc<Vec<f32>> =
        Arc::new((0..p2p_dim).map(|j| (j as f32).cos() * 1e-4).collect());
    let p2p_grad: GradFn = {
        let fixed = Arc::clone(&p2p_fixed);
        Arc::new(move |_w, _seed| fixed.as_ref().clone())
    };
    let mut mesh_per_step = 0.0f64;
    for (label, dissemination) in [
        ("full-mesh", Dissemination::FullMesh),
        (
            "gossip f=2 ttl=6",
            Dissemination::Gossip(GossipConfig { fanout: 2, flush_every: 1, ttl: 6 }),
        ),
        (
            "gossip f=2 flush=4",
            Dissemination::Gossip(GossipConfig { fanout: 2, flush_every: 4, ttl: 6 }),
        ),
    ] {
        let cfg = P2pConfig {
            n_workers: 32,
            steps_per_worker: 20,
            method: Method::Asp,
            lr: 1e-6,
            dim: p2p_dim,
            seed: 1,
            dissemination,
            ..P2pConfig::default()
        };
        let grad = p2p_grad.clone();
        let (r, _) = bench_once(&format!("p2p 32w x 20 steps, {label}"), || {
            p2p::run(&cfg, vec![0.0; p2p_dim], grad)
        });
        let steps: u64 = r.steps.iter().sum();
        let per_step = r.update_msgs as f64 / steps.max(1) as f64;
        if mesh_per_step == 0.0 {
            mesh_per_step = per_step;
        }
        println!(
            "    -> {} update msgs ({per_step:.2}/worker-step, {:.1}x fewer \
             than mesh), {} rumor copies, {} dropped",
            r.update_msgs,
            mesh_per_step / per_step.max(1e-9),
            r.rumor_copies,
            r.dropped_deltas,
        );
    }

    // Batched pushes on top of sharding: fewer, fatter scatter messages.
    for &(shards, push_batch) in &[(4usize, 4usize), (4, 8)] {
        let cfg = PsConfig {
            n_workers: 16,
            steps_per_worker: 120,
            method: Method::Asp,
            lr: 1e-6,
            dim,
            seed: 1,
            n_shards: shards,
            push_batch,
            ..PsConfig::default()
        };
        let grad = grad.clone();
        let (r, _) = bench_once(
            &format!("ps push 16w x 120 steps, {shards} shards, batch {push_batch}"),
            || paramserver::run(&cfg, vec![0.0; dim], grad),
        );
        let steps: u64 = r.steps.iter().sum();
        println!(
            "    -> {:.1}k worker-steps/s, {} push msgs ({:.2}x vs 1 shard unbatched)",
            steps as f64 / r.wall_secs.max(1e-9) / 1e3,
            r.update_msgs,
            steps as f64 / r.wall_secs.max(1e-9) / baseline.max(1e-9),
        );
    }
}
