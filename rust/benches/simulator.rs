//! Simulator throughput benches (L3 hot loop): events/s per barrier
//! method, with and without the real-SGD workload, plus the pure
//! minibatch-gradient kernel the SGD mode spends its time in.

use std::time::Duration;

use actor_psp::barrier::Method;
use actor_psp::model::linear::{Dataset, LinearModel};
use actor_psp::sim::{ClusterConfig, SgdConfig, Simulator};
use actor_psp::util::bench::{bench, bench_once};
use actor_psp::util::rng::Rng;

fn main() {
    println!("simulator throughput (events/s is the L3 perf headline)");
    println!("{}", "-".repeat(110));

    // Pure barrier-dynamics simulation, paper scale.
    for method in Method::paper_five(10, 4) {
        let cfg = ClusterConfig {
            n_nodes: 1000,
            duration: 40.0,
            seed: 42,
            ..ClusterConfig::default()
        };
        let (r, secs) = bench_once(
            &format!("sim 1000x40s {method} (no sgd)"),
            || Simulator::new(cfg, method).run(),
        );
        println!(
            "    -> {} events, {:.2}M events/s, {} advances",
            r.events,
            r.events as f64 / secs / 1e6,
            r.total_advances
        );
    }

    // With the real-SGD workload (d=1000): gradient math dominates.
    let cfg = ClusterConfig {
        n_nodes: 1000,
        duration: 40.0,
        seed: 42,
        sgd: Some(SgdConfig::default()),
        ..ClusterConfig::default()
    };
    let (r, secs) = bench_once("sim 1000x40s pssp:10:4 + sgd d=1000", || {
        Simulator::new(cfg, Method::Pssp { sample: 10, staleness: 4 }).run()
    });
    println!(
        "    -> {} updates applied, {:.1}k updates/s",
        r.update_msgs,
        r.update_msgs as f64 / secs / 1e3
    );

    // The inner gradient kernel on its own.
    let mut rng = Rng::new(3);
    let data = Dataset::synthetic(4096, 1000, 0.1, &mut rng);
    let w = vec![0.1f32; 1000];
    let mut model = LinearModel::new(1000);
    let mut seed = 0u64;
    bench(
        "minibatch_grad d=1000 b=32 (pure rust)",
        Duration::from_millis(500),
        || {
            seed += 1;
            std::hint::black_box(model.minibatch_grad(&data, &w, seed, 32));
        },
    );

    // Scaling in system size at fixed horizon.
    for &n in &[100usize, 1_000, 10_000] {
        let cfg = ClusterConfig {
            n_nodes: n,
            duration: 20.0,
            seed: 1,
            ..ClusterConfig::default()
        };
        let (r, secs) = bench_once(&format!("sim n={n} 20s pbsp:10"), || {
            Simulator::new(cfg, Method::Pbsp { sample: 10 }).run()
        });
        println!(
            "    -> {:.2}M events/s",
            r.events as f64 / secs / 1e6
        );
    }
}
