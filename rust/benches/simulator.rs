//! Simulator throughput benches (L3 hot loop): events/s per barrier
//! method and per system size, heap-vs-calendar scheduler comparison,
//! the real-SGD workload, and the 100k-node sweep.
//!
//! Usage (args after `cargo bench --bench simulator --`):
//!
//! ```text
//! --smoke            small CI grid (skips the full-horizon method table)
//! --json PATH        write results as JSON (default results/bench_simulator.json)
//! --check PATH       compare events/s against a baseline suite
//! --tol F            allowed fractional regression (default 0.30)
//! ```
//!
//! Relative paths resolve against the workspace root, so the invocation
//! works from any working directory. Exits non-zero when any benchmark
//! regresses more than `tol` below the baseline; baselines with missing
//! or null `events_per_sec` entries are reported but not enforced (the
//! bootstrap state before real CI numbers are committed).

use std::path::{Path, PathBuf};
use std::time::Duration;

use actor_psp::barrier::Method;
use actor_psp::engine::delta::CompressConfig;
use actor_psp::engine::paramserver::ShardLayout;
use actor_psp::model::linear::{Dataset, LinearModel};
use actor_psp::sim::{ChurnConfig, ClusterConfig, SgdConfig, SimResult, Simulator};
use actor_psp::util::bench::{bench, bench_once, BenchSuite};
use actor_psp::util::rng::Rng;

/// Resolve a path against the workspace root (parent of the crate dir)
/// so `cargo bench` behaves the same from the root or from `rust/`.
fn from_workspace(p: &str) -> PathBuf {
    let p = Path::new(p);
    if p.is_absolute() {
        return p.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|root| root.join(p))
        .unwrap_or_else(|| p.to_path_buf())
}

struct Opts {
    smoke: bool,
    json: String,
    check: Option<String>,
    tol: f64,
}

fn parse_opts() -> Opts {
    let mut opts = Opts {
        smoke: false,
        json: "results/bench_simulator.json".to_string(),
        check: None,
        tol: 0.30,
    };
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--smoke" => opts.smoke = true,
            "--json" => opts.json = it.next().expect("--json needs a path"),
            "--check" => opts.check = Some(it.next().expect("--check needs a path")),
            "--tol" => {
                opts.tol = it
                    .next()
                    .expect("--tol needs a value")
                    .parse()
                    .expect("--tol must be a number")
            }
            // cargo bench passes `--bench` (and test-harness flags) through.
            _ => {}
        }
    }
    opts
}

fn scale_cfg(n: usize) -> ClusterConfig {
    ClusterConfig {
        n_nodes: n,
        duration: 20.0,
        seed: 1,
        ..ClusterConfig::default()
    }
}

fn record_run(suite: &mut BenchSuite, name: &str, r: &SimResult, secs: f64) {
    let eps = r.events as f64 / secs.max(1e-9);
    println!(
        "    -> {} events, {:.2}M events/s, {} advances",
        r.events,
        eps / 1e6,
        r.total_advances
    );
    suite.record(name, &[
        ("events_per_sec", eps),
        ("events", r.events as f64),
        ("advances", r.total_advances as f64),
        ("wall_secs", secs),
    ]);
}

fn main() {
    let opts = parse_opts();
    let mut suite = BenchSuite::new("simulator");
    println!("simulator throughput (events/s is the L3 perf headline)");
    println!("{}", "-".repeat(110));

    // Virtual-node load balance: max/min per-shard push-traffic ratio
    // (each batched push to a shard carries its owned-key count in f32s,
    // so key counts are proportional to push bytes). One ring position
    // per shard reproduces the classic successor-placement skew; 64
    // vnodes must flatten it — the ratio-of-ratios is gated below like
    // the calendar/heap speedup (runs in smoke mode too: pure layout
    // arithmetic, no simulation).
    let vnode_improvement;
    {
        let (dim, n_shards) = (4096, 8);
        let skewed = ShardLayout::new(dim, n_shards, 1).imbalance();
        let flat = ShardLayout::new(dim, n_shards, 64).imbalance();
        vnode_improvement = skewed / flat;
        println!(
            "vnode balance d={dim} shards={n_shards}: max/min {skewed:.2} \
             (1 vnode) -> {flat:.2} (64 vnodes), {vnode_improvement:.2}x better"
        );
        suite.record("vnode_balance", &[
            ("imbalance_v1", skewed),
            ("imbalance_v64", flat),
            ("improvement", vnode_improvement),
        ]);
    }

    // Pure barrier-dynamics simulation, paper scale (full mode only).
    if !opts.smoke {
        for method in Method::paper_five(10, 4) {
            let cfg = ClusterConfig {
                n_nodes: 1000,
                duration: 40.0,
                seed: 42,
                ..ClusterConfig::default()
            };
            let name = format!("sim_n1000_40s_{method}");
            let (r, secs) = bench_once(
                &format!("sim 1000x40s {method} (no sgd)"),
                || Simulator::new(cfg, method).run(),
            );
            record_run(&mut suite, &name, &r, secs);
        }
    }

    // Calendar queue vs the binary-heap oracle at the acceptance point
    // (n=10_000, pbsp:10, 20s): same trajectory, different scheduler.
    let calendar_speedup;
    {
        let cfg = scale_cfg(10_000);
        let m = Method::Pbsp { sample: 10 };
        let (r_heap, secs_heap) = bench_once("sim n=10000 20s pbsp:10 (heap oracle)", || {
            Simulator::new(cfg.clone(), m).run_reference()
        });
        record_run(&mut suite, "sim_n10000_pbsp10_heap", &r_heap, secs_heap);
        let (r_cal, secs_cal) = bench_once("sim n=10000 20s pbsp:10 (calendar)", || {
            Simulator::new(cfg, m).run()
        });
        record_run(&mut suite, "sim_n10000_pbsp10", &r_cal, secs_cal);
        assert_eq!(
            r_heap.final_steps, r_cal.final_steps,
            "schedulers must agree on trajectories"
        );
        let speedup = (r_cal.events as f64 / secs_cal.max(1e-9))
            / (r_heap.events as f64 / secs_heap.max(1e-9));
        println!("    -> calendar/heap speedup at n=10k: {speedup:.2}x");
        suite.record("sim_n10000_pbsp10", &[("speedup_vs_heap", speedup)]);
        calendar_speedup = speedup;
    }

    // Scaling in system size at fixed horizon, up to the 100k sweep the
    // README's Performance section quotes.
    let sizes: &[usize] = if opts.smoke {
        &[1_000, 100_000]
    } else {
        &[100, 1_000, 10_000, 100_000]
    };
    for &n in sizes {
        let cfg = scale_cfg(n);
        let (r, secs) = bench_once(&format!("sim n={n} 20s pbsp:10"), || {
            Simulator::new(cfg, Method::Pbsp { sample: 10 }).run()
        });
        record_run(&mut suite, &format!("sim_n{n}_pbsp10_scale"), &r, secs);
    }

    // Crash-fault churn at scale: Crash/ConfirmDead events plus victims
    // pinned in the tracker until confirmation exercise the membership
    // plane's simulator model — the hot loop must absorb the extra event
    // kinds without losing its events/s headline.
    {
        let cfg = ClusterConfig {
            churn: Some(ChurnConfig {
                join_rate: 5.0,
                leave_rate: 2.0,
                crash_rate: 2.0,
            }),
            crash_detect_secs: 1.0,
            ..scale_cfg(10_000)
        };
        let (r, secs) = bench_once("sim n=10000 20s pbsp:10 + crash churn", || {
            Simulator::new(cfg, Method::Pbsp { sample: 10 }).run()
        });
        println!("    -> {} crash-stop(s) confirmed through the run", r.crashes);
        record_run(&mut suite, "sim_n10000_pbsp10_crash_churn", &r, secs);
    }

    // With the real-SGD workload: gradient math dominates; the versioned
    // snapshot store must keep this at least at parity with the old
    // clone-per-advance design while holding memory bounded.
    {
        let dim = if opts.smoke { 200 } else { 1000 };
        let cfg = ClusterConfig {
            n_nodes: 1000,
            duration: if opts.smoke { 20.0 } else { 40.0 },
            seed: 42,
            sgd: Some(SgdConfig { dim, ..SgdConfig::default() }),
            ..ClusterConfig::default()
        };
        let name = format!("sim_n1000_sgd_d{dim}");
        let (r, secs) = bench_once(&format!("sim 1000 pssp:10:4 + sgd d={dim}"), || {
            Simulator::new(cfg, Method::Pssp { sample: 10, staleness: 4 }).run()
        });
        println!(
            "    -> {} updates applied, {:.1}k updates/s",
            r.update_msgs,
            r.update_msgs as f64 / secs / 1e3
        );
        suite.record(&name, &[
            ("events_per_sec", r.events as f64 / secs.max(1e-9)),
            ("updates_per_sec", r.update_msgs as f64 / secs.max(1e-9)),
            ("wall_secs", secs),
        ]);
    }

    // Delta-payload compression: the sim plane's bytes/update headline.
    // Same seed means the same event trajectory (encoding never touches
    // event timing), so the dense/top-k payload-byte ratio IS the
    // per-update wire saving. Hardware-independent, gated below like
    // the vnode and calendar ratios (runs in smoke mode too).
    let compress_ratio;
    {
        let dim = 1024;
        let mk = |compress| ClusterConfig {
            n_nodes: 100,
            duration: 10.0,
            seed: 42,
            sgd: Some(SgdConfig { dim, ..SgdConfig::default() }),
            compress,
            ..ClusterConfig::default()
        };
        let m = Method::Pssp { sample: 10, staleness: 4 };
        let (dense, _) =
            bench_once("sim n=100 10s + sgd d=1024 (dense payloads)", || {
                Simulator::new(mk(Some(CompressConfig::default())), m).run()
            });
        let (topk, _) =
            bench_once("sim n=100 10s + sgd d=1024 (top-k 64)", || {
                Simulator::new(mk(CompressConfig::parse("topk", 64, "i8")), m)
                    .run()
            });
        assert_eq!(
            dense.update_msgs, topk.update_msgs,
            "compression must not change the event trajectory"
        );
        let per = |r: &SimResult| {
            r.payload_bytes as f64 / r.update_msgs.max(1) as f64
        };
        compress_ratio = per(&dense) / per(&topk).max(1e-9);
        println!(
            "    -> payload bytes/update d={dim}: dense {:.0}B, top-k 64 \
             {:.0}B ({compress_ratio:.2}x smaller)",
            per(&dense),
            per(&topk)
        );
        suite.record("compress_bytes", &[
            ("bytes_ratio", compress_ratio),
            ("dense_bytes_per_update", per(&dense)),
            ("topk_bytes_per_update", per(&topk)),
        ]);
    }

    // The inner gradient kernel on its own (full mode only).
    if !opts.smoke {
        let mut rng = Rng::new(3);
        let data = Dataset::synthetic(4096, 1000, 0.1, &mut rng);
        let w = vec![0.1f32; 1000];
        let mut model = LinearModel::new(1000);
        let mut seed = 0u64;
        let r = bench(
            "minibatch_grad d=1000 b=32 (pure rust)",
            Duration::from_millis(500),
            || {
                seed += 1;
                std::hint::black_box(model.minibatch_grad(&data, &w, seed, 32));
            },
        );
        suite.record("minibatch_grad_d1000_b32", &[("per_sec", r.per_sec())]);
    }

    // Persist machine-readable results.
    let json_path = from_workspace(&opts.json);
    suite.write(&json_path).expect("writing bench JSON");
    println!("written: {}", json_path.display());

    // Regression gate against a checked-in baseline.
    if let Some(check) = &opts.check {
        // Self-relative floor first: both schedulers ran on the same
        // hardware in this very process, so this gate needs no committed
        // numbers and is armed everywhere — the calendar queue earning
        // its keep is a ratio, not an absolute.
        println!(
            "gate calendar/heap speedup: {calendar_speedup:.2}x (floor 0.70x)"
        );
        if calendar_speedup < 0.70 {
            eprintln!(
                "calendar-queue scheduler fell to {calendar_speedup:.2}x of \
                 the heap oracle (floor 0.70x) — scheduler perf regression"
            );
            std::process::exit(1);
        }
        // Hardware-independent like the speedup ratio: virtual nodes must
        // cut the per-shard push-traffic imbalance at least 3x vs single
        // -position placement (the PR 6 acceptance bar).
        println!(
            "gate vnode balance improvement: {vnode_improvement:.2}x (floor 3.00x)"
        );
        if vnode_improvement < 3.0 {
            eprintln!(
                "vnode placement only improved push-traffic balance \
                 {vnode_improvement:.2}x (floor 3.0x) — placement regression"
            );
            std::process::exit(1);
        }
        // Also a ratio: top-k 64 of d=1024 must keep the wire at least
        // 4x lighter per update than dense payloads (the PR's
        // approximate-communication acceptance bar).
        println!(
            "gate compressed payload bytes/update: {compress_ratio:.2}x \
             (floor 4.00x)"
        );
        if compress_ratio < 4.0 {
            eprintln!(
                "top-k payloads only {compress_ratio:.2}x smaller than dense \
                 (floor 4.0x) — delta codec regression"
            );
            std::process::exit(1);
        }
        let base_path = from_workspace(check);
        let base = BenchSuite::load(&base_path).expect("loading baseline");
        let mut failures = Vec::new();
        let mut compared = 0;
        for name in base.benches() {
            let Some(want) = base.metric(name, "events_per_sec") else {
                println!("baseline '{name}': no events_per_sec (bootstrap) — skipped");
                continue;
            };
            let Some(got) = suite.metric(name, "events_per_sec") else {
                println!("baseline '{name}': not measured in this mode — skipped");
                continue;
            };
            compared += 1;
            let floor = want * (1.0 - opts.tol);
            let verdict = if got < floor { "REGRESSED" } else { "ok" };
            println!(
                "gate {name}: {:.2}M ev/s vs baseline {:.2}M (floor {:.2}M) {verdict}",
                got / 1e6,
                want / 1e6,
                floor / 1e6
            );
            if got < floor {
                failures.push(name.to_string());
            }
        }
        if !failures.is_empty() {
            eprintln!(
                "events/s regressed >{:.0}% on: {}",
                opts.tol * 100.0,
                failures.join(", ")
            );
            std::process::exit(1);
        }
        println!("regression gate passed ({compared} benches compared)");
    }
}
