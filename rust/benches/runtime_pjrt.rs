//! PJRT runtime benches: artifact compile latency, per-call execute
//! latency for the Pallas-kernel linear artifacts, and fused transformer
//! step throughput — the L1/L2-via-L3 numbers in EXPERIMENTS.md §Perf.
//!
//! Requires `make artifacts`.

use std::time::{Duration, Instant};

use actor_psp::runtime::{Manifest, Runtime, Tensor};
use actor_psp::train::{Corpus, TransformerTrainer};
use actor_psp::util::bench::bench;
use actor_psp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    if !cfg!(feature = "pjrt") {
        eprintln!("built without the `pjrt` feature — nothing to bench");
        return Ok(());
    }
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    println!("PJRT runtime benches (CPU plugin)");
    println!("{}", "-".repeat(110));

    // Compile latency (cold) per artifact.
    let rt = Runtime::new()?;
    for name in ["linear_grad_n128_d100", "linear_step_n32_d1000", "tf_tiny_step"] {
        let t0 = Instant::now();
        rt.prepare(name)?;
        println!(
            "{:<44} {:>12}        once  {:.3}s (compile)",
            name,
            "",
            t0.elapsed().as_secs_f64()
        );
    }

    // Execute latency: the paper-shaped linear gradient and fused step.
    let mut rng = Rng::new(5);
    let budget = Duration::from_secs(2);
    {
        let (n, d) = (128usize, 100usize);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        bench("execute linear_grad n=128 d=100", budget, || {
            std::hint::black_box(
                rt.execute(
                    "linear_grad_n128_d100",
                    &[
                        Tensor::F32(x.clone()),
                        Tensor::F32(w.clone()),
                        Tensor::F32(y.clone()),
                    ],
                )
                .unwrap(),
            );
        });
    }
    {
        let (n, d) = (32usize, 1000usize);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = vec![0.0; d];
        let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        bench("execute linear_step n=32 d=1000 (paper)", budget, || {
            std::hint::black_box(
                rt.execute(
                    "linear_step_n32_d1000",
                    &[
                        Tensor::F32(x.clone()),
                        Tensor::F32(w.clone()),
                        Tensor::F32(y.clone()),
                        Tensor::F32(vec![0.005]),
                    ],
                )
                .unwrap(),
            );
        });
    }

    // Fused transformer train step throughput (the e2e driver's hot path).
    let rt2 = Runtime::new()?;
    let mut trainer = TransformerTrainer::new(rt2, "tiny", 1)?;
    let corpus = Corpus::synthetic(1 << 14, trainer.meta.vocab, 9);
    let mut brng = Rng::new(11);
    let batch = corpus.next_batch(trainer.meta.batch, trainer.meta.seq, &mut brng);
    let t0 = Instant::now();
    let mut steps = 0u32;
    while t0.elapsed() < Duration::from_secs(5) {
        trainer.train_step(&batch, 0.05)?;
        steps += 1;
    }
    let sps = steps as f64 / t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {:>12} steps        {:.2} steps/s ({} params, fused fwd+bwd+sgd)",
        "tf_tiny_step throughput", steps, sps, trainer.meta.param_count
    );
    Ok(())
}
