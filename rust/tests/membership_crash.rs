//! Crash-fault membership correctness: the properties that turn a
//! crash-stop from "hope the timeout fires" into a structural guarantee.
//!
//! 1. **Exactly-once under crash-stop + repair** (property test): a
//!    deterministic round-based harness drives the same [`GossipNode`] +
//!    [`Membership`] state machines the threaded engine uses — per-node
//!    overlay views, shared heartbeat table, suspect/confirm timers,
//!    custody re-announcement and successor store re-send — and asserts
//!    that after one crash-stop (messages into the dead node are *lost*,
//!    not rerouted) every live peer still applies every rumor of every
//!    live origin, plus every rumor the dead origin ever announced,
//!    exactly once; and that every survivor learns the custodian's exact
//!    count (the drain's termination evidence). Across fanout ∈ {1,2,4},
//!    TTLs including 0, and crash rounds from "before the first
//!    origination" to "long after quiescence".
//! 2. **Threaded engine, crash mid-run**: with one peer crash-stopped
//!    (no `Done`, no handoff), all survivors terminate without reaching
//!    `drain_timeout`, report zero missing/dropped deltas, and — with
//!    exactly-representable dyadic gradients — end bit-identical to the
//!    analytic sum of every announced delta (survivors' full runs + the
//!    victim's pre-crash steps). Bitwise equality *is* the exactly-once
//!    proof: a lost or doubled delta shifts the sum.
//! 3. **The counterfactual**: the same crash with the membership plane
//!    disabled stalls every survivor to `drain_timeout` — the failure
//!    mode this subsystem exists to remove.

use std::sync::Arc;
use std::time::Duration;

use actor_psp::barrier::Method;
use actor_psp::engine::delta::DeltaPayload;
use actor_psp::engine::gossip::{GossipConfig, GossipNode, Rumor};
use actor_psp::engine::membership::{Membership, MembershipConfig};
use actor_psp::engine::p2p::{self, Departure, Dissemination, P2pConfig};
use actor_psp::engine::GradFn;
use actor_psp::overlay::Ring;
use actor_psp::testing::property;
use actor_psp::util::rng::Rng;

// ---------------------------------------------------------------------
// Synchronous round-based harness (crash-stop + membership plane)
// ---------------------------------------------------------------------

struct CrashOutcome {
    /// applies[node][origin][seq] = times `node` applied that rumor.
    applies: Vec<Vec<Vec<u32>>>,
    /// Rumors each origin actually originated (the victim stops early).
    originated: Vec<u32>,
    /// The victim's announced-count as learned by each node (custodian
    /// count or the Repair broadcast) — the drain's termination evidence.
    announced: Vec<Option<u32>>,
    live: Vec<bool>,
    rounds: usize,
    physical_msgs: u64,
}

/// Drive n nodes for `origin_rounds` rounds of one-origination-per-node,
/// with a crash-stop at `(victim, round)`, then run to quiescence under
/// the membership plane. Per round: crash → originate → heartbeat →
/// flush (via each node's own overlay view) → deliver (messages to the
/// dead node are LOST — no transport rerouting; repair is the membership
/// plane's job) → detect/evict/repair. The loop ends only once every
/// live observer has confirmed the death and the wires are quiet — the
/// harness analogue of "all survivors drain without the timeout".
fn run_crash_rounds(
    n: usize,
    cfg: &GossipConfig,
    origin_rounds: usize,
    crash: (usize, usize),
    mem: &MembershipConfig,
    seed: u64,
) -> CrashOutcome {
    let launch = Ring::with_nodes(n, seed);
    let mut rng = Rng::new(seed ^ 0xD15E);
    let mut nodes: Vec<GossipNode> =
        (0..n).map(|i| GossipNode::with_handoff_store(i, n)).collect();
    let mut members: Vec<Membership> = (0..n)
        .map(|i| Membership::new(i, launch.clone(), 0, mem.clone()))
        .collect();
    let (victim, crash_round) = crash;
    let mut live = vec![true; n];
    let mut beats = vec![0u64; n];
    let mut applies = vec![vec![vec![0u32; origin_rounds]; n]; n];
    let mut originated = vec![0u32; n];
    let mut announced: Vec<Option<u32>> = vec![None; n];
    let mut in_flight: Vec<(usize, Vec<Rumor>)> = Vec::new();
    // Custody announcements queued for next-round delivery: (dest, count, store).
    let mut repairs: Vec<(usize, u32, Vec<Rumor>)> = Vec::new();
    let mut physical_msgs = 0u64;
    let mut round = 0usize;
    loop {
        // crash phase: the victim goes silent at the top of its round
        if round == crash_round && live[victim] {
            live[victim] = false;
        }
        // originate phase
        if round < origin_rounds {
            for (i, node) in nodes.iter_mut().enumerate() {
                if live[i] {
                    let payload = DeltaPayload::dense(vec![i as f32 + 1.0]);
                    let seq = node.originate(payload, cfg);
                    applies[i][i][seq as usize] += 1; // applied locally
                    originated[i] += 1;
                }
            }
        }
        // heartbeat phase (the shared liveness table)
        for (i, b) in beats.iter_mut().enumerate() {
            if live[i] {
                *b += 1;
            }
        }
        // flush phase: routed by each node's OWN membership view, so an
        // evicted victim stops receiving chain traffic
        for i in 0..n {
            if live[i] {
                for (dest, batch) in nodes[i].flush(cfg, members[i].ring(), &mut rng) {
                    physical_msgs += 1;
                    in_flight.push((dest, batch));
                }
            }
        }
        // quiescence check — after flushing (empty wires here mean empty
        // relay buffers everywhere) and only once every live observer
        // holds the confirmation
        let victim_settled = !live[victim]
            && (0..n)
                .filter(|&i| live[i])
                .all(|i| members[i].detector.is_dead(victim));
        let quiet = in_flight.is_empty() && repairs.is_empty();
        if quiet && round >= origin_rounds && victim_settled {
            break;
        }
        // delivery phase: messages into the dead node are lost
        let batches = std::mem::take(&mut in_flight);
        for (dest, batch) in batches {
            if !live[dest] {
                continue;
            }
            nodes[dest].receive(batch, |r| {
                applies[dest][r.origin as usize][r.seq as usize] += 1;
            });
        }
        let pending = std::mem::take(&mut repairs);
        for (dest, count, store) in pending {
            if !live[dest] {
                continue;
            }
            announced[dest] = Some(announced[dest].map_or(count, |c| c.max(count)));
            nodes[dest].receive(store, |r| {
                applies[dest][r.origin as usize][r.seq as usize] += 1;
            });
        }
        // detection phase: every live observer runs its suspect/confirm
        // timers over the shared beat table
        let now = (round + 1) as u64;
        for i in 0..n {
            if !live[i] {
                continue;
            }
            let obs = members[i].detector.observe(now, |j| beats[j], |_| false);
            for d in obs.dead {
                let out = members[i].evict(d).expect("confirmations are reported once");
                if out.custodian {
                    // Custody repair: re-announce the dead origin's exact
                    // count and re-inject its rumors from our store.
                    let count = nodes[i].applied_count(d as u32);
                    announced[i] = Some(announced[i].map_or(count, |c| c.max(count)));
                    let store = nodes[i].rumors_of(d as u32);
                    for j in 0..n {
                        if j != i && live[j] {
                            physical_msgs += 1;
                            repairs.push((j, count, store.clone()));
                        }
                    }
                }
                if let Some(succ) = out.lost_successor {
                    // Successor repair: re-send our full store across the
                    // gap the dead node left in the chain.
                    let store = nodes[i].handoff_rumors();
                    if !store.is_empty() {
                        physical_msgs += 1;
                        in_flight.push((succ, store));
                    }
                }
            }
        }
        round += 1;
        let bound = 10 * n
            + 10 * origin_rounds
            + crash_round
            + (mem.suspect_after + mem.confirm_after) as usize
            + 100;
        assert!(
            round < bound,
            "crash repair did not quiesce after {round} rounds \
             (n={n} victim={victim} crash_round={crash_round})"
        );
    }
    CrashOutcome { applies, originated, announced, live, rounds: round, physical_msgs }
}

#[test]
fn prop_crash_stop_repairs_to_exactly_once_delivery() {
    property("crash-stop membership repair exactly-once", 40, |g| {
        let n = g.usize_in(3, 24);
        let fanout = *g.choose(&[1usize, 2, 4]);
        // TTL 0 included on purpose: after the gap is repaired,
        // completeness must come from the successor chain alone.
        let ttl = g.usize_in(0, 6) as u32;
        let cfg = GossipConfig { fanout, flush_every: 1, ttl };
        let origin_rounds = g.usize_in(1, 3);
        let victim = g.usize_in(0, n - 1);
        // From "before anything was announced" to "long after quiescence".
        let crash_round = g.usize_in(0, 2 * n);
        let mem = MembershipConfig {
            suspect_after: g.u64_in(1, 3),
            confirm_after: g.u64_in(1, 3),
        };
        let d = run_crash_rounds(
            n, &cfg, origin_rounds, (victim, crash_round), &mem, g.seed(),
        );
        assert!(!d.live[victim]);
        // Every rumor every origin *announced* (and the victim announced
        // everything it originated — it flushed each round it lived)
        // lands on every live node exactly once.
        for (node, per_origin) in d.applies.iter().enumerate() {
            if !d.live[node] {
                continue;
            }
            for (origin, seqs) in per_origin.iter().enumerate() {
                for (seq, &count) in
                    seqs.iter().take(d.originated[origin] as usize).enumerate()
                {
                    assert_eq!(
                        count, 1,
                        "node {node} applied rumor ({origin}, {seq}) {count} \
                         times (n={n} fanout={fanout} ttl={ttl} \
                         rounds={origin_rounds} victim={victim} \
                         crash_round={crash_round} mem={mem:?})"
                    );
                }
            }
        }
        // Every survivor holds the custodian's exact count for the dead
        // origin — the evidence the engine drain terminates on.
        for i in 0..n {
            if d.live[i] {
                assert_eq!(
                    d.announced[i],
                    Some(d.originated[victim]),
                    "node {i} never learned the dead origin's count \
                     (n={n} victim={victim} crash_round={crash_round})"
                );
            }
        }
        assert!(d.physical_msgs > 0 || n == 1);
        assert!(d.rounds > 0);
    });
}

// ---------------------------------------------------------------------
// Threaded engine: crash-stop mid-run, exact arithmetic
// ---------------------------------------------------------------------

const DIM: usize = 16;
const WORKER_SEED_SALT: u64 = 0xABCD_EF01;

/// Gradients that are (a) independent of the model, so arrival order
/// cannot change later gradients, and (b) small dyadic rationals, so f32
/// accumulation is exact and therefore order-independent.
fn dyadic_grad() -> GradFn {
    Arc::new(|_w, seed| {
        (0..DIM)
            .map(|j| (((seed ^ j as u64) % 15) as f32 - 7.0) * 0.25)
            .collect()
    })
}

/// The exact model every survivor must reach: init + Σ of every
/// *announced* delta — survivors contribute all their steps, the crash
/// victim only the steps it completed (and flushed) before going silent.
fn analytic_model_with_crash(cfg: &P2pConfig, victim: usize, victim_steps: u64) -> Vec<f32> {
    let mut w = vec![0.0f32; cfg.dim];
    for i in 0..cfg.n_workers {
        let mut grad_rng =
            Rng::new(cfg.seed ^ (i as u64).wrapping_mul(WORKER_SEED_SALT));
        let steps = if i == victim { victim_steps } else { cfg.steps_per_worker };
        for _ in 0..steps {
            let seed = grad_rng.next_u64();
            for (j, wj) in w.iter_mut().enumerate() {
                let g = (((seed ^ j as u64) % 15) as f32 - 7.0) * 0.25;
                *wj += -cfg.lr * g;
            }
        }
    }
    w
}

fn crash_cfg(fanout: usize, method: Method) -> P2pConfig {
    P2pConfig {
        n_workers: 6,
        steps_per_worker: 5,
        method,
        lr: 0.5, // power of two: deltas stay exactly representable
        dim: DIM,
        seed: 97,
        dissemination: Dissemination::Gossip(GossipConfig {
            fanout,
            flush_every: 1,
            ttl: 4,
        }),
        churn: vec![Departure { worker: 3, at_step: 2, graceful: false }],
        ..P2pConfig::default()
    }
}

#[test]
fn crash_stop_survivors_drain_fast_and_lose_nothing_across_fanouts() {
    for fanout in [1usize, 2, 4] {
        let cfg = crash_cfg(fanout, Method::Asp);
        let expect = analytic_model_with_crash(&cfg, 3, 2);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let r = p2p::run(&cfg, vec![0.0; DIM], dyadic_grad());
        assert_eq!(r.departed, vec![3], "fanout={fanout}");
        assert_eq!(r.steps[3], 2, "victim stopped at its crash step");
        // The property: survivors terminate WITHOUT the drain timeout...
        assert!(
            r.wall_secs < cfg.drain_timeout.as_secs_f64() / 2.0,
            "fanout={fanout}: drain took {}s — that is the timeout stall \
             the membership plane must prevent",
            r.wall_secs
        );
        // ...and every announced rumor (live origins' 5 each + the
        // victim's 2) is applied exactly once everywhere: bitwise
        // equality with the analytic sum proves no loss and no double.
        assert_eq!(r.dropped_deltas, 0, "fanout={fanout}");
        assert_eq!(r.missing_rumors, 0, "fanout={fanout}");
        assert_eq!(r.discarded_msgs, 0, "fanout={fanout}");
        for (i, rep) in r.replicas.iter().enumerate() {
            if i == 3 {
                continue; // the victim's replica stops mid-run
            }
            assert_eq!(
                bits(rep),
                bits(&expect),
                "fanout={fanout}: survivor {i} lost or doubled a delta"
            );
        }
        // Failure detection actually ran and repaired.
        assert!(r.confirmed_dead >= 1, "fanout={fanout}: no confirmation");
        assert!(r.repair_msgs >= 1, "fanout={fanout}: no repair traffic");
        for j in [0usize, 1, 2, 4, 5] {
            assert_eq!(r.steps[j], 5, "fanout={fanout}: survivor {j} stalled");
        }
    }
}

#[test]
fn crash_stop_under_sampled_barrier_unblocks_after_eviction() {
    // pSSP survivors eventually sample the frozen victim and block; the
    // confirm + evict must unblock them (the dead node disappears from
    // the overlay view), and the run still loses nothing.
    let cfg = crash_cfg(2, Method::Pssp { sample: 2, staleness: 2 });
    let expect = analytic_model_with_crash(&cfg, 3, 2);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let r = p2p::run(&cfg, vec![0.0; DIM], dyadic_grad());
    assert_eq!(r.departed, vec![3]);
    for j in [0usize, 1, 2, 4, 5] {
        assert_eq!(r.steps[j], 5, "survivor {j} never got past the dead sample");
    }
    assert!(r.wall_secs < cfg.drain_timeout.as_secs_f64() / 2.0);
    assert_eq!(r.dropped_deltas, 0);
    assert_eq!(r.missing_rumors, 0);
    for (i, rep) in r.replicas.iter().enumerate() {
        if i != 3 {
            assert_eq!(bits(rep), bits(&expect), "survivor {i} diverged");
        }
    }
}

#[test]
fn without_membership_a_crash_stalls_survivors_to_drain_timeout() {
    // The counterfactual this subsystem exists for: same crash, detector
    // off — every survivor camps on drain_timeout waiting for a Done
    // that never comes. (Timeout shrunk so the test stays fast; the
    // victim's announced rumors all delivered pre-crash, so the cost is
    // pure stall, not loss.)
    let cfg = P2pConfig {
        membership: None,
        drain_timeout: Duration::from_millis(700),
        ..crash_cfg(2, Method::Asp)
    };
    let r = p2p::run(&cfg, vec![0.0; DIM], dyadic_grad());
    assert_eq!(r.departed, vec![3]);
    assert!(
        r.wall_secs >= 0.7,
        "without membership the drain should stall to the timeout, \
         finished in {}s",
        r.wall_secs
    );
    assert_eq!(r.confirmed_dead, 0);
    assert_eq!(r.repair_msgs, 0);
    // No busy-wait while camped on the deadline: the drain's blocking
    // recv is clamped to a ≥1ms poll floor, so each of the 5 stalled
    // survivors pays at most ~timeout/1ms iterations (plus one per
    // message ingested). An unclamped recv_timeout(≈0) hot-spins through
    // millions of iterations in the same 700ms.
    assert!(r.drain_polls > 0, "drain ran but recorded no poll iterations");
    assert!(
        r.drain_polls < 20_000,
        "drain busy-waited: {} poll iterations across survivors for a \
         700ms timeout",
        r.drain_polls
    );
}
