//! Cross-transport equivalence for the deployed node runtime.
//!
//! The same 3-node pSSP workload runs twice — once over in-process
//! channels, once over real TCP sockets (each node's transport bound to
//! 127.0.0.1:0) — and must produce the *same dissemination outcome*:
//! identical per-origin applied-rumor counts on every node, zero
//! dropped deltas, zero missing rumors. Models are not compared: f32
//! accumulation order legitimately differs with arrival order; what the
//! deployment plane owes the engine is that every announced rumor is
//! applied exactly once, and that is transport-independent.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use actor_psp::barrier::Method;
use actor_psp::engine::delta::CompressConfig;
use actor_psp::engine::gossip::GossipConfig;
use actor_psp::engine::node::{run_node, NodeOutcome, Workload};
use actor_psp::engine::transport::{ChannelTransport, TcpTransport};
use actor_psp::engine::GradFn;
use actor_psp::util::rng::Rng;

fn workload(steps: u64, flush_every: u64, method: Method) -> Workload {
    Workload {
        n: 3,
        steps,
        dim: 16,
        lr: 0.1,
        seed: 42,
        method,
        gossip: GossipConfig { fanout: 2, flush_every, ttl: 4 },
        drain_timeout: Duration::from_secs(20),
        membership: None,
        compress: CompressConfig::default(),
    }
}

/// Gradients derived only from the step seed, so a node's originations
/// are identical across transports by construction.
fn seed_only_grad() -> GradFn {
    Arc::new(|w: &[f32], seed: u64| {
        let mut rng = Rng::new(seed);
        (0..w.len()).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    })
}

fn run_channel_cluster(wl: &Workload) -> Vec<NodeOutcome> {
    let transports = ChannelTransport::cluster(wl.n);
    let mut handles = Vec::new();
    for (id, mut tr) in transports.into_iter().enumerate() {
        let cfg = wl.node_config(id);
        let grad = seed_only_grad();
        handles.push(std::thread::spawn(move || run_node(&cfg, &mut tr, grad, None)));
    }
    handles.into_iter().map(|h| h.join().expect("channel node")).collect()
}

fn run_tcp_cluster(wl: &Workload) -> Vec<NodeOutcome> {
    // Bind every listener first so the full roster is known before any
    // node starts (the CLI learns it from the bootstrap handshake; the
    // test shortcuts to the same roster directly).
    let listeners: Vec<TcpListener> = (0..wl.n)
        .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind"))
        .collect();
    let roster: Vec<(usize, String)> = listeners
        .iter()
        .enumerate()
        .map(|(id, l)| (id, l.local_addr().unwrap().to_string()))
        .collect();
    let mut handles = Vec::new();
    for (id, listener) in listeners.into_iter().enumerate() {
        let cfg = wl.node_config(id);
        let roster = roster.clone();
        let grad = seed_only_grad();
        handles.push(std::thread::spawn(move || {
            let mut tr = TcpTransport::with_listener(id, cfg.n, listener).expect("transport");
            tr.connect_peers(&roster);
            let out = run_node(&cfg, &mut tr, grad, None);
            assert!(tr.bytes_out() > 0, "node {id} never wrote to the wire");
            out
        }));
    }
    handles.into_iter().map(|h| h.join().expect("tcp node")).collect()
}

fn assert_equivalent(wl: &Workload, channel: &[NodeOutcome], tcp: &[NodeOutcome]) {
    let originations = wl.steps.div_ceil(wl.gossip.flush_every.max(1));
    for id in 0..wl.n {
        let (c, t) = (&channel[id], &tcp[id]);
        assert_eq!(c.report.dropped_deltas, 0, "channel node {id} dropped");
        assert_eq!(t.report.dropped_deltas, 0, "tcp node {id} dropped");
        assert_eq!(c.report.missing_rumors, 0, "channel node {id} missing");
        assert_eq!(t.report.missing_rumors, 0, "tcp node {id} missing");
        assert_eq!(
            c.applied_of, t.applied_of,
            "node {id}: per-origin applied counts diverge across transports"
        );
        assert_eq!(
            t.applied_of,
            vec![originations as u32; wl.n],
            "node {id}: not every origination was applied exactly once"
        );
        // Every node completed its own steps (the step table may lag
        // for *other* nodes — Done, not Step, is the final word).
        assert_eq!(t.report.steps[id], wl.steps, "tcp node {id} steps");
        assert_eq!(c.report.steps[id], wl.steps, "channel node {id} steps");
    }
}

#[test]
fn tcp_cluster_matches_channel_cluster_under_pssp() {
    let wl = workload(15, 1, Method::Pssp { sample: 2, staleness: 2 });
    let channel = run_channel_cluster(&wl);
    let tcp = run_tcp_cluster(&wl);
    assert_equivalent(&wl, &channel, &tcp);
}

#[test]
fn tcp_cluster_matches_channel_cluster_with_batched_flushes() {
    // flush_every=4 over 10 steps -> originations at 4, 8, 10: the
    // batching path (rumor per 4 compacted deltas) must also agree.
    let wl = workload(10, 4, Method::Asp);
    let channel = run_channel_cluster(&wl);
    let tcp = run_tcp_cluster(&wl);
    assert_equivalent(&wl, &channel, &tcp);
}
