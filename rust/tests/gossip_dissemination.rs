//! Gossip-plane correctness: the properties that make the O(n·fanout)
//! model plane *trustworthy*, not just cheap.
//!
//! 1. **Exactly-once, no-loss dissemination** (property test): a
//!    deterministic round-based harness drives the same [`GossipNode`]
//!    state machine the threaded engine uses, and asserts every rumor of
//!    every origin reaches every live peer exactly once — across
//!    fanout ∈ {1, 2, 4}, arbitrary TTLs (the successor chain must carry
//!    completeness even with zero shortcut budget) and one mid-run
//!    graceful `leave()`.
//! 2. **Full-mesh equivalence** (threaded engine): with exactly
//!    representable dyadic gradients, every worker replica must end
//!    bit-identical to the analytic sum of all deltas — under the legacy
//!    mesh AND under gossip — because f32 addition of small dyadics is
//!    exact (hence order-independent) and every delta is applied exactly
//!    once.
//! 3. **The acceptance bar**: at n = 256 the gossip plane must move the
//!    same deltas with ≥ 5× fewer physical update messages per step than
//!    the full mesh.

use std::collections::BTreeMap;
use std::sync::Arc;

use actor_psp::barrier::Method;
use actor_psp::engine::delta::DeltaPayload;
use actor_psp::engine::gossip::{GossipConfig, GossipNode, Rumor};
use actor_psp::engine::p2p::{self, Dissemination, P2pConfig};
use actor_psp::engine::GradFn;
use actor_psp::overlay::Ring;
use actor_psp::testing::property;
use actor_psp::util::rng::Rng;

// ---------------------------------------------------------------------
// Synchronous round-based harness
// ---------------------------------------------------------------------

struct RunOutcome {
    /// applies[node][origin][seq] = times `node` applied that rumor.
    applies: Vec<Vec<Vec<u32>>>,
    /// Rumors each origin actually originated (victims stop early).
    originated: Vec<u32>,
    live: Vec<bool>,
    rounds: usize,
    physical_msgs: u64,
}

/// Drive n nodes for `origin_rounds` rounds of one-origination-per-node,
/// then run to quiescence. Per round: originate → flush (collect wire
/// batches) → deliver → churn. `leave` = (node, round): a graceful
/// departure — the node flushes its buffer and hands its rumor store to
/// its ring successor. The transport is reliable and chord-like: batches
/// addressed to a departed node are re-routed to the successor of its
/// old ring position (receivers dedup, so re-routing can never
/// double-apply).
fn run_rounds(
    n: usize,
    cfg: &GossipConfig,
    origin_rounds: usize,
    leave: Option<(usize, usize)>,
    seed: u64,
) -> RunOutcome {
    let mut ring = Ring::with_nodes(n, seed);
    let mut rng = Rng::new(seed ^ 0xD15E);
    let mut nodes: Vec<GossipNode> =
        (0..n).map(|i| GossipNode::with_handoff_store(i, n)).collect();
    let mut live = vec![true; n];
    let mut applies = vec![vec![vec![0u32; origin_rounds]; n]; n];
    let mut originated = vec![0u32; n];
    // departed node -> its old ring id (for transport re-routing)
    let mut departed: BTreeMap<usize, u64> = BTreeMap::new();

    let mut in_flight: Vec<(usize, Vec<Rumor>)> = Vec::new();
    let mut physical_msgs = 0u64;
    let mut round = 0usize;
    loop {
        // originate phase: every live node emits one rumor per round
        if round < origin_rounds {
            for (i, node) in nodes.iter_mut().enumerate() {
                if live[i] {
                    let payload = DeltaPayload::dense(vec![i as f32 + 1.0]);
                    let seq = node.originate(payload, cfg);
                    applies[i][i][seq as usize] += 1; // applied locally
                    originated[i] += 1;
                }
            }
        }
        // flush phase: fresh buffers go on the wire
        for (i, node) in nodes.iter_mut().enumerate() {
            if live[i] {
                for (dest, batch) in node.flush(cfg, &ring, &mut rng) {
                    physical_msgs += 1;
                    in_flight.push((dest, batch));
                }
            }
        }
        if in_flight.is_empty() && round >= origin_rounds {
            break;
        }
        // delivery phase
        let batches = std::mem::take(&mut in_flight);
        for (dest, batch) in batches {
            // chord transport: departed owner → deliver to the successor
            // of its old position (skipping further departed hops)
            let mut dest = dest;
            while !live[dest] {
                let old_id = departed[&dest];
                match ring.successor(old_id.wrapping_add(1)) {
                    Some((_, next)) => dest = next,
                    None => break, // ring empty; drop
                }
            }
            if !live[dest] {
                continue;
            }
            let d = dest;
            nodes[d].receive(batch, |r| {
                applies[d][r.origin as usize][r.seq as usize] += 1;
            });
        }
        // churn phase: one graceful leave at the configured round
        if let Some((victim, at)) = leave {
            if round == at && live[victim] {
                let old_id = ring.ring_id_of(victim).unwrap();
                // flush what the victim still owes the network
                for (dest, batch) in nodes[victim].flush(cfg, &ring, &mut rng) {
                    physical_msgs += 1;
                    in_flight.push((dest, batch));
                }
                // hand the full store to the successor (post-leave ring)
                ring.leave(victim);
                live[victim] = false;
                departed.insert(victim, old_id);
                if let Some((_, succ)) = ring.successor(old_id.wrapping_add(1)) {
                    let store = nodes[victim].handoff_rumors();
                    if !store.is_empty() {
                        physical_msgs += 1;
                        in_flight.push((succ, store));
                    }
                }
            }
        }
        round += 1;
        assert!(
            round < 10 * n + 10 * origin_rounds + 100,
            "dissemination did not quiesce after {round} rounds (n={n})"
        );
    }
    RunOutcome { applies, originated, live, rounds: round, physical_msgs }
}

#[test]
fn prop_gossip_delivers_exactly_once_to_every_live_peer() {
    property("gossip exactly-once dissemination", 40, |g| {
        let n = g.usize_in(3, 24);
        let fanout = *g.choose(&[1usize, 2, 4]);
        // TTL 0 included on purpose: completeness must come from the
        // successor chain alone, not from lucky shortcut spread.
        let ttl = g.usize_in(0, 6) as u32;
        let cfg = GossipConfig { fanout, flush_every: 1, ttl };
        let origin_rounds = g.usize_in(1, 3);
        let victim = g.usize_in(0, n - 1);
        let at = g.usize_in(0, 2 * n);
        let leave = g.bool().then_some((victim, at));
        let d = run_rounds(n, &cfg, origin_rounds, leave, g.seed());
        for (node, per_origin) in d.applies.iter().enumerate() {
            if !d.live[node] {
                continue;
            }
            for (origin, seqs) in per_origin.iter().enumerate() {
                for (seq, &count) in
                    seqs.iter().take(d.originated[origin] as usize).enumerate()
                {
                    assert_eq!(
                        count, 1,
                        "node {node} applied rumor ({origin}, {seq}) {count} \
                         times (n={n} fanout={fanout} ttl={ttl} \
                         rounds={origin_rounds} leave={leave:?})"
                    );
                }
            }
        }
    });
}

#[test]
fn steady_state_gossip_cuts_physical_messages_5x_vs_mesh() {
    // 8 rounds of one-delta-per-node at n=48: the mesh would ship every
    // delta to every peer as its own message; partner-per-tick batching
    // has to do the same job in ≥5x fewer physical messages while
    // converging within O(rounds + log n) of the last origination.
    let n = 48;
    let rounds = 8;
    let cfg = GossipConfig { fanout: 2, flush_every: 1, ttl: 5 };
    let d = run_rounds(n, &cfg, rounds, None, 7);
    let mesh = (n * (n - 1) * rounds) as u64;
    assert!(
        d.physical_msgs * 5 <= mesh,
        "gossip spent {} physical messages; mesh would spend {mesh}",
        d.physical_msgs
    );
    assert!(
        d.rounds <= rounds + n,
        "dissemination tail too long: {} rounds",
        d.rounds
    );
    // completeness at full scale, exactly once
    for per_origin in &d.applies {
        for seqs in per_origin {
            assert!(seqs.iter().all(|&c| c == 1));
        }
    }
}

// ---------------------------------------------------------------------
// Threaded engine: full-mesh equivalence with exact arithmetic
// ---------------------------------------------------------------------

const DIM: usize = 16;
const WORKER_SEED_SALT: u64 = 0xABCD_EF01;

/// Gradients that are (a) independent of the model, so arrival order
/// cannot change later gradients, and (b) small dyadic rationals, so f32
/// accumulation is exact and therefore order-independent.
fn dyadic_grad() -> GradFn {
    Arc::new(|_w, seed| {
        (0..DIM)
            .map(|j| (((seed ^ j as u64) % 15) as f32 - 7.0) * 0.25)
            .collect()
    })
}

/// The exact model every replica must reach: init + Σ all deltas. The
/// engine derives each step's gradient seed as a pure function of
/// (engine seed, worker, step) — replicated here.
fn analytic_model(cfg: &P2pConfig) -> Vec<f32> {
    let mut w = vec![0.0f32; cfg.dim];
    for i in 0..cfg.n_workers {
        let mut grad_rng =
            Rng::new(cfg.seed ^ (i as u64).wrapping_mul(WORKER_SEED_SALT));
        for _ in 0..cfg.steps_per_worker {
            let seed = grad_rng.next_u64();
            for (j, wj) in w.iter_mut().enumerate() {
                let g = (((seed ^ j as u64) % 15) as f32 - 7.0) * 0.25;
                *wj += -cfg.lr * g;
            }
        }
    }
    w
}

fn equivalence_cfg(dissemination: Dissemination) -> P2pConfig {
    P2pConfig {
        n_workers: 5,
        steps_per_worker: 8,
        method: Method::Asp,
        lr: 0.5, // power of two: deltas stay exactly representable
        dim: DIM,
        seed: 90,
        dissemination,
        ..P2pConfig::default()
    }
}

#[test]
fn gossip_matches_full_mesh_and_analytic_sum_bitwise() {
    let mesh_cfg = equivalence_cfg(Dissemination::FullMesh);
    let expect = analytic_model(&mesh_cfg);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

    let mesh = p2p::run(&mesh_cfg, vec![0.0; DIM], dyadic_grad());
    assert_eq!(mesh.replicas.len(), 5);
    for (i, rep) in mesh.replicas.iter().enumerate() {
        assert_eq!(
            bits(rep),
            bits(&expect),
            "full-mesh replica {i} diverged from the analytic delta sum"
        );
    }

    // Flood-equivalent gossip: fanout = n-1 reaches every peer directly,
    // single-step flush, ttl 0 (no shortcut relays needed). Exactly-once
    // dedup must make the trajectories identical to the mesh.
    let gossip_cfg = equivalence_cfg(Dissemination::Gossip(GossipConfig {
        fanout: 4,
        flush_every: 1,
        ttl: 0,
    }));
    let gossip = p2p::run(&gossip_cfg, vec![0.0; DIM], dyadic_grad());
    assert_eq!(gossip.dropped_deltas, 0);
    for (i, rep) in gossip.replicas.iter().enumerate() {
        assert_eq!(
            bits(rep),
            bits(&expect),
            "gossip replica {i} diverged from the full-mesh trajectory"
        );
    }
    // every origin's every rumor applied exactly once by every peer
    assert_eq!(gossip.applied_rumors, 5 * 8 * 4);
}

#[test]
fn gossip_with_relays_still_applies_every_delta_exactly_once() {
    // Low fanout + TTL: multi-hop relays do the spreading; the per-origin
    // sequence dedup must still land every delta exactly once everywhere.
    let cfg = equivalence_cfg(Dissemination::Gossip(GossipConfig {
        fanout: 1,
        flush_every: 1,
        ttl: 8,
    }));
    let expect = analytic_model(&cfg);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let r = p2p::run(&cfg, vec![0.0; DIM], dyadic_grad());
    assert_eq!(r.dropped_deltas, 0);
    assert_eq!(r.applied_rumors, 5 * 8 * 4);
    for (i, rep) in r.replicas.iter().enumerate() {
        assert_eq!(bits(rep), bits(&expect), "replica {i} missed or doubled a delta");
    }
}

#[test]
fn origin_side_compaction_preserves_the_delta_sum() {
    // flush_every = 4 compacts 8 steps into 2 rumors per origin; the
    // summed payloads must land every worker on the same analytic model.
    let cfg = equivalence_cfg(Dissemination::Gossip(GossipConfig {
        fanout: 4,
        flush_every: 4,
        ttl: 2,
    }));
    let expect = analytic_model(&cfg);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let r = p2p::run(&cfg, vec![0.0; DIM], dyadic_grad());
    assert_eq!(r.dropped_deltas, 0);
    // 2 rumors per origin × 4 receiving peers
    assert_eq!(r.applied_rumors, 5 * 2 * 4);
    for (i, rep) in r.replicas.iter().enumerate() {
        assert_eq!(bits(rep), bits(&expect), "replica {i} lost a compacted delta");
    }
}

// ---------------------------------------------------------------------
// Acceptance: ≥5× fewer update messages than the mesh at n=256
// ---------------------------------------------------------------------

#[test]
fn acceptance_256_workers_gossip_cuts_update_msgs_5x() {
    let mk = |dissemination| P2pConfig {
        n_workers: 256,
        steps_per_worker: 3,
        method: Method::Asp,
        lr: 1e-3,
        dim: 8,
        seed: 11,
        dissemination,
        ..P2pConfig::default()
    };
    let grad: GradFn = Arc::new(|_w, seed| {
        (0..8).map(|j| ((seed >> j) & 1) as f32 * 1e-3).collect()
    });

    let mesh = p2p::run(&mk(Dissemination::FullMesh), vec![0.0; 8], grad.clone());
    assert_eq!(mesh.update_msgs, 256 * 255 * 3);

    let gossip = p2p::run(
        &mk(Dissemination::Gossip(GossipConfig { fanout: 2, flush_every: 1, ttl: 6 })),
        vec![0.0; 8],
        grad,
    );
    let steps: u64 = gossip.steps.iter().sum();
    assert_eq!(steps, 256 * 3);
    assert!(
        gossip.update_msgs * 5 <= mesh.update_msgs,
        "gossip sent {} update msgs vs mesh {} — less than the 5x cut",
        gossip.update_msgs,
        mesh.update_msgs
    );
    // The Done-announced rumor counts make the drain exit exact: no
    // worker leaves while it is owed deltas, so zero drops is a
    // guarantee here, not a timing accident — and every one of the
    // 256·3 rumors lands on all 255 peers exactly once.
    assert_eq!(gossip.dropped_deltas, 0);
    assert_eq!(gossip.applied_rumors, 256 * 3 * 255);
}
