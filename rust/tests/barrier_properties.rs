//! Cross-module property tests: the §6.1 generalisation lattice and the
//! barrier invariants, asserted over whole simulated *trajectories* (not
//! just single decisions).

use actor_psp::barrier::Method;
use actor_psp::sim::{ClusterConfig, Simulator, TimeDist};
use actor_psp::testing::property;

fn cfg(n: usize, seed: u64, duration: f64) -> ClusterConfig {
    ClusterConfig { n_nodes: n, seed, duration, ..ClusterConfig::default() }
}

#[test]
fn prop_ssp_staleness_never_violated_at_horizon() {
    property("SSP spread ≤ θ+1 on trajectories", 25, |g| {
        let n = g.usize_in(2, 80);
        let staleness = g.u64_in(0, 6);
        let seed = g.seed();
        let r = Simulator::new(cfg(n, seed, 15.0), Method::Ssp { staleness }).run();
        let min = *r.final_steps.iter().min().unwrap();
        let max = *r.final_steps.iter().max().unwrap();
        // a worker may be one step past the barrier check (it checks
        // before STARTING a step), hence θ+1
        assert!(
            max - min <= staleness + 1,
            "n={n} θ={staleness}: spread {min}..{max}"
        );
    });
}

#[test]
fn prop_pbsp_full_population_sample_behaves_like_bsp() {
    property("pBSP(n) trajectory ≈ BSP trajectory spread", 10, |g| {
        let n = g.usize_in(2, 40);
        let seed = g.seed();
        let p = Simulator::new(cfg(n, seed, 12.0), Method::Pbsp { sample: n }).run();
        let min = *p.final_steps.iter().min().unwrap();
        let max = *p.final_steps.iter().max().unwrap();
        // full-sample pBSP enforces the BSP invariant exactly
        assert!(max - min <= 1, "pBSP(P) spread {min}..{max}");
    });
}

#[test]
fn prop_progress_monotone_in_staleness() {
    property("mean progress non-decreasing in θ", 8, |g| {
        let n = g.usize_in(10, 60);
        let seed = g.seed();
        let t1 = g.u64_in(0, 3);
        let t2 = t1 + g.u64_in(1, 6);
        let r1 = Simulator::new(cfg(n, seed, 15.0), Method::Ssp { staleness: t1 }).run();
        let r2 = Simulator::new(cfg(n, seed, 15.0), Method::Ssp { staleness: t2 }).run();
        assert!(
            r2.mean_progress() >= r1.mean_progress() * 0.95,
            "θ {t1}->{t2}: progress {} -> {}",
            r1.mean_progress(),
            r2.mean_progress()
        );
    });
}

#[test]
fn prop_asp_progress_dominates_all_methods() {
    property("ASP mean progress is maximal", 8, |g| {
        let n = g.usize_in(10, 60);
        let seed = g.seed();
        let asp = Simulator::new(cfg(n, seed, 12.0), Method::Asp).run();
        let m = *g.choose(&[
            Method::Bsp,
            Method::Ssp { staleness: 4 },
            Method::Pbsp { sample: 5 },
            Method::Pssp { sample: 5, staleness: 4 },
        ]);
        let other = Simulator::new(cfg(n, seed, 12.0), m).run();
        assert!(
            asp.mean_progress() >= other.mean_progress() * 0.98,
            "{m} progressed past ASP: {} vs {}",
            other.mean_progress(),
            asp.mean_progress()
        );
    });
}

#[test]
fn prop_update_and_control_accounting_consistent() {
    property("message accounting invariants", 12, |g| {
        let n = g.usize_in(2, 50);
        let seed = g.seed();
        let beta = g.usize_in(1, 8);
        let r = Simulator::new(
            cfg(n, seed, 10.0),
            Method::Pbsp { sample: beta },
        )
        .run();
        // every advance was preceded by >= 1 sampling attempt of cost 2β
        assert!(
            r.control_msgs >= 2 * beta as u64 * r.total_advances / (n as u64).max(1),
            "control messages too low"
        );
        // updates pushed >= advances (a node pushes, then may block)
        assert!(r.update_msgs >= r.total_advances);
        // and can exceed advances by at most the population (one in-flight
        // push per node)
        assert!(r.update_msgs <= r.total_advances + n as u64);
    });
}

#[test]
fn prop_determinism_across_time_dists() {
    property("simulator determinism for all time distributions", 9, |g| {
        let dist = *g.choose(&[
            TimeDist::Exponential,
            TimeDist::Normal { cv: 0.3 },
            TimeDist::Pareto { shape: 2.5 },
        ]);
        let n = g.usize_in(5, 40);
        let seed = g.seed();
        let mk = || ClusterConfig {
            n_nodes: n,
            seed,
            duration: 8.0,
            iter_dist: dist,
            ..ClusterConfig::default()
        };
        let a = Simulator::new(mk(), Method::Pssp { sample: 3, staleness: 2 }).run();
        let b = Simulator::new(mk(), Method::Pssp { sample: 3, staleness: 2 }).run();
        assert_eq!(a.final_steps, b.final_steps);
        assert_eq!(a.control_msgs, b.control_msgs);
        assert_eq!(a.events, b.events);
    });
}

#[test]
fn prop_every_layer_admission_spelling_agrees_with_the_policy() {
    // PR 9 deleted four inline admission reimplementations (simulator
    // tracker, parameter-server coordinator, p2p worker, deployed node).
    // This pins that each deleted spelling was — and stays — value-equal
    // to the one BarrierPolicy core, for all six methods, against the
    // centralised oracle decision.
    use actor_psp::barrier::{decide_with_oracle, BarrierPolicy, ViewRequirement};
    property("all legacy admission spellings == policy == oracle", 400, |g| {
        let methods = [
            Method::Bsp,
            Method::Asp,
            Method::Ssp { staleness: g.u64_in(0, 6) },
            Method::Pbsp { sample: g.usize_in(1, 12) },
            Method::Pssp { sample: g.usize_in(1, 12), staleness: g.u64_in(0, 6) },
            Method::Pquorum {
                sample: g.usize_in(1, 12),
                staleness: g.u64_in(0, 6),
                quorum_pct: g.u64_in(0, 100) as u8,
            },
        ];
        let method = *g.choose(&methods);
        let n = g.usize_in(1, 40);
        let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, 15)).collect();
        let my = g.u64_in(0, 15);
        let policy = BarrierPolicy::new(method);
        let control = method.build();
        let mut scratch = Vec::new();
        let oracle = {
            let mut rng = g.rng();
            decide_with_oracle(&*control, my, &steps, &mut rng, &mut scratch)
        };
        // Re-draw the identical sample for the policy + legacy sides.
        let view: Vec<u64> = match policy.view() {
            ViewRequirement::None => Vec::new(),
            ViewRequirement::Global => steps.clone(),
            ViewRequirement::Sample(beta) => {
                let mut rng = g.rng();
                let mut idx = Vec::new();
                rng.sample_into(steps.len(), beta, &mut idx);
                idx.iter().map(|&i| steps[i]).collect()
            }
        };
        let mine = policy.admit_view(my, &view);
        assert_eq!(mine, oracle, "{method:?} my={my} view={view:?}");
        if policy.min_view_sufficient() && !view.is_empty() {
            let min = *view.iter().min().unwrap();
            let theta = policy.staleness();
            // simulator tracker / ps coordinator form: min + θ >= my
            // (overflow-prone — the policy's saturating form is the fix,
            // value-equal on every reachable input)
            assert_eq!(mine, min.saturating_add(theta) >= my);
            // p2p worker ∀-peer form: every sampled peer within the window
            assert_eq!(
                mine,
                view.iter().all(|&s| my.saturating_sub(s) <= theta)
            );
            // deployed-node streamed-min form
            assert_eq!(mine, policy.admit_min(my, Some(min)));
        }
    });
}

#[test]
fn prop_p2p_window_is_anchored_at_the_completed_step() {
    // Regression pin for the p2p engine's historical off-by-one: a
    // worker that has just *finished* step `step` crosses the barrier
    // for `step + 1`, so the window predicate must be
    // `(step + 1).saturating_sub(peer) <= θ` — anchoring at `step`
    // admits one step too eagerly whenever the slowest sampled peer is
    // exactly θ+1 behind the next step.
    use actor_psp::barrier::BarrierPolicy;
    property("p2p lag form anchored at step+1", 200, |g| {
        let theta = g.u64_in(0, 6);
        let policy =
            BarrierPolicy::new(Method::Pssp { sample: 4, staleness: theta });
        let n = g.usize_in(1, 24);
        // step >= θ so the boundary peer below is genuinely θ+1 behind
        // (no saturation masking the gap).
        let step = theta + g.u64_in(0, 15);
        let view: Vec<u64> = (0..n).map(|_| g.u64_in(0, 17)).collect();
        let correct =
            view.iter().all(|&s| (step + 1).saturating_sub(s) <= theta);
        assert_eq!(policy.admit_view(step + 1, &view), correct);
        // The boundary that exposed the bug: one peer exactly θ+1 behind
        // the *next* step must block, even though it is only θ behind
        // the completed one.
        let boundary = step - theta;
        assert!(!policy.admit_view(step + 1, &[boundary]));
        assert!(policy.admit_view(step + 1, &[boundary + 1]));
    });
}

#[test]
fn prop_churn_preserves_invariants() {
    property("churn: active set consistent, progress continues", 10, |g| {
        let n = g.usize_in(5, 40);
        let seed = g.seed();
        let churn = actor_psp::sim::ChurnConfig {
            join_rate: g.f64_in(0.1, 2.0),
            leave_rate: g.f64_in(0.1, 2.0),
            crash_rate: 0.0,
        };
        let c = ClusterConfig {
            n_nodes: n,
            seed,
            duration: 10.0,
            churn: Some(churn),
            ..ClusterConfig::default()
        };
        let r = Simulator::new(c, Method::Pssp { sample: 3, staleness: 2 }).run();
        assert!(!r.final_steps.is_empty(), "cluster died out entirely");
        assert!(r.total_advances > 0);
    });
}
