//! Fault-injection property test: a hostile wire must not change what
//! the node runtime delivers.
//!
//! Every node's [`ChannelTransport`] is wrapped in a seeded
//! [`FaultyTransport`] injecting drops (first-attempt losses that
//! retransmit), duplicates, delays, reordering — and, on node 0, a
//! one-directional partition toward node 1 that heals mid-run. Under
//! the at-least-once delivery contract the gossip plane dedups by
//! rumor id, so the observable outcome must be *exactly-once*: every
//! origination applied once on every node, zero dropped deltas, zero
//! missing rumors — across gossip fanout ∈ {1, 2, 4} and several fault
//! seeds. Only a partition that never heals may genuinely lose frames,
//! and this test never configures one.

use std::sync::Arc;
use std::time::Duration;

use actor_psp::barrier::Method;
use actor_psp::engine::delta::CompressConfig;
use actor_psp::engine::gossip::GossipConfig;
use actor_psp::engine::node::{run_node, NodeOutcome, Workload};
use actor_psp::engine::transport::{ChannelTransport, FaultConfig, FaultStats, FaultyTransport};
use actor_psp::engine::GradFn;
use actor_psp::util::rng::Rng;

fn workload(fanout: usize) -> Workload {
    Workload {
        n: 3,
        steps: 8,
        dim: 8,
        lr: 0.1,
        seed: 42,
        method: Method::Pssp { sample: 2, staleness: 2 },
        gossip: GossipConfig { fanout, flush_every: 1, ttl: 4 },
        drain_timeout: Duration::from_secs(20),
        membership: None,
        compress: CompressConfig::default(),
    }
}

fn seed_only_grad() -> GradFn {
    Arc::new(|w: &[f32], seed: u64| {
        let mut rng = Rng::new(seed);
        (0..w.len()).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    })
}

/// Per-node fault recipe: heavy enough that every fault kind fires,
/// tame enough that the run stays well inside the drain timeout.
fn faults(node: usize, seed: u64) -> FaultConfig {
    let mut fc = FaultConfig {
        seed: seed.wrapping_mul(0x9E37_79B9).wrapping_add(node as u64),
        drop_p: 0.15,
        dup_p: 0.15,
        delay_p: 0.2,
        delay_max: Duration::from_millis(10),
        retry: Duration::from_millis(15),
        reorder_p: 0.1,
        ..FaultConfig::default()
    };
    if node == 0 {
        // Asymmetric partition: node 0 cannot reach node 1 until the
        // heal — frames queue and deliver late, never silently vanish.
        fc.partitions = vec![(0, 1)];
        fc.heal_after = Some(Duration::from_millis(250));
    }
    fc
}

fn run_faulty_cluster(wl: &Workload, fault_seed: u64) -> (Vec<NodeOutcome>, FaultStats) {
    let transports = ChannelTransport::cluster(wl.n);
    let mut handles = Vec::new();
    for (id, tr) in transports.into_iter().enumerate() {
        let cfg = wl.node_config(id);
        let fc = faults(id, fault_seed);
        let grad = seed_only_grad();
        handles.push(std::thread::spawn(move || {
            let mut faulty = FaultyTransport::new(tr, fc);
            let out = run_node(&cfg, &mut faulty, grad, None);
            (out, faulty.stats())
        }));
    }
    let mut outs = Vec::new();
    let mut total = FaultStats::default();
    for h in handles {
        let (out, s) = h.join().expect("faulty node");
        outs.push(out);
        total.dropped += s.dropped;
        total.duplicated += s.duplicated;
        total.delayed += s.delayed;
        total.reordered += s.reordered;
        total.partitioned += s.partitioned;
    }
    (outs, total)
}

#[test]
fn faulty_wire_still_delivers_exactly_once_across_fanouts() {
    for fanout in [1usize, 2, 4] {
        for fault_seed in [7u64, 1717] {
            let wl = workload(fanout);
            let (outs, stats) = run_faulty_cluster(&wl, fault_seed);
            // The chaos actually happened — otherwise the assertions
            // below are vacuous.
            assert!(
                stats.dropped + stats.duplicated + stats.delayed + stats.reordered > 0,
                "fanout {fanout} seed {fault_seed}: no faults fired"
            );
            assert!(
                stats.partitioned > 0,
                "fanout {fanout} seed {fault_seed}: partition never blocked a frame"
            );
            for (i, o) in outs.iter().enumerate() {
                assert_eq!(
                    o.report.dropped_deltas, 0,
                    "fanout {fanout} seed {fault_seed}: node {i} dropped deltas"
                );
                assert_eq!(
                    o.report.missing_rumors, 0,
                    "fanout {fanout} seed {fault_seed}: node {i} missing rumors"
                );
                // Exactly-once per origin: all 8 originations of all 3
                // nodes applied, none twice (applied_of counts distinct).
                assert_eq!(
                    o.applied_of,
                    vec![wl.steps as u32; wl.n],
                    "fanout {fanout} seed {fault_seed}: node {i} applied_of"
                );
                assert_eq!(o.report.steps[i], wl.steps);
            }
        }
    }
}
