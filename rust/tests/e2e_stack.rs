//! Integration over the full three-layer stack (needs `make artifacts`
//! plus the `pjrt` feature for the PJRT-backed tests): PJRT-backed
//! engines, cross-validation of the Pallas-kernel artifacts against the
//! pure-Rust model, a short end-to-end transformer run, and the sharded
//! parameter-server acceptance sweep on the `real_sgd_cluster` scenario.
//!
//! PJRT tests skip (with a note) when artifacts are absent or the crate
//! was built without the `pjrt` feature, so plain `cargo test` stays
//! runnable everywhere; the sharded-engine equivalence tests run the same
//! workload shape through the pure-Rust gradient path and always run.

use std::sync::Arc;

use actor_psp::barrier::Method;
use actor_psp::engine::paramserver::{self, PsConfig};
use actor_psp::engine::GradFn;
use actor_psp::model::linear::{minibatch_grad_fn, Dataset, LinearModel};
use actor_psp::runtime::{linear_grad_fn, Manifest, Runtime, RuntimeService, Tensor};
use actor_psp::train::{psp_train_lm, train_lm, Corpus, TransformerTrainer};
use actor_psp::util::rng::Rng;
use actor_psp::util::stats::l2_dist;

fn have_artifacts() -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return false;
    }
    let ok = Manifest::default_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

/// The `real_sgd_cluster` example's workload shape: 6 workers, 12 steps,
/// d = 100, seed 3, pure-Rust gradients over the same synthetic dataset.
fn sgd_cluster_cfg(method: Method) -> PsConfig {
    PsConfig {
        n_workers: 6,
        steps_per_worker: 12,
        method,
        lr: 0.05,
        dim: 100,
        seed: 3,
        ..PsConfig::default()
    }
}

fn sgd_cluster_grad(dim: usize) -> (GradFn, Vec<f32>) {
    let mut rng = Rng::new(11);
    let data = Arc::new(Dataset::synthetic(2048, dim, 0.05, &mut rng));
    let w_true = data.w_true.clone();
    (minibatch_grad_fn(data, 32), w_true)
}

#[test]
fn sharded_engine_learns_on_real_sgd_cluster_scenario() {
    // Every shard count must converge on the seeded scenario, for all
    // five barrier methods of the paper.
    for method in Method::paper_five(3, 2) {
        for shards in [1usize, 4] {
            let cfg = PsConfig { n_shards: shards, ..sgd_cluster_cfg(method) };
            let (grad, w_true) = sgd_cluster_grad(cfg.dim);
            let r = paramserver::run(&cfg, vec![0.0; cfg.dim], grad);
            let init = l2_dist(&vec![0.0; cfg.dim], &w_true);
            let err = l2_dist(&r.model, &w_true);
            assert!(
                err < init * 0.9,
                "{method} shards={shards}: no learning ({init} -> {err})"
            );
        }
    }
}

#[test]
fn sharded_engine_acceptance_equivalence() {
    // Acceptance criterion: n_shards in {1, 4} reaches the same final
    // model (within 1e-4) as the single-actor engine on the seeded
    // real_sgd_cluster scenario, for BSP, SSP(4) and pSSP(8, 4).
    //
    // Live-thread runs with model-dependent gradients are only
    // interleaving-deterministic with one worker, so the multi-worker leg
    // uses a seed-only gradient oracle (the applied-update multiset is
    // then interleaving-independent) and the single-worker leg keeps the
    // real minibatch gradients.
    for method in [
        Method::Bsp,
        Method::Ssp { staleness: 4 },
        Method::Pssp { sample: 8, staleness: 4 },
    ] {
        // leg 1: single worker, real gradients, bitwise-stable trajectory
        let single = PsConfig {
            n_workers: 1,
            steps_per_worker: 24,
            ..sgd_cluster_cfg(method)
        };
        let (grad, _) = sgd_cluster_grad(single.dim);
        let reference = paramserver::run(&single, vec![0.0; single.dim], grad.clone());
        let sharded = paramserver::run(
            &PsConfig { n_shards: 4, ..single.clone() },
            vec![0.0; single.dim],
            grad,
        );
        let d = l2_dist(&sharded.model, &reference.model);
        assert!(d < 1e-4, "{method} single-worker: shards diverged by {d}");

        // leg 2: full 6-worker scenario, seed-only gradients
        let multi = sgd_cluster_cfg(method);
        let dim = multi.dim;
        let oracle: GradFn = Arc::new(move |_w, seed| {
            let mut rng = Rng::new(seed);
            (0..dim).map(|_| (rng.next_f32() - 0.5) * 0.2).collect()
        });
        let r1 = paramserver::run(&multi, vec![0.0; dim], oracle.clone());
        let r4 = paramserver::run(
            &PsConfig { n_shards: 4, ..multi.clone() },
            vec![0.0; dim],
            oracle.clone(),
        );
        let d = l2_dist(&r1.model, &r4.model);
        assert!(d < 1e-4, "{method} multi-worker: shards diverged by {d}");
        // batched pushes keep the same sum too
        let rb = paramserver::run(
            &PsConfig { n_shards: 4, push_batch: 3, ..multi.clone() },
            vec![0.0; dim],
            oracle,
        );
        let d = l2_dist(&r1.model, &rb.model);
        assert!(d < 1e-4, "{method} batched: diverged by {d}");
    }
}

#[test]
fn sharding_splits_messages_across_shards() {
    let cfg = PsConfig { n_shards: 4, ..sgd_cluster_cfg(Method::Asp) };
    let (grad, _) = sgd_cluster_grad(cfg.dim);
    let r = paramserver::run(&cfg, vec![0.0; cfg.dim], grad);
    // one scatter message per shard per step
    assert_eq!(r.update_msgs, 6 * 12 * 4);
    // batching divides the scatter count
    let cfg = PsConfig { push_batch: 4, ..cfg };
    let (grad, _) = sgd_cluster_grad(cfg.dim);
    let r = paramserver::run(&cfg, vec![0.0; cfg.dim], grad);
    assert_eq!(r.update_msgs, 6 * 3 * 4);
}

#[test]
fn pjrt_linear_step_matches_rust_sgd_trajectory() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::new().unwrap();
    let (n, d) = (32usize, 1000usize);
    let mut rng = Rng::new(9);
    let data = Dataset::synthetic(n, d, 0.05, &mut rng);
    let lr = 0.002f32;

    // PJRT trajectory: 5 fused steps through the Pallas kernel artifact.
    let mut w_pjrt = vec![0.0f32; d];
    for _ in 0..5 {
        let out = rt
            .execute(
                "linear_step_n32_d1000",
                &[
                    Tensor::F32(data.x.clone()),
                    Tensor::F32(w_pjrt.clone()),
                    Tensor::F32(data.y.clone()),
                    Tensor::F32(vec![lr]),
                ],
            )
            .unwrap();
        w_pjrt = out[0].as_f32().unwrap().to_vec();
    }

    // Pure-Rust trajectory: full-batch gradient + manual update.
    let mut model = LinearModel::new(d);
    let mut w_rust = vec![0.0f32; d];
    for _ in 0..5 {
        let g = model.full_grad(&data, &w_rust);
        for (wi, gi) in w_rust.iter_mut().zip(&g) {
            *wi -= lr * gi;
        }
    }

    let dist = l2_dist(&w_pjrt, &w_rust);
    assert!(dist < 1e-2, "trajectories diverged: L2 {dist}");
}

#[test]
fn paramserver_engine_over_pjrt_all_methods() {
    if !have_artifacts() {
        return;
    }
    let svc = Arc::new(RuntimeService::spawn().unwrap());
    let mut rng = Rng::new(21);
    let data = Arc::new(Dataset::synthetic(1024, 100, 0.05, &mut rng));
    for method in Method::paper_five(2, 2) {
        for shards in [1usize, 4] {
            let grad = linear_grad_fn(
                Arc::clone(&svc),
                "linear_grad_n128_d100",
                Arc::clone(&data),
                128,
            )
            .unwrap();
            let cfg = PsConfig {
                n_workers: 3,
                steps_per_worker: 4,
                method,
                lr: 0.05,
                dim: 100,
                seed: 5,
                n_shards: shards,
                ..PsConfig::default()
            };
            let r = paramserver::run(&cfg, vec![0.0; 100], grad);
            assert_eq!(r.update_msgs, 12 * shards as u64, "{method}");
            let err = l2_dist(&r.model, &data.w_true);
            let init = l2_dist(&vec![0.0; 100], &data.w_true);
            assert!(
                err < init,
                "{method} shards={shards}: no learning ({init} -> {err})"
            );
        }
    }
}

#[test]
fn transformer_learns_above_chance_quickly() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::new().unwrap();
    let mut trainer = TransformerTrainer::new(rt, "tiny", 7).unwrap();
    let uniform = trainer.uniform_loss();
    let corpus = Corpus::synthetic(1 << 14, trainer.meta.vocab, 3);
    let log = train_lm(&mut trainer, &corpus, 25, 0.25, 11).unwrap();
    assert!(
        (log.first_loss() - uniform).abs() < 0.6,
        "fresh model should start near ln(vocab)={uniform}: {}",
        log.first_loss()
    );
    assert!(
        log.last_loss() < log.first_loss() * 0.8,
        "loss should fall >20% in 25 steps: {} -> {}",
        log.first_loss(),
        log.last_loss()
    );
    assert!(log.losses.iter().all(|(_, l)| l.is_finite()));
}

#[test]
fn psp_paced_training_differentiates_methods() {
    if !have_artifacts() {
        return;
    }
    let steps = 16u64;
    let run = |method| {
        let rt = Runtime::new().unwrap();
        let mut trainer = TransformerTrainer::new(rt, "tiny", 7).unwrap();
        let corpus = Corpus::synthetic(1 << 14, trainer.meta.vocab, 3);
        psp_train_lm(
            &mut trainer, &corpus, method, 4, steps, 0.25, 13,
            Some((0.25, 4.0)), 1,
        )
        .unwrap()
    };
    let bsp = run(Method::Bsp);
    let asp = run(Method::Asp);
    // BSP pacing keeps workers in lockstep even with a straggler
    let bmin = bsp.worker_steps.iter().min().unwrap();
    let bmax = bsp.worker_steps.iter().max().unwrap();
    assert!(bmax - bmin <= 1, "BSP spread {bmin}..{bmax}");
    // ASP lets fast workers run ahead
    let amin = asp.worker_steps.iter().min().unwrap();
    let amax = asp.worker_steps.iter().max().unwrap();
    assert!(amax - amin >= 1, "ASP should spread: {:?}", asp.worker_steps);
    // both actually trained
    assert_eq!(bsp.losses.len() as u64, steps);
    assert_eq!(asp.losses.len() as u64, steps);
}

#[test]
fn tf_loss_artifact_agrees_with_step_loss() {
    if !have_artifacts() {
        return;
    }
    // loss(params, batch) from the eval artifact must equal the
    // loss-before-step returned by the step artifact on the same batch.
    let rt = Runtime::new().unwrap();
    let mut trainer = TransformerTrainer::new(rt, "tiny", 3).unwrap();
    let corpus = Corpus::synthetic(1 << 13, trainer.meta.vocab, 5);
    let mut rng = Rng::new(8);
    let batch = corpus.next_batch(trainer.meta.batch, trainer.meta.seq, &mut rng);
    let eval = trainer.eval_loss(&batch).unwrap();
    let step_loss = trainer.train_step(&batch, 0.0).unwrap();
    assert!(
        (eval - step_loss).abs() < 1e-4,
        "eval {eval} vs step-before-loss {step_loss}"
    );
}
