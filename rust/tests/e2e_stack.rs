//! Integration over the full three-layer stack (needs `make artifacts`):
//! PJRT-backed engines, cross-validation of the Pallas-kernel artifacts
//! against the pure-Rust model, and a short end-to-end transformer run.
//!
//! Tests skip (with a note) when artifacts are absent so `cargo test`
//! stays runnable before the first `make artifacts`.

use std::sync::Arc;

use actor_psp::barrier::Method;
use actor_psp::engine::paramserver::{self, PsConfig};
use actor_psp::model::linear::{Dataset, LinearModel};
use actor_psp::runtime::{linear_grad_fn, Manifest, Runtime, RuntimeService, Tensor};
use actor_psp::train::{psp_train_lm, train_lm, Corpus, TransformerTrainer};
use actor_psp::util::rng::Rng;
use actor_psp::util::stats::l2_dist;

fn have_artifacts() -> bool {
    let ok = Manifest::default_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

#[test]
fn pjrt_linear_step_matches_rust_sgd_trajectory() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::new().unwrap();
    let (n, d) = (32usize, 1000usize);
    let mut rng = Rng::new(9);
    let data = Dataset::synthetic(n, d, 0.05, &mut rng);
    let lr = 0.002f32;

    // PJRT trajectory: 5 fused steps through the Pallas kernel artifact.
    let mut w_pjrt = vec![0.0f32; d];
    for _ in 0..5 {
        let out = rt
            .execute(
                "linear_step_n32_d1000",
                &[
                    Tensor::F32(data.x.clone()),
                    Tensor::F32(w_pjrt.clone()),
                    Tensor::F32(data.y.clone()),
                    Tensor::F32(vec![lr]),
                ],
            )
            .unwrap();
        w_pjrt = out[0].as_f32().unwrap().to_vec();
    }

    // Pure-Rust trajectory: full-batch gradient + manual update.
    let mut model = LinearModel::new(d);
    let mut w_rust = vec![0.0f32; d];
    for _ in 0..5 {
        let g = model.full_grad(&data, &w_rust);
        for (wi, gi) in w_rust.iter_mut().zip(&g) {
            *wi -= lr * gi;
        }
    }

    let dist = l2_dist(&w_pjrt, &w_rust);
    assert!(dist < 1e-2, "trajectories diverged: L2 {dist}");
}

#[test]
fn paramserver_engine_over_pjrt_all_methods() {
    if !have_artifacts() {
        return;
    }
    let svc = Arc::new(RuntimeService::spawn().unwrap());
    let mut rng = Rng::new(21);
    let data = Arc::new(Dataset::synthetic(1024, 100, 0.05, &mut rng));
    for method in Method::paper_five(2, 2) {
        let grad = linear_grad_fn(
            Arc::clone(&svc),
            "linear_grad_n128_d100",
            Arc::clone(&data),
            128,
        )
        .unwrap();
        let cfg = PsConfig {
            n_workers: 3,
            steps_per_worker: 4,
            method,
            lr: 0.05,
            dim: 100,
            seed: 5,
            ..PsConfig::default()
        };
        let r = paramserver::run(&cfg, vec![0.0; 100], grad);
        assert_eq!(r.update_msgs, 12, "{method}");
        let err = l2_dist(&r.model, &data.w_true);
        let init = l2_dist(&vec![0.0; 100], &data.w_true);
        assert!(err < init, "{method}: no learning ({init} -> {err})");
    }
}

#[test]
fn transformer_learns_above_chance_quickly() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::new().unwrap();
    let mut trainer = TransformerTrainer::new(rt, "tiny", 7).unwrap();
    let uniform = trainer.uniform_loss();
    let corpus = Corpus::synthetic(1 << 14, trainer.meta.vocab, 3);
    let log = train_lm(&mut trainer, &corpus, 25, 0.25, 11).unwrap();
    assert!(
        (log.first_loss() - uniform).abs() < 0.6,
        "fresh model should start near ln(vocab)={uniform}: {}",
        log.first_loss()
    );
    assert!(
        log.last_loss() < log.first_loss() * 0.8,
        "loss should fall >20% in 25 steps: {} -> {}",
        log.first_loss(),
        log.last_loss()
    );
    assert!(log.losses.iter().all(|(_, l)| l.is_finite()));
}

#[test]
fn psp_paced_training_differentiates_methods() {
    if !have_artifacts() {
        return;
    }
    let steps = 16u64;
    let run = |method| {
        let rt = Runtime::new().unwrap();
        let mut trainer = TransformerTrainer::new(rt, "tiny", 7).unwrap();
        let corpus = Corpus::synthetic(1 << 14, trainer.meta.vocab, 3);
        psp_train_lm(
            &mut trainer, &corpus, method, 4, steps, 0.25, 13,
            Some((0.25, 4.0)),
        )
        .unwrap()
    };
    let bsp = run(Method::Bsp);
    let asp = run(Method::Asp);
    // BSP pacing keeps workers in lockstep even with a straggler
    let bmin = bsp.worker_steps.iter().min().unwrap();
    let bmax = bsp.worker_steps.iter().max().unwrap();
    assert!(bmax - bmin <= 1, "BSP spread {bmin}..{bmax}");
    // ASP lets fast workers run ahead
    let amin = asp.worker_steps.iter().min().unwrap();
    let amax = asp.worker_steps.iter().max().unwrap();
    assert!(amax - amin >= 1, "ASP should spread: {:?}", asp.worker_steps);
    // both actually trained
    assert_eq!(bsp.losses.len() as u64, steps);
    assert_eq!(asp.losses.len() as u64, steps);
}

#[test]
fn tf_loss_artifact_agrees_with_step_loss() {
    if !have_artifacts() {
        return;
    }
    // loss(params, batch) from the eval artifact must equal the
    // loss-before-step returned by the step artifact on the same batch.
    let rt = Runtime::new().unwrap();
    let mut trainer = TransformerTrainer::new(rt, "tiny", 3).unwrap();
    let corpus = Corpus::synthetic(1 << 13, trainer.meta.vocab, 5);
    let mut rng = Rng::new(8);
    let batch = corpus.next_batch(trainer.meta.batch, trainer.meta.seq, &mut rng);
    let eval = trainer.eval_loss(&batch).unwrap();
    let step_loss = trainer.train_step(&batch, 0.0).unwrap();
    assert!(
        (eval - step_loss).abs() < 1e-4,
        "eval {eval} vs step-before-loss {step_loss}"
    );
}
