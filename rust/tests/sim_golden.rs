//! Golden-trace tests across the scheduler / snapshot-store refactor.
//!
//! Two layers of protection:
//!
//! 1. **Oracle equality** — the calendar-queue simulator must produce
//!    exactly the trajectory of the pre-refactor binary-heap scheduler
//!    ([`Simulator::run_reference`]), including bit-exact SGD error
//!    curves through the versioned snapshot store, for every paper
//!    method, with and without churn and losses.
//! 2. **Recorded fingerprints** — seed-42 fingerprints of
//!    `final_steps` / `update_msgs` / `control_msgs` for all of
//!    `Method::paper_five`, persisted in `tests/golden/sim_seed42.json`.
//!    On a fresh checkout (no file) the fingerprints are recorded
//!    locally; commit the generated file to pin the trajectories so
//!    *future* refactors are held to the same traces. **CI never
//!    bootstraps**: with `GITHUB_ACTIONS` (or `GOLDEN_STRICT=1`) set and
//!    no committed file, the test fails — a silently-recording golden
//!    test pins nothing and can never catch a regression. CI still
//!    records + uploads the would-be file as the
//!    `sim-golden-fingerprints` artifact so a maintainer can commit it
//!    (this container has no Rust toolchain, so the numbers must come
//!    from a real run). Intentional trajectory change: delete the file,
//!    re-run (`GOLDEN_RECORD=1` forces recording anywhere), re-commit.

use actor_psp::barrier::Method;
use actor_psp::sim::{ChurnConfig, ClusterConfig, SgdConfig, SimResult, Simulator};
use actor_psp::util::json::{obj, Json};

fn golden_cfg() -> ClusterConfig {
    ClusterConfig {
        n_nodes: 300,
        duration: 20.0,
        seed: 42,
        ..ClusterConfig::default()
    }
}

fn assert_same_trajectory(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.final_steps, b.final_steps, "{what}: final_steps diverged");
    assert_eq!(a.update_msgs, b.update_msgs, "{what}: update_msgs diverged");
    assert_eq!(a.control_msgs, b.control_msgs, "{what}: control_msgs diverged");
    assert_eq!(a.total_advances, b.total_advances, "{what}: advances diverged");
    assert_eq!(a.lost_msgs, b.lost_msgs, "{what}: lost_msgs diverged");
    assert_eq!(a.events, b.events, "{what}: event count diverged");
    assert_eq!(
        a.updates_timeline, b.updates_timeline,
        "{what}: updates timeline diverged"
    );
    // Error curves must match to the bit, not approximately: the
    // snapshot store's replayed reads feed the same gradients in the
    // same order as the old cloned snapshots.
    let bits = |r: &SimResult| -> Vec<(u64, u64)> {
        r.error_timeline
            .iter()
            .map(|&(t, e)| (t.to_bits(), e.to_bits()))
            .collect()
    };
    assert_eq!(bits(a), bits(b), "{what}: error timeline diverged");
}

#[test]
fn calendar_matches_heap_oracle_for_paper_five() {
    for m in Method::paper_five(10, 4) {
        let sim = Simulator::new(golden_cfg(), m);
        let cal = sim.run();
        let heap = sim.run_reference();
        assert_same_trajectory(&cal, &heap, &format!("{m}"));
    }
}

#[test]
fn calendar_matches_heap_oracle_with_sgd() {
    for m in Method::paper_five(8, 4) {
        let cfg = ClusterConfig {
            n_nodes: 80,
            sgd: Some(SgdConfig { dim: 120, ..SgdConfig::default() }),
            ..golden_cfg()
        };
        let sim = Simulator::new(cfg, m);
        assert_same_trajectory(&sim.run(), &sim.run_reference(), &format!("{m}+sgd"));
    }
}

#[test]
fn calendar_matches_heap_oracle_under_churn_and_loss() {
    let cfg = ClusterConfig {
        n_nodes: 120,
        churn: Some(ChurnConfig { join_rate: 1.0, leave_rate: 1.0, crash_rate: 0.0 }),
        loss_rate: 0.1,
        sgd: Some(SgdConfig { dim: 60, ..SgdConfig::default() }),
        ..golden_cfg()
    };
    for m in Method::paper_five(6, 3) {
        let sim = Simulator::new(cfg.clone(), m);
        assert_same_trajectory(&sim.run(), &sim.run_reference(), &format!("{m}+churn"));
    }
}

#[test]
fn calendar_matches_heap_oracle_under_crash_churn() {
    // Crash-stop churn adds Crash/ConfirmDead events to the schedule; the
    // calendar queue must still replay the heap oracle bit-exactly,
    // including the victim stream.
    let cfg = ClusterConfig {
        n_nodes: 120,
        churn: Some(ChurnConfig { join_rate: 1.0, leave_rate: 0.5, crash_rate: 0.5 }),
        crash_detect_secs: 0.75,
        sgd: Some(SgdConfig { dim: 60, ..SgdConfig::default() }),
        ..golden_cfg()
    };
    for m in Method::paper_five(6, 3) {
        let sim = Simulator::new(cfg.clone(), m);
        let cal = sim.run();
        let heap = sim.run_reference();
        assert_same_trajectory(&cal, &heap, &format!("{m}+crash"));
        assert_eq!(cal.churn_victims, heap.churn_victims, "{m}: victim stream");
        assert_eq!(cal.crashes, heap.crashes, "{m}: crash count");
    }
}

/// FNV-1a over the step vector — stable fingerprint of a trajectory.
fn fnv(steps: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &s in steps {
        for b in s.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/sim_seed42.json")
}

#[test]
fn golden_fingerprints_seed42_paper_five() {
    let mut measured: Vec<(String, Json)> = Vec::new();
    let mut results: Vec<(String, SimResult)> = Vec::new();
    for m in Method::paper_five(10, 4) {
        let r = Simulator::new(golden_cfg(), m).run();
        results.push((m.to_string(), r));
    }
    for (name, r) in &results {
        let entry = obj(vec![
            (
                "final_steps_fnv",
                Json::Str(format!("{:016x}", fnv(&r.final_steps))),
            ),
            (
                "final_steps_sum",
                Json::Num(r.final_steps.iter().sum::<u64>() as f64),
            ),
            ("update_msgs", Json::Num(r.update_msgs as f64)),
            ("control_msgs", Json::Num(r.control_msgs as f64)),
            ("total_advances", Json::Num(r.total_advances as f64)),
        ]);
        measured.push((name.clone(), entry));
    }
    let doc = obj(vec![
        ("config", Json::Str("n=300 d=20s seed=42 defaults".to_string())),
        (
            "methods",
            obj(measured.iter().map(|(n, j)| (n.as_str(), j.clone())).collect()),
        ),
    ]);

    let path = golden_path();
    if !path.exists() {
        let force_record = std::env::var_os("GOLDEN_RECORD").is_some();
        let strict = std::env::var_os("GOLDEN_STRICT").is_some()
            || std::env::var_os("GITHUB_ACTIONS").is_some();
        if strict && !force_record {
            panic!(
                "golden fingerprint file {} is missing — CI refuses to \
                 bootstrap (a self-recording golden test pins nothing). \
                 Run `GOLDEN_RECORD=1 cargo test --test sim_golden` (or \
                 download the sim-golden-fingerprints CI artifact) and \
                 commit the file.",
                path.display()
            );
        }
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, doc.to_pretty()).unwrap();
        eprintln!(
            "recorded golden fingerprints at {} — commit this file to pin \
             seeded trajectories (CI fails until it is committed)",
            path.display()
        );
        return;
    }
    let want = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let want_methods = want.get("methods").and_then(Json::as_obj).unwrap();
    for (name, got) in &measured {
        let w = want_methods
            .get(name)
            .unwrap_or_else(|| panic!("golden file missing method {name}"));
        let w_fnv = w.get("final_steps_fnv").and_then(Json::as_str).unwrap();
        let g_fnv = got.get("final_steps_fnv").and_then(Json::as_str).unwrap();
        assert_eq!(
            w_fnv,
            g_fnv,
            "{name}: final_steps fingerprint changed; if intentional, \
             delete {} and re-run",
            golden_path().display()
        );
        for key in [
            "final_steps_sum",
            "update_msgs",
            "control_msgs",
            "total_advances",
        ] {
            let wv = w.get(key).and_then(Json::as_f64).unwrap();
            let gv = got.get(key).and_then(Json::as_f64).unwrap();
            assert_eq!(
                wv.to_bits(),
                gv.to_bits(),
                "{name}.{key}: golden {wv} != measured {gv} — a seeded \
                 trajectory changed; if intentional, delete {} and re-run",
                golden_path().display()
            );
        }
    }
}

// ---------------------------------------------------------------------
// Churn-trajectory golden: pin the victim-selection order
// ---------------------------------------------------------------------

fn churn_cfg() -> ClusterConfig {
    ClusterConfig {
        n_nodes: 120,
        duration: 20.0,
        seed: 42,
        churn: Some(ChurnConfig { join_rate: 1.0, leave_rate: 1.0, crash_rate: 0.0 }),
        ..ClusterConfig::default()
    }
}

fn churn_golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/churn_seed42.json")
}

/// PR 2 changed churn victim selection from an O(n) scan to the dense
/// active-list pick — still uniform, but a different enumeration order,
/// which silently shifted every seeded churn figure. This golden pins the
/// post-PR-2 victim order explicitly so the *next* refactor of the active
/// list (or of `next_below`, or of the event schedule around Leave) is
/// caught as a diff instead of re-shifting the figures. Same record /
/// strict protocol as the fingerprints above, in its own file.
#[test]
fn golden_churn_victim_order_seed42() {
    let methods = [Method::Pssp { sample: 10, staleness: 4 }, Method::Bsp];
    let mut measured: Vec<(String, Json)> = Vec::new();
    for m in methods {
        let r = Simulator::new(churn_cfg(), m).run();
        assert!(!r.churn_victims.is_empty(), "{m}: churn never fired");
        let victims64: Vec<u64> = r.churn_victims.iter().map(|&v| v as u64).collect();
        let entry = obj(vec![
            (
                "victims",
                Json::Arr(victims64.iter().map(|&v| Json::Num(v as f64)).collect()),
            ),
            ("victims_fnv", Json::Str(format!("{:016x}", fnv(&victims64)))),
            (
                "final_steps_fnv",
                Json::Str(format!("{:016x}", fnv(&r.final_steps))),
            ),
        ]);
        measured.push((m.to_string(), entry));
    }
    let doc = obj(vec![
        (
            "config",
            Json::Str("n=120 d=20s seed=42 churn join=1 leave=1".to_string()),
        ),
        (
            "methods",
            obj(measured.iter().map(|(n, j)| (n.as_str(), j.clone())).collect()),
        ),
    ]);

    let path = churn_golden_path();
    if !path.exists() {
        let force_record = std::env::var_os("GOLDEN_RECORD").is_some();
        let strict = std::env::var_os("GOLDEN_STRICT").is_some()
            || std::env::var_os("GITHUB_ACTIONS").is_some();
        if strict && !force_record {
            panic!(
                "churn golden file {} is missing — CI refuses to bootstrap. \
                 Run `GOLDEN_RECORD=1 cargo test --test sim_golden \
                 golden_churn_victim_order_seed42` (or download the \
                 sim-golden-fingerprints CI artifact) and commit the file.",
                path.display()
            );
        }
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, doc.to_pretty()).unwrap();
        eprintln!(
            "recorded churn victim-order golden at {} — commit this file to \
             pin seeded churn trajectories",
            path.display()
        );
        return;
    }
    let want = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let want_methods = want.get("methods").and_then(Json::as_obj).unwrap();
    for (name, got) in &measured {
        let w = want_methods
            .get(name)
            .unwrap_or_else(|| panic!("churn golden missing method {name}"));
        let wv: Vec<u64> = w
            .get("victims")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|j| j.as_f64().unwrap() as u64)
            .collect();
        let gv: Vec<u64> = got
            .get("victims")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|j| j.as_f64().unwrap() as u64)
            .collect();
        assert_eq!(
            wv, gv,
            "{name}: churn victim-selection order changed; if intentional, \
             delete {} and re-run",
            churn_golden_path().display()
        );
        for key in ["victims_fnv", "final_steps_fnv"] {
            assert_eq!(
                w.get(key).and_then(Json::as_str),
                got.get(key).and_then(Json::as_str),
                "{name}.{key} diverged"
            );
        }
    }
}

#[test]
fn seeded_runs_are_reproducible_across_processes_inputs() {
    // Same seed, two separate Simulator instances: identical everything.
    let m = Method::Pssp { sample: 10, staleness: 4 };
    let a = Simulator::new(golden_cfg(), m).run();
    let b = Simulator::new(golden_cfg(), m).run();
    assert_same_trajectory(&a, &b, "re-run");
}
