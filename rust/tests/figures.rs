//! Integration: the experiment harness reproduces the *shapes* the paper
//! reports (quick profile). These are the acceptance tests of the
//! reproduction — who wins, in which direction, roughly by how much.

use actor_psp::exp::{self, Cell, ExpOpts};

fn quick() -> ExpOpts {
    ExpOpts {
        quick: true,
        nodes: 150,
        duration: 15.0,
        sample: 5,
        staleness: 4,
        ..ExpOpts::default()
    }
}

fn num(c: &Cell) -> f64 {
    match c {
        Cell::Num(n) => *n,
        Cell::Int(i) => *i as f64,
        Cell::Str(_) => panic!("expected numeric cell"),
    }
}

/// Column index of a method in reports whose col 0 is the x value.
const BSP: usize = 1;
const SSP: usize = 2;
const ASP: usize = 3;
const PBSP: usize = 4;
const PSSP: usize = 5;

#[test]
fn fig1a_progress_ordering() {
    let rep = &exp::run("fig1a", &quick()).unwrap()[0];
    // rows: bsp, ssp, asp, pbsp, pssp; col 1 = mean progress
    let mean = |i: usize| num(&rep.rows[i][1]);
    let iqr = |i: usize| num(&rep.rows[i][8]);
    assert!(mean(2) > mean(1) && mean(1) > mean(0), "ASP > SSP > BSP progress");
    // probabilistic methods sit above their deterministic counterparts
    assert!(mean(3) >= mean(0), "pBSP >= BSP");
    assert!(mean(4) >= mean(1) * 0.9, "pSSP ~>= SSP");
    // dispersion: ASP widest, BSP tightest
    assert!(iqr(2) >= iqr(0), "ASP iqr >= BSP iqr");
}

#[test]
fn fig1c_sample_size_morphs_asp_to_bsp() {
    let rep = &exp::run("fig1c", &quick()).unwrap()[0];
    // Larger beta => more mass at low steps => higher CDF value at the
    // median grid point.
    let mid = rep.rows.len() / 2;
    let row = &rep.rows[mid];
    let beta0 = num(&row[1]);
    let beta64 = num(&row[row.len() - 1]);
    assert!(
        beta64 >= beta0 - 1e-9,
        "beta=64 CDF ({beta64}) should dominate beta=0 ({beta0}) at mid-grid"
    );
}

#[test]
fn fig1d_errors_decrease_for_all_methods() {
    let rep = &exp::run("fig1d", &quick()).unwrap()[0];
    let first = &rep.rows[0];
    let last = rep.rows.last().unwrap();
    for col in 1..first.len() {
        let (e0, e1) = (num(&first[col]), num(&last[col]));
        assert!(
            e1 < e0,
            "method col {col}: error should decrease ({e0} -> {e1})"
        );
    }
}

#[test]
fn fig1e_asp_sends_most_updates() {
    let rep = &exp::run("fig1e", &quick()).unwrap()[0];
    let last = rep.rows.last().unwrap();
    let (bsp, asp) = (num(&last[BSP]), num(&last[ASP]));
    assert!(
        asp > 2.0 * bsp,
        "ASP updates ({asp}) should be several times BSP's ({bsp}); \
         the paper reports ~10x at 1000 nodes"
    );
    let (pbsp, pssp) = (num(&last[PBSP]), num(&last[PSSP]));
    assert!(pbsp < asp && pssp < asp, "probabilistic methods sit below ASP");
}

#[test]
fn fig2a_straggler_robustness_grouping() {
    let rep = &exp::run("fig2a", &quick()).unwrap()[0];
    let last = rep.rows.last().unwrap(); // 30% stragglers
    let (bsp, ssp, asp, pbsp, pssp) = (
        num(&last[BSP]),
        num(&last[SSP]),
        num(&last[ASP]),
        num(&last[PBSP]),
        num(&last[PSSP]),
    );
    // deterministic group collapses harder than the sampling group
    assert!(bsp < asp && ssp < asp, "BSP/SSP below ASP under stragglers");
    assert!(
        pbsp > bsp && pssp > ssp * 0.9,
        "probabilistic variants retain more progress: pbsp={pbsp} bsp={bsp}"
    );
}

#[test]
fn fig2c_two_groups_emerge_with_slowness() {
    let rep = &exp::run("fig2c", &quick()).unwrap()[0];
    let last = rep.rows.last().unwrap(); // 16x slowness
    let (bsp, asp, pbsp) = (num(&last[BSP]), num(&last[ASP]), num(&last[PBSP]));
    assert!(
        asp > 2.0 * bsp,
        "at 16x slowness ASP ({asp}) >> BSP ({bsp})"
    );
    assert!(
        pbsp > 1.5 * bsp,
        "pBSP ({pbsp}) should sit in the robust group, far above BSP ({bsp})"
    );
}

#[test]
fn fig3_scalability_direction() {
    let rep = &exp::run("fig3", &quick()).unwrap()[0];
    let last = rep.rows.last().unwrap();
    let (bsp, asp) = (num(&last[BSP]), num(&last[ASP]));
    // growing the system hurts BSP far more than ASP
    assert!(
        bsp <= asp + 5.0,
        "BSP Δ={bsp}% should be below ASP Δ={asp}%"
    );
}

#[test]
fn fig4_fig5_bounds_generated() {
    let f4 = &exp::run("fig4", &quick()).unwrap()[0];
    let f5 = &exp::run("fig5", &quick()).unwrap()[0];
    assert_eq!(f4.rows.len(), 19);
    assert_eq!(f5.rows.len(), 19);
    // variance bounds dominate mean bounds pointwise (integer lags)
    for (r4, r5) in f4.rows.iter().zip(&f5.rows) {
        for c in 1..4 {
            assert!(num(&r5[c]) >= num(&r4[c]) * 0.99);
        }
    }
}

/// The parallel sweep runner must not change any report: `--jobs 8`
/// emits exactly the rows of `--jobs 1` (scheduling reorders execution,
/// never results).
#[test]
fn parallel_jobs_emit_identical_reports() {
    for id in ["fig1a", "fig2a", "fig3"] {
        let serial = ExpOpts { jobs: 1, ..quick() };
        let wide = ExpOpts { jobs: 8, ..quick() };
        let a = &exp::run(id, &serial).unwrap()[0];
        let b = &exp::run(id, &wide).unwrap()[0];
        assert_eq!(a.render(), b.render(), "{id}: rows differ across --jobs");
    }
}

#[test]
fn all_experiments_run_and_emit_json() {
    let dir = std::env::temp_dir().join(format!("psp-exp-{}", std::process::id()));
    let opts = ExpOpts {
        quick: true,
        nodes: 60,
        duration: 8.0,
        sample: 3,
        out_dir: Some(dir.clone()),
        ..ExpOpts::default()
    };
    let reports = exp::run("all", &opts).unwrap();
    assert_eq!(reports.len(), exp::ALL.len());
    for id in exp::ALL {
        let path = dir.join(format!("{id}.json"));
        assert!(path.exists(), "{id}.json missing");
        let src = std::fs::read_to_string(&path).unwrap();
        let j = actor_psp::util::json::Json::parse(&src).unwrap();
        assert_eq!(j.req_str("id").unwrap(), *id);
    }
    let _ = std::fs::remove_dir_all(dir);
}
