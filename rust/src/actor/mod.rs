//! Minimal actor runtime (offline substitute for tokio): named actors on
//! OS threads with typed mailboxes, used by the live engines
//! ([`crate::engine`]) — the framework the paper calls "Actor".
//!
//! Design choices:
//! * one thread per actor, `std::sync::mpsc` mailboxes — the engines run
//!   dozens of workers, not thousands (the thousand-node experiments run
//!   on the discrete-event simulator instead);
//! * [`Address`] is a cheap clonable handle; sends never block (unbounded
//!   channel) and return `false` once the actor is gone, which is how
//!   engines tolerate worker shutdown races;
//! * a global send counter per system feeds the communication-cost
//!   metrics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Handle for sending messages to an actor.
pub struct Address<M> {
    tx: Sender<M>,
    sent: Arc<AtomicU64>,
}

impl<M> Clone for Address<M> {
    fn clone(&self) -> Self {
        Address { tx: self.tx.clone(), sent: Arc::clone(&self.sent) }
    }
}

impl<M> Address<M> {
    /// Send a message. Returns false if the actor has terminated.
    pub fn send(&self, msg: M) -> bool {
        let ok = self.tx.send(msg).is_ok();
        if ok {
            self.sent.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }
}

/// The receiving side owned by the actor body.
pub struct Mailbox<M> {
    rx: Receiver<M>,
}

impl<M> Mailbox<M> {
    /// Block for the next message; `None` when all addresses are dropped.
    pub fn recv(&self) -> Option<M> {
        self.rx.recv().ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<M> {
        self.rx.try_recv().ok()
    }

    /// Receive with a timeout.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Option<M> {
        self.rx.recv_timeout(dur).ok()
    }

    /// Batched receive: block for one message, then opportunistically
    /// drain up to `max - 1` more already-queued messages (FIFO order
    /// preserved). Returns how many landed in `buf` (0 = channel closed).
    ///
    /// High-fan-in actors (the parameter-server shards) use this to
    /// amortise one mailbox wakeup over a burst of pushes.
    pub fn recv_batch(&self, buf: &mut Vec<M>, max: usize) -> usize {
        buf.clear();
        if max == 0 {
            return 0;
        }
        match self.rx.recv() {
            Ok(m) => buf.push(m),
            Err(_) => return 0,
        }
        while buf.len() < max {
            match self.rx.try_recv() {
                Ok(m) => buf.push(m),
                Err(_) => break,
            }
        }
        buf.len()
    }
}

/// A running actor: its address plus the join handle of its thread.
pub struct Actor<M, T = ()> {
    pub addr: Address<M>,
    handle: JoinHandle<T>,
    pub name: String,
}

impl<M, T> Actor<M, T> {
    /// Wait for the actor to finish and return its result.
    ///
    /// Note: the actor's mailbox stays open while `self.addr` exists; drop
    /// clones (or send an explicit stop message) before joining.
    pub fn join(self) -> T {
        let name = self.name;
        self.handle
            .join()
            .unwrap_or_else(|_| panic!("actor '{name}' panicked"))
    }

    /// Split into (address, join handle) when the owner wants to keep
    /// messaging while a supervisor joins.
    pub fn into_parts(self) -> (Address<M>, JoinHandle<T>) {
        (self.addr, self.handle)
    }
}

/// An actor system: spawns actors and aggregates message metrics.
#[derive(Default)]
pub struct System {
    sent: Arc<AtomicU64>,
}

impl System {
    pub fn new() -> System {
        System { sent: Arc::new(AtomicU64::new(0)) }
    }

    /// Total messages sent through this system's addresses.
    pub fn messages_sent(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }

    /// Spawn a named actor. The body receives its mailbox and runs to
    /// completion; the returned [`Actor`] carries its address.
    pub fn spawn<M, T, F>(&self, name: &str, body: F) -> Actor<M, T>
    where
        M: Send + 'static,
        T: Send + 'static,
        F: FnOnce(Mailbox<M>) -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        let addr = Address { tx, sent: Arc::clone(&self.sent) };
        let name_owned = name.to_string();
        let handle = std::thread::Builder::new()
            .name(name_owned.clone())
            .spawn(move || body(Mailbox { rx }))
            .expect("spawn actor thread");
        Actor { addr, handle, name: name_owned }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn ping_pong() {
        let sys = System::new();
        let echo = sys.spawn::<(u32, Sender<u32>), _, _>("echo", |mb| {
            let mut count = 0;
            while let Some((x, reply)) = mb.recv() {
                let _ = reply.send(x + 1);
                count += 1;
            }
            count
        });
        let (tx, rx) = channel();
        for i in 0..10 {
            assert!(echo.addr.send((i, tx.clone())));
            assert_eq!(rx.recv().unwrap(), i + 1);
        }
        let (addr, handle) = echo.into_parts();
        drop(addr);
        drop(tx);
        assert_eq!(handle.join().unwrap(), 10);
    }

    #[test]
    fn send_to_dead_actor_returns_false() {
        let sys = System::new();
        let a = sys.spawn::<u32, _, _>("dies", |_mb| ());
        let (addr, handle) = a.into_parts();
        handle.join().unwrap();
        assert!(!addr.send(1));
    }

    #[test]
    fn message_counter_counts() {
        let sys = System::new();
        let sink = sys.spawn::<u32, _, _>("sink", |mb| {
            while mb.recv().is_some() {}
        });
        for i in 0..25 {
            sink.addr.send(i);
        }
        assert_eq!(sys.messages_sent(), 25);
        let (addr, handle) = sink.into_parts();
        drop(addr);
        handle.join().unwrap();
    }

    #[test]
    fn many_actors_parallel() {
        let sys = System::new();
        let actors: Vec<_> = (0..16)
            .map(|i| {
                sys.spawn::<u64, _, _>(&format!("w{i}"), move |mb| {
                    let mut acc = 0u64;
                    while let Some(x) = mb.recv() {
                        acc += x;
                    }
                    acc
                })
            })
            .collect();
        for a in &actors {
            for x in 1..=10u64 {
                a.addr.send(x);
            }
        }
        let total: u64 = actors
            .into_iter()
            .map(|a| {
                let (addr, handle) = a.into_parts();
                drop(addr);
                handle.join().unwrap()
            })
            .sum();
        assert_eq!(total, 16 * 55);
    }

    #[test]
    fn recv_batch_drains_fifo_in_bursts() {
        let sys = System::new();
        let sink = sys.spawn::<u32, Vec<u32>, _>("batcher", |mb| {
            let mut buf = Vec::new();
            let mut seen = Vec::new();
            let mut batches = 0u32;
            while mb.recv_batch(&mut buf, 4) > 0 {
                assert!(buf.len() <= 4);
                seen.extend(buf.drain(..));
                batches += 1;
            }
            assert!(batches <= seen.len() as u32);
            seen
        });
        for i in 0..25 {
            sink.addr.send(i);
        }
        let (addr, handle) = sink.into_parts();
        drop(addr);
        let seen = handle.join().unwrap();
        assert_eq!(seen, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn recv_timeout_expires() {
        let sys = System::new();
        let probe = sys.spawn::<u32, _, _>("probe", |mb| {
            mb.recv_timeout(Duration::from_millis(20)).is_none()
        });
        let (addr, handle) = probe.into_parts();
        drop(addr);
        assert!(handle.join().unwrap());
    }
}
