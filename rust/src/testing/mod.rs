//! In-repo property-testing helper (offline substitute for `proptest`).
//!
//! Runs a property over many seeded random cases; on failure it retries the
//! case with progressively "smaller" inputs when the generator supports
//! shrinking, and always reports the failing seed so the case can be
//! replayed deterministically:
//!
//! ```text
//! property failed (seed=0x1234abcd, case=17): <message>
//! ```
//!
//! Usage (no_run: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use actor_psp::testing::{property, Gen};
//! property("sample size within bounds", 200, |g| {
//!     let n = g.usize_in(1, 100);
//!     let k = g.usize_in(0, n);
//!     let mut rng = g.rng();
//!     let s = rng.sample_indices(n, k);
//!     assert_eq!(s.len(), k.min(n));
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case generator handle: draws sized random inputs from the case seed.
pub struct Gen {
    rng: Rng,
    seed: u64,
    /// Shrink level 0 = full-size inputs; higher levels shrink ranges.
    shrink: u32,
}

impl Gen {
    fn new(seed: u64, shrink: u32) -> Gen {
        Gen { rng: Rng::new(seed), seed, shrink }
    }

    /// The case seed (for logging in assertions).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// A fresh RNG derived from the case seed (for driving the SUT).
    pub fn rng(&mut self) -> Rng {
        self.rng.fork(0xC0FFEE)
    }

    /// usize in [lo, hi], range shrinks toward lo on failure retries.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let hi_eff = if self.shrink == 0 {
            hi
        } else {
            let span = (hi - lo) >> self.shrink;
            lo + span
        };
        lo + self.rng.next_below((hi_eff - lo + 1) as u64) as usize
    }

    /// u64 in [lo, hi].
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let hi_eff = if self.shrink == 0 {
            hi
        } else {
            lo + ((hi - lo) >> self.shrink)
        };
        self.rng.next_range(lo, hi_eff)
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Vector of f64s with length in [0, max_len].
    pub fn vec_f64(&mut self, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Choose uniformly from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }
}

/// Run `prop` over `cases` seeded cases. Panics (with seed) on first failure
/// after attempting shrink retries. The base seed can be overridden with
/// `ACTOR_PROP_SEED` for replay; case count with `ACTOR_PROP_CASES`.
pub fn property<F>(name: &str, cases: u32, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let base_seed = std::env::var("ACTOR_PROP_SEED")
        .ok()
        .and_then(|s| parse_seed(&s))
        .unwrap_or(0x5EED_0000);
    let cases = std::env::var("ACTOR_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);

    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let outcome = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 0);
            prop(&mut g);
        });
        if let Err(err) = outcome {
            // Try shrunk variants of the same seed to find a smaller repro.
            let mut smallest: Option<u32> = None;
            for shrink in (1..=4).rev() {
                let retry = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, shrink);
                    prop(&mut g);
                });
                if retry.is_err() {
                    smallest = Some(shrink);
                    break;
                }
            }
            let msg = panic_message(&err);
            match smallest {
                Some(s) => panic!(
                    "property '{name}' failed (seed={seed:#018x}, case={case}, \
                     also fails at shrink level {s}): {msg}"
                ),
                None => panic!(
                    "property '{name}' failed (seed={seed:#018x}, case={case}): {msg}"
                ),
            }
        }
    }
}

fn parse_seed(s: &str) -> Option<u64> {
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

fn panic_message(err: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = err.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = err.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::sync::atomic::AtomicU32::new(0);
        property("always true", 50, |g| {
            let _ = g.usize_in(0, 10);
            count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            property("always false", 10, |_g| {
                panic!("intentional");
            });
        });
        let msg = panic_message(&result.unwrap_err());
        assert!(msg.contains("seed="), "{msg}");
        assert!(msg.contains("intentional"), "{msg}");
    }

    #[test]
    fn gen_ranges_respected() {
        property("gen ranges", 100, |g| {
            let x = g.usize_in(3, 9);
            assert!((3..=9).contains(&x));
            let y = g.u64_in(100, 200);
            assert!((100..=200).contains(&y));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(99, 0);
        let mut b = Gen::new(99, 0);
        assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        assert_eq!(a.bool(), b.bool());
    }
}
