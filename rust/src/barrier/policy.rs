//! The one barrier-decision core every execution layer consults.
//!
//! Before this module existed, four layers each re-implemented admission
//! by hand (the simulator, the parameter server, the in-process p2p
//! engine and the deployed node) — all of them different spellings of
//! the same window predicate `my_step − min(view) ≤ θ`. [`BarrierPolicy`]
//! centralises that arithmetic behind two entry points:
//!
//! * [`BarrierPolicy::admit_min`] — for ∀-window methods
//!   ([`BarrierControl::min_view_sufficient`]), which only need the
//!   minimum of the observed view. Layers that can stream a min (the
//!   simulator's step tracker, the coordinator) stay O(1) per decision.
//! * [`BarrierPolicy::admit_view`] — for quorum-style methods that need
//!   the materialised sample; delegates to the live
//!   [`BarrierControl::can_advance`].
//!
//! The layers keep their own *view acquisition* (oracle tables, sampled
//! trackers, overlay gossip) — the paper's point is exactly that the
//! decision composes with any view source — but the decision itself now
//! has a single owner, pinned against [`super::decide_with_oracle`] by
//! the cross-layer equivalence suite in `rust/tests/barrier_properties.rs`.
//!
//! # Online adaptation (DSSP-style)
//!
//! Because every admission flows through the policy, it is also the one
//! place that can *observe* the barrier: per-crossing wait time, per-step
//! compute time, and the view-lag distribution. With an
//! [`AdaptiveConfig`] attached, the policy retunes its **effective**
//! staleness θ and sample size β online, following Dynamic SSP (Zhao et
//! al. 2019): when a large fraction of wall-clock time is spent blocked
//! at the barrier (flash-crowd stragglers), loosen; when waits are
//! cheap, tighten back toward fresh synchronisation. Decisions are
//! per-node and purely local — no consensus machinery, the same argument
//! the paper makes for fully-distributed PSP — and draw **no**
//! randomness, so an attached-but-never-fed controller (or
//! `adaptive = None`) leaves every RNG stream and golden trajectory
//! bit-identical.
//!
//! Which knobs move is method-dependent (ROADMAP item 3a):
//!
//! | method   | θ adapts | β adapts |
//! |----------|----------|----------|
//! | SSP      | yes      | —        |
//! | pSSP     | yes      | yes (when θ saturates) |
//! | pQuorum  | no (θ is part of the quorum predicate) | yes |
//! | BSP/ASP/pBSP | no — the method *is* its bound | no |
//!
//! Loosening grows θ multiplicatively (flash crowds need a fast
//! response) and only then sheds β (observe fewer peers, cutting control
//! traffic in the storm); tightening decays θ and then grows β back for
//! better tail coverage. All moves clamp to the configured bounds.
//!
//! Two triggers drive the controller, because a crossing-gated window
//! alone is frozen exactly when it most needs to move — a blocked node
//! stops crossing, so its window stops filling:
//!
//! 1. **Crossing window**: every `window` completed crossings, compare
//!    the blocked fraction of wall-clock against `loosen_above` /
//!    `tighten_below`.
//! 2. **Stall streak**: `window` *consecutive failed admissions* (the
//!    node is parked at the barrier, rechecking) are one immediate
//!    loosen — the ramp tracks the straggler gap while blocked, at the
//!    recheck/poll cadence every engine already has.

use super::{BarrierControl, Method, ViewRequirement};

/// Bounds and cadence for the online controller. Attach one to a
/// [`BarrierPolicy`] via [`BarrierPolicy::with_adaptive`] to enable
/// adaptation; `None` keeps the policy bit-identical to the static
/// method it wraps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveConfig {
    /// Barrier crossings per adaptation round: the controller looks at
    /// the wait/compute ratio over this many completed steps, then
    /// decides. Doubles as the stall-streak length — this many
    /// *consecutive failed admissions* loosen immediately, so a blocked
    /// node keeps adapting while it cannot cross. Small windows react
    /// faster to flash crowds; large ones smooth diurnal noise.
    pub window: u32,
    /// Fraction of window wall-clock spent blocked above which the
    /// policy loosens (θ up, then β down).
    pub loosen_above: f64,
    /// Fraction below which it tightens (θ down, then β up).
    pub tighten_below: f64,
    pub min_staleness: u64,
    pub max_staleness: u64,
    pub min_sample: usize,
    pub max_sample: usize,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            window: 8,
            loosen_above: 0.20,
            tighten_below: 0.05,
            min_staleness: 0,
            max_staleness: 64,
            min_sample: 1,
            max_sample: 64,
        }
    }
}

impl AdaptiveConfig {
    /// Clamp the bounds into a usable shape: `min ≤ max`, a sample of at
    /// least 1 (β = 0 would silently become ASP), a window of at least 1.
    pub fn normalized(mut self) -> AdaptiveConfig {
        self.window = self.window.max(1);
        self.min_sample = self.min_sample.max(1);
        self.max_staleness = self.max_staleness.max(self.min_staleness);
        self.max_sample = self.max_sample.max(self.min_sample);
        self
    }
}

/// Lifetime barrier observations, kept by every policy (adaptive or
/// not). `barrier_waits`/`stall_ticks` are the unified counters all
/// engines now report: a *wait* is a crossing that blocked at least
/// once, a *stall tick* is one failed admission evaluation (the
/// event-driven simulator parks global-view nodes instead of polling,
/// so its ticks count park episodes; the polling engines count polls).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BarrierStats {
    /// Completed barrier crossings observed via `record_crossing`.
    pub crossings: u64,
    /// Crossings that blocked (wait > 0) before passing.
    pub barrier_waits: u64,
    /// Failed admission evaluations observed via `record_decision`.
    pub stall_ticks: u64,
    /// Seconds spent blocked at the barrier, summed over crossings.
    pub wait_secs: f64,
    /// Seconds spent computing, summed over crossings.
    pub busy_secs: f64,
    /// View-lag distribution (my_step − min observed view) over all
    /// recorded decisions: running sum, count and max.
    pub lag_sum: u64,
    pub lag_count: u64,
    pub lag_max: u64,
}

impl BarrierStats {
    /// Mean view lag over every recorded decision (0 when none).
    pub fn mean_lag(&self) -> f64 {
        if self.lag_count == 0 {
            0.0
        } else {
            self.lag_sum as f64 / self.lag_count as f64
        }
    }
}

/// The per-window accumulator + knob-selection state of the controller.
#[derive(Debug, Clone, Copy)]
struct AdaptiveState {
    cfg: AdaptiveConfig,
    theta_adapts: bool,
    beta_adapts: bool,
    win_crossings: u32,
    win_wait: f64,
    win_busy: f64,
    /// Consecutive failed admissions since the last pass — the
    /// *while-blocked* loosening trigger (see [`BarrierPolicy::record_decision`]).
    win_fails: u32,
    retunes: u64,
}

/// A live barrier-decision handle: the configured [`Method`], its built
/// [`BarrierControl`], the effective (possibly adapted) θ/β, and the
/// observation window. See the module docs for the full story.
pub struct BarrierPolicy {
    base: Method,
    control: Box<dyn BarrierControl>,
    eff_staleness: u64,
    eff_sample: usize,
    adaptive: Option<AdaptiveState>,
    stats: BarrierStats,
}

impl std::fmt::Debug for BarrierPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BarrierPolicy")
            .field("base", &self.base)
            .field("eff_staleness", &self.eff_staleness)
            .field("eff_sample", &self.eff_sample)
            .field("adaptive", &self.adaptive.is_some())
            .field("stats", &self.stats)
            .finish()
    }
}

impl Clone for BarrierPolicy {
    fn clone(&self) -> Self {
        BarrierPolicy {
            base: self.base,
            control: self.base.build(),
            eff_staleness: self.eff_staleness,
            eff_sample: self.eff_sample,
            adaptive: self.adaptive,
            stats: self.stats,
        }
    }
}

impl BarrierPolicy {
    /// A static policy: replays the wrapped method's decisions
    /// bit-identically and only keeps counters.
    pub fn new(method: Method) -> BarrierPolicy {
        BarrierPolicy::with_adaptive(method, None)
    }

    /// A policy with an optional online controller. `None` == `new`.
    pub fn with_adaptive(
        method: Method,
        adaptive: Option<AdaptiveConfig>,
    ) -> BarrierPolicy {
        let control = method.build();
        let eff_staleness = control.staleness();
        let eff_sample = match control.view() {
            ViewRequirement::Sample(beta) => beta,
            _ => 0,
        };
        let (theta_adapts, beta_adapts) = match method {
            Method::Ssp { .. } => (true, false),
            Method::Pssp { .. } => (true, true),
            Method::Pquorum { .. } => (false, true),
            Method::Bsp | Method::Asp | Method::Pbsp { .. } => (false, false),
        };
        let adaptive = adaptive
            .filter(|_| theta_adapts || beta_adapts)
            .map(|cfg| AdaptiveState {
                cfg: cfg.normalized(),
                theta_adapts,
                beta_adapts,
                win_crossings: 0,
                win_wait: 0.0,
                win_busy: 0.0,
                win_fails: 0,
                retunes: 0,
            });
        let mut policy = BarrierPolicy {
            base: method,
            control,
            eff_staleness,
            eff_sample,
            adaptive,
            stats: BarrierStats::default(),
        };
        // Start inside the configured bounds so the first window does not
        // have to walk a far-out-of-range starting point home.
        if let Some(st) = policy.adaptive {
            if st.theta_adapts {
                policy.eff_staleness = policy
                    .eff_staleness
                    .clamp(st.cfg.min_staleness, st.cfg.max_staleness);
            }
            if st.beta_adapts {
                policy.eff_sample =
                    policy.eff_sample.clamp(st.cfg.min_sample, st.cfg.max_sample);
            }
        }
        policy
    }

    /// The method this policy was configured with.
    pub fn base(&self) -> Method {
        self.base
    }

    /// The method currently in force: the base with the adapted
    /// effective θ/β substituted in. Equal to `base()` while adaptation
    /// is off or has not moved anything.
    pub fn effective(&self) -> Method {
        match self.base {
            Method::Ssp { .. } => Method::Ssp { staleness: self.eff_staleness },
            Method::Pbsp { .. } => Method::Pbsp { sample: self.eff_sample },
            Method::Pssp { .. } => Method::Pssp {
                sample: self.eff_sample,
                staleness: self.eff_staleness,
            },
            Method::Pquorum { staleness, quorum_pct, .. } => Method::Pquorum {
                sample: self.eff_sample,
                staleness,
                quorum_pct,
            },
            m => m,
        }
    }

    pub fn is_adaptive(&self) -> bool {
        self.adaptive.is_some()
    }

    pub fn name(&self) -> &'static str {
        self.control.name()
    }

    /// The view to acquire for the next decision — with the *effective*
    /// sample size for the PSP family.
    pub fn view(&self) -> ViewRequirement {
        match self.control.view() {
            ViewRequirement::Sample(_) => ViewRequirement::Sample(self.eff_sample),
            v => v,
        }
    }

    /// The effective staleness bound (θ for SSP-like, 0 for BSP-like,
    /// `u64::MAX` for ASP).
    pub fn staleness(&self) -> u64 {
        self.eff_staleness
    }

    /// The effective sample size β (0 for global/no-view methods).
    pub fn sample_size(&self) -> usize {
        self.eff_sample
    }

    pub fn min_view_sufficient(&self) -> bool {
        self.control.min_view_sufficient()
    }

    /// ∀-window admission from a streamed view minimum. `None` means the
    /// view was empty (β = 0, or every peer departed) — an empty view
    /// never blocks, exactly as `can_advance(_, &[])` never blocks.
    ///
    /// This is the one spelling of the predicate the whole system uses:
    /// `my_step − min ≤ θ` in saturating arithmetic. It is value-equal
    /// to every legacy inline form (`min + θ ≥ my_step`,
    /// `(step+1) − sⱼ ≤ θ` over all j, ...) — pinned by the equivalence
    /// suite — and overflow-safe where `min + θ` was not.
    pub fn admit_min(&self, my_step: u64, min_view: Option<u64>) -> bool {
        match min_view {
            None => true,
            Some(m) => my_step.saturating_sub(m) <= self.eff_staleness,
        }
    }

    /// Admission over a materialised view. ∀-window methods reduce to
    /// [`Self::admit_min`] (same decision, same effective θ);
    /// quorum-style methods delegate to the live control's
    /// `can_advance`, which owns the quorum-fraction predicate.
    pub fn admit_view(&self, my_step: u64, view: &[u64]) -> bool {
        if view.is_empty() {
            return true;
        }
        if self.control.min_view_sufficient() {
            self.admit_min(my_step, view.iter().min().copied())
        } else {
            self.control.can_advance(my_step, view)
        }
    }

    /// Record one admission evaluation: whether it passed, and the
    /// observed view lag (`my_step − min(view)`, `None` when the method
    /// needed no view). Failed evaluations are the `stall_ticks` counter.
    pub fn record_decision(&mut self, passed: bool, lag: Option<u64>) {
        if !passed {
            self.stats.stall_ticks += 1;
        }
        if let Some(l) = lag {
            self.stats.lag_sum += l;
            self.stats.lag_count += 1;
            self.stats.lag_max = self.stats.lag_max.max(l);
        }
        // Loosen *while* blocked: `window` consecutive failed admissions
        // mean the bound is too tight right now. A purely crossing-gated
        // controller is frozen exactly when it most needs to move — a
        // blocked node stops crossing, so its window stops filling — but
        // failed admissions keep ticking at the recheck/poll cadence and
        // are just as observable locally.
        let Some(st) = self.adaptive.as_mut() else { return };
        if passed {
            st.win_fails = 0;
        } else {
            st.win_fails += 1;
            if st.win_fails >= st.cfg.window {
                st.win_fails = 0;
                st.retunes += 1;
                self.loosen();
            }
        }
    }

    /// Record a completed barrier crossing: `wait_secs` blocked at the
    /// barrier (0 when admitted first try) and `busy_secs` of compute
    /// for the step. Drives the adaptation window; retunes at window
    /// boundaries when a controller is attached. Never draws randomness.
    pub fn record_crossing(&mut self, wait_secs: f64, busy_secs: f64) {
        self.stats.crossings += 1;
        if wait_secs > 0.0 {
            self.stats.barrier_waits += 1;
        }
        self.stats.wait_secs += wait_secs;
        self.stats.busy_secs += busy_secs;
        let Some(st) = self.adaptive.as_mut() else { return };
        st.win_crossings += 1;
        st.win_wait += wait_secs.max(0.0);
        st.win_busy += busy_secs.max(0.0);
        if st.win_crossings >= st.cfg.window {
            self.retune();
        }
    }

    /// Lifetime observation counters.
    pub fn stats(&self) -> &BarrierStats {
        &self.stats
    }

    /// How many adaptation rounds have fired (0 when static).
    pub fn retunes(&self) -> u64 {
        self.adaptive.map_or(0, |st| st.retunes)
    }

    /// One DSSP-style controller step over the finished window.
    fn retune(&mut self) {
        let Some(st) = self.adaptive.as_mut() else { return };
        let total = st.win_wait + st.win_busy;
        let frac = if total > 0.0 { st.win_wait / total } else { 0.0 };
        let cfg = st.cfg;
        st.win_crossings = 0;
        st.win_wait = 0.0;
        st.win_busy = 0.0;
        st.retunes += 1;
        if frac > cfg.loosen_above {
            self.loosen();
        } else if frac < cfg.tighten_below {
            self.tighten();
        }
    }

    /// Waits dominate: a straggler storm. Open the window fast
    /// (multiplicative growth), and once θ is pegged, observe fewer
    /// peers — each probe of a storm costs messages and is likely to
    /// hit a straggler anyway.
    fn loosen(&mut self) {
        let Some(st) = self.adaptive.as_ref() else { return };
        let (cfg, theta_adapts, beta_adapts) =
            (st.cfg, st.theta_adapts, st.beta_adapts);
        if theta_adapts && self.eff_staleness < cfg.max_staleness {
            let grown = self.eff_staleness + 1 + self.eff_staleness / 2;
            self.eff_staleness = grown.min(cfg.max_staleness);
        } else if beta_adapts && self.eff_sample > cfg.min_sample {
            self.eff_sample -= 1;
        }
    }

    /// Waits are cheap: claw freshness back. Decay θ (gentler than the
    /// growth — AIMD), then widen the sample again for better
    /// straggler-tail coverage.
    fn tighten(&mut self) {
        let Some(st) = self.adaptive.as_ref() else { return };
        let (cfg, theta_adapts, beta_adapts) =
            (st.cfg, st.theta_adapts, st.beta_adapts);
        if theta_adapts && self.eff_staleness > cfg.min_staleness {
            let cut = 1 + self.eff_staleness / 4;
            self.eff_staleness =
                self.eff_staleness.saturating_sub(cut).max(cfg.min_staleness);
        } else if beta_adapts && self.eff_sample < cfg.max_sample {
            self.eff_sample += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::decide_with_oracle;
    use super::*;
    use crate::testing::property;

    #[test]
    fn static_policy_matches_legacy_predicates() {
        // Every inline form the engines used to hand-roll, against the
        // policy's one spelling.
        let ssp = BarrierPolicy::new(Method::Ssp { staleness: 4 });
        for (my, min) in [(0u64, 0u64), (5, 1), (6, 1), (9, 5), (10, 5), (3, 7)] {
            // paramserver coordinator / sim tracker form: min + θ >= my
            assert_eq!(ssp.admit_min(my, Some(min)), min + 4 >= my, "{my} {min}");
            // p2p worker form: my.saturating_sub(s) <= θ for the min peer
            assert_eq!(
                ssp.admit_min(my, Some(min)),
                my.saturating_sub(min) <= 4,
            );
        }
        let bsp = BarrierPolicy::new(Method::Bsp);
        assert!(bsp.admit_min(3, Some(3)));
        assert!(!bsp.admit_min(3, Some(2)));
        let asp = BarrierPolicy::new(Method::Asp);
        assert!(asp.admit_min(u64::MAX, Some(0)));
        // Empty views never block, for any method.
        assert!(bsp.admit_min(10, None));
        assert!(bsp.admit_view(10, &[]));
    }

    #[test]
    fn admit_view_matches_decide_with_oracle_for_all_six_methods() {
        // The policy must agree with the centralised oracle decision for
        // any view the oracle could have sampled.
        property("policy == decide_with_oracle", 300, |g| {
            let methods = [
                Method::Bsp,
                Method::Asp,
                Method::Ssp { staleness: g.u64_in(0, 6) },
                Method::Pbsp { sample: g.usize_in(1, 16) },
                Method::Pssp { sample: g.usize_in(1, 16), staleness: g.u64_in(0, 6) },
                Method::Pquorum {
                    sample: g.usize_in(1, 16),
                    staleness: g.u64_in(0, 6),
                    quorum_pct: g.u64_in(0, 100) as u8,
                },
            ];
            let method = *g.choose(&methods);
            let n = g.usize_in(1, 48);
            let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, 12)).collect();
            let my = g.u64_in(0, 12);
            let policy = BarrierPolicy::new(method);
            let control = method.build();
            // Drive both deciders over the same sampled view.
            let mut rng = g.rng();
            let mut scratch = Vec::new();
            let oracle =
                decide_with_oracle(&*control, my, &steps, &mut rng, &mut scratch);
            // Re-draw the identical sample for the policy side.
            let mut rng2 = g.rng();
            let mine = match policy.view() {
                ViewRequirement::None => policy.admit_view(my, &[]),
                ViewRequirement::Global => policy.admit_view(my, &steps),
                ViewRequirement::Sample(beta) => {
                    let mut idx = Vec::new();
                    rng2.sample_into(steps.len(), beta, &mut idx);
                    let view: Vec<u64> =
                        idx.iter().map(|&i| steps[i]).collect();
                    policy.admit_view(my, &view)
                }
            };
            assert_eq!(mine, oracle, "{method:?} my={my} steps={steps:?}");
        });
    }

    #[test]
    fn quorum_boundary_follows_the_trait_not_integer_pct_arithmetic() {
        // 4-of-5 at 80%: exactly on the quorum — the float predicate
        // (with its 1e-12 slack) admits. This is the canonical decision
        // node.rs used to approximate with integer-percent arithmetic.
        let p = BarrierPolicy::new(Method::Pquorum {
            sample: 5,
            staleness: 0,
            quorum_pct: 80,
        });
        assert!(p.admit_view(3, &[3, 3, 3, 3, 0]));
        assert!(!p.admit_view(3, &[3, 3, 3, 0, 0]));
        assert!(!p.min_view_sufficient());
    }

    #[test]
    fn static_policy_never_moves_and_counts_faithfully() {
        let mut p = BarrierPolicy::new(Method::Pssp { sample: 10, staleness: 4 });
        for _ in 0..100 {
            p.record_decision(false, Some(7));
            p.record_decision(true, Some(2));
            p.record_crossing(3.0, 1.0); // waits dominate — would loosen
        }
        assert_eq!(p.effective(), p.base());
        assert_eq!(p.retunes(), 0);
        assert_eq!(p.stats().crossings, 100);
        assert_eq!(p.stats().barrier_waits, 100);
        assert_eq!(p.stats().stall_ticks, 100);
        assert_eq!(p.stats().lag_max, 7);
        assert_eq!(p.stats().lag_count, 200);
        // Waits with zero duration are crossings, not barrier_waits.
        p.record_crossing(0.0, 1.0);
        assert_eq!(p.stats().crossings, 101);
        assert_eq!(p.stats().barrier_waits, 100);
    }

    #[test]
    fn adaptive_pssp_loosens_then_tightens_within_bounds() {
        let cfg = AdaptiveConfig {
            window: 4,
            max_staleness: 16,
            min_sample: 2,
            max_sample: 12,
            ..AdaptiveConfig::default()
        };
        let mut p = BarrierPolicy::with_adaptive(
            Method::Pssp { sample: 10, staleness: 2 },
            Some(cfg),
        );
        // Storm: waits dominate every window → θ grows to its cap, then
        // β starts shedding.
        for _ in 0..200 {
            p.record_crossing(5.0, 1.0);
        }
        assert_eq!(p.staleness(), 16, "θ should peg at max under a storm");
        assert_eq!(p.sample_size(), 2, "β should shed once θ is pegged");
        assert!(p.retunes() >= 2);
        match p.effective() {
            Method::Pssp { sample, staleness } => {
                assert_eq!((sample, staleness), (2, 16));
            }
            m => panic!("effective method changed shape: {m:?}"),
        }
        // Calm: waits vanish → θ decays home, β recovers to its cap.
        for _ in 0..400 {
            p.record_crossing(0.0, 1.0);
        }
        assert_eq!(p.staleness(), 0);
        assert_eq!(p.sample_size(), 12);
        // The view advertises the *effective* β.
        assert_eq!(p.view(), ViewRequirement::Sample(12));
    }

    #[test]
    fn consecutive_failed_admissions_loosen_while_blocked() {
        // A blocked node stops crossing, so the crossing window freezes —
        // the stall path must still move θ. `window` consecutive failed
        // admissions are one loosen; any pass resets the streak.
        let cfg = AdaptiveConfig {
            window: 4,
            max_staleness: 512,
            ..AdaptiveConfig::default()
        };
        let mut p = BarrierPolicy::with_adaptive(
            Method::Pssp { sample: 10, staleness: 4 },
            Some(cfg),
        );
        // Three fails then a pass: streak broken, nothing moves.
        for _ in 0..3 {
            p.record_decision(false, Some(9));
        }
        p.record_decision(true, Some(0));
        assert_eq!(p.staleness(), 4);
        assert_eq!(p.retunes(), 0);
        // Four consecutive fails: one loosen (4 → 4 + 1 + 4/2 = 7).
        for _ in 0..4 {
            p.record_decision(false, Some(9));
        }
        assert_eq!(p.staleness(), 7);
        assert_eq!(p.retunes(), 1);
        // Stay blocked: the ramp keeps tracking the gap, capped at max.
        for _ in 0..4000 {
            p.record_decision(false, Some(9));
        }
        assert_eq!(p.staleness(), 512);
        assert_eq!(p.stats().stall_ticks, 3 + 4 + 4000);
    }

    #[test]
    fn adaptation_moves_theta_only_for_ssp_and_beta_only_for_pquorum() {
        let cfg = AdaptiveConfig { window: 2, ..AdaptiveConfig::default() };
        let mut ssp = BarrierPolicy::with_adaptive(
            Method::Ssp { staleness: 1 },
            Some(cfg),
        );
        let mut quorum = BarrierPolicy::with_adaptive(
            Method::Pquorum { sample: 10, staleness: 4, quorum_pct: 80 },
            Some(cfg),
        );
        for _ in 0..50 {
            ssp.record_crossing(5.0, 1.0);
            quorum.record_crossing(5.0, 1.0);
        }
        assert!(ssp.staleness() > 1);
        assert_eq!(ssp.sample_size(), 0, "SSP has no sample to adapt");
        assert_eq!(quorum.staleness(), 4, "quorum θ is part of its predicate");
        assert!(quorum.sample_size() < 10, "quorum sheds β under a storm");
        match quorum.effective() {
            Method::Pquorum { staleness, quorum_pct, .. } => {
                assert_eq!((staleness, quorum_pct), (4, 80));
            }
            m => panic!("effective method changed shape: {m:?}"),
        }
    }

    #[test]
    fn bsp_asp_pbsp_never_adapt_even_when_asked() {
        for m in [Method::Bsp, Method::Asp, Method::Pbsp { sample: 5 }] {
            let mut p = BarrierPolicy::with_adaptive(
                m,
                Some(AdaptiveConfig { window: 1, ..AdaptiveConfig::default() }),
            );
            assert!(!p.is_adaptive(), "{m:?} has no adaptable knobs");
            for _ in 0..20 {
                p.record_crossing(9.0, 1.0);
            }
            assert_eq!(p.effective(), m);
        }
    }

    #[test]
    fn normalized_config_repairs_degenerate_bounds() {
        let cfg = AdaptiveConfig {
            window: 0,
            min_sample: 0,
            max_sample: 0,
            min_staleness: 9,
            max_staleness: 3,
            ..AdaptiveConfig::default()
        }
        .normalized();
        assert_eq!(cfg.window, 1);
        assert_eq!(cfg.min_sample, 1);
        assert!(cfg.max_sample >= cfg.min_sample);
        assert!(cfg.max_staleness >= cfg.min_staleness);
    }

    #[test]
    fn prop_admit_min_equals_all_peer_window_form() {
        // The p2p engine's legacy ∀-peer spelling reduces to the min
        // spelling: every peer passes iff the slowest one does.
        property("∀-peer window == min window", 200, |g| {
            let theta = g.u64_in(0, 8);
            let p = BarrierPolicy::new(Method::Pssp { sample: 3, staleness: theta });
            let n = g.usize_in(1, 32);
            let view: Vec<u64> = (0..n).map(|_| g.u64_in(0, 20)).collect();
            let my = g.u64_in(0, 20);
            let all_form = view.iter().all(|&s| my.saturating_sub(s) <= theta);
            assert_eq!(p.admit_min(my, view.iter().min().copied()), all_form);
            assert_eq!(p.admit_view(my, &view), all_form);
        });
    }
}
