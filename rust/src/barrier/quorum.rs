//! Quorum-PSP — the §3.2 generalisation the paper sketches but does not
//! evaluate: *“a node can choose to either pass the barrier by advancing
//! its local step if a given threshold has been reached”*.
//!
//! Instead of requiring **every** sampled peer to be within the staleness
//! window (pSSP's ∀-quantifier), `PQuorum(β, θ, q)` advances when at
//! least a fraction `q` of the sampled peers are within θ:
//!
//! * q = 1.0 → exactly pSSP(β, θ);
//! * q = 0.0 → ASP;
//! * intermediate q trades straggler-tail tolerance against update noise
//!   one knob finer than the β/θ pair alone.
//!
//! Evaluated by the `ablation` experiment (`actor exp abl_quorum`).

use super::{BarrierControl, ViewRequirement};

/// Quorum-threshold probabilistic barrier.
#[derive(Debug, Clone, Copy)]
pub struct PQuorum {
    sample_size: usize,
    staleness: u64,
    /// Required fraction of the sample within the window, in [0, 1].
    quorum: f64,
}

impl PQuorum {
    pub fn new(sample_size: usize, staleness: u64, quorum: f64) -> PQuorum {
        assert!((0.0..=1.0).contains(&quorum), "quorum must be in [0,1]");
        PQuorum { sample_size, staleness, quorum }
    }

    pub fn quorum(&self) -> f64 {
        self.quorum
    }
}

impl BarrierControl for PQuorum {
    fn name(&self) -> &'static str {
        "pquorum"
    }

    fn view(&self) -> ViewRequirement {
        if self.sample_size == 0 || self.quorum == 0.0 {
            ViewRequirement::None
        } else {
            ViewRequirement::Sample(self.sample_size)
        }
    }

    fn can_advance(&self, my_step: u64, view: &[u64]) -> bool {
        if view.is_empty() {
            return true;
        }
        let within = view
            .iter()
            .filter(|&&s| my_step.saturating_sub(s) <= self.staleness)
            .count();
        (within as f64) >= self.quorum * view.len() as f64 - 1e-12
    }

    fn staleness(&self) -> u64 {
        // For the simulator's release index the *guaranteed* bound only
        // exists at q = 1; weaker quorums behave like a looser window.
        self.staleness
    }

    fn min_view_sufficient(&self) -> bool {
        false // needs the count within the window, not just the minimum
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::barrier::Ssp;
    use crate::testing::property;

    #[test]
    fn quorum_one_equals_pssp_predicate() {
        property("PQuorum(q=1) == SSP predicate", 200, |g| {
            let n = g.usize_in(1, 32);
            let staleness = g.u64_in(0, 5);
            let view: Vec<u64> = (0..n).map(|_| g.u64_in(0, 15)).collect();
            let my = g.u64_in(0, 15);
            let q = PQuorum::new(n, staleness, 1.0);
            let ssp = Ssp::new(staleness);
            assert_eq!(q.can_advance(my, &view), ssp.can_advance(my, &view));
        });
    }

    #[test]
    fn quorum_zero_is_asp() {
        let q = PQuorum::new(5, 0, 0.0);
        assert_eq!(q.view(), ViewRequirement::None);
        assert!(q.can_advance(100, &[0, 0, 0]));
    }

    #[test]
    fn half_quorum_tolerates_half_the_stragglers() {
        let q = PQuorum::new(4, 0, 0.5);
        // 2 of 4 peers at my step: exactly quorum
        assert!(q.can_advance(5, &[5, 5, 0, 0]));
        // 1 of 4: below quorum
        assert!(!q.can_advance(5, &[5, 0, 0, 0]));
    }

    #[test]
    fn prop_monotone_in_quorum() {
        property("stricter quorum never unblocks", 200, |g| {
            let n = g.usize_in(1, 20);
            let staleness = g.u64_in(0, 4);
            let view: Vec<u64> = (0..n).map(|_| g.u64_in(0, 10)).collect();
            let my = g.u64_in(0, 10);
            let q1 = g.f64_in(0.0, 1.0);
            let q2 = (q1 + g.f64_in(0.0, 1.0 - q1)).min(1.0);
            let loose = PQuorum::new(n, staleness, q1);
            let strict = PQuorum::new(n, staleness, q2);
            if strict.can_advance(my, &view) {
                assert!(
                    loose.can_advance(my, &view),
                    "q={q2} passed but q={q1} blocked"
                );
            }
        });
    }

    #[test]
    fn empty_view_always_passes() {
        assert!(PQuorum::new(3, 2, 0.9).can_advance(7, &[]));
    }
}
