//! Asynchronous Parallel — paper eq. (3): no synchronisation at all.

use super::{BarrierControl, ViewRequirement};

/// ASP: always advance (`⊤`). Fastest iteration rate, no consistency — the
/// noisy end of the paper's trade-off spectrum (highest error sensitivity
/// to stragglers, Fig 2b).
#[derive(Debug, Clone, Copy, Default)]
pub struct Asp;

impl BarrierControl for Asp {
    fn name(&self) -> &'static str {
        "asp"
    }

    fn view(&self) -> ViewRequirement {
        ViewRequirement::None
    }

    fn can_advance(&self, _my_step: u64, _view: &[u64]) -> bool {
        true
    }

    fn staleness(&self) -> u64 {
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_blocks() {
        assert!(Asp.can_advance(0, &[]));
        assert!(Asp.can_advance(5, &[0, 0, 0]));
        assert!(Asp.can_advance(u64::MAX, &[0]));
    }

    #[test]
    fn requires_no_view() {
        assert_eq!(Asp.view(), ViewRequirement::None);
    }
}
