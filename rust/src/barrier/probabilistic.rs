//! The PSP composition: `pX = X ∘ sample(β)` — paper §4.2 / §6.1.
//!
//! [`Probabilistic`] wraps *any* [`BarrierControl`] and changes only its
//! view requirement from Global to Sample(β). The decision predicate is
//! untouched — exactly the paper's claim that "almost nothing needs to be
//! changed in the aforementioned algorithms except that only the sampled
//! states instead of the global states are passed into the barrier
//! function".

use super::{BarrierControl, ViewRequirement};

/// A barrier method composed with the sampling primitive.
///
/// `Probabilistic::new(Bsp, β)` is pBSP(β); `Probabilistic::new(Ssp::new(θ), β)`
/// is pSSP(β, θ). Any future barrier composes the same way.
#[derive(Debug, Clone, Copy)]
pub struct Probabilistic<B> {
    inner: B,
    sample_size: usize,
}

impl<B: BarrierControl> Probabilistic<B> {
    pub fn new(inner: B, sample_size: usize) -> Self {
        Probabilistic { inner, sample_size }
    }

    pub fn sample_size(&self) -> usize {
        self.sample_size
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }
}

impl<B: BarrierControl> BarrierControl for Probabilistic<B> {
    fn name(&self) -> &'static str {
        // Fixed names for the two standard compositions; anything else is
        // reported generically.
        match self.inner.name() {
            "bsp" => "pbsp",
            "ssp" => "pssp",
            _ => "psp",
        }
    }

    fn view(&self) -> ViewRequirement {
        if self.sample_size == 0 {
            // S = ∅ reduces to ASP (paper §6.1): no view needed at all.
            ViewRequirement::None
        } else {
            ViewRequirement::Sample(self.sample_size)
        }
    }

    fn can_advance(&self, my_step: u64, view: &[u64]) -> bool {
        // Same predicate, sampled view. An empty sample (β=0 or a 1-node
        // system) always passes — the inner predicates are ∀-quantified.
        self.inner.can_advance(my_step, view)
    }

    fn staleness(&self) -> u64 {
        self.inner.staleness()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::barrier::{Bsp, Ssp};

    #[test]
    fn names_follow_composition() {
        assert_eq!(Probabilistic::new(Bsp, 4).name(), "pbsp");
        assert_eq!(Probabilistic::new(Ssp::new(2), 4).name(), "pssp");
    }

    #[test]
    fn zero_sample_requires_no_view() {
        assert_eq!(Probabilistic::new(Bsp, 0).view(), ViewRequirement::None);
        assert_eq!(
            Probabilistic::new(Bsp, 7).view(),
            ViewRequirement::Sample(7)
        );
    }

    #[test]
    fn predicate_matches_inner_on_same_view() {
        let view = [3u64, 5, 2];
        for my in 0..8 {
            assert_eq!(
                Probabilistic::new(Ssp::new(2), 3).can_advance(my, &view),
                Ssp::new(2).can_advance(my, &view),
            );
        }
    }

    #[test]
    fn staleness_passthrough() {
        assert_eq!(Probabilistic::new(Ssp::new(9), 3).staleness(), 9);
        assert_eq!(Probabilistic::new(Bsp, 3).staleness(), 0);
    }
}
