//! Stale Synchronous Parallel (Ho et al. 2013) — paper Algorithm 2 / eq. (2).

use super::{BarrierControl, ViewRequirement};

/// SSP(θ): a worker may advance while no observed peer lags more than θ
/// steps behind it (`∀j: s − sⱼ ≤ θ`).
///
/// θ = 0 is exactly [`super::Bsp`]; θ = ∞ (`u64::MAX`) is [`super::Asp`] —
/// the generalisation the paper's §6.1 lattice describes, and which the
/// property tests assert.
#[derive(Debug, Clone, Copy)]
pub struct Ssp {
    staleness: u64,
}

impl Ssp {
    pub fn new(staleness: u64) -> Ssp {
        Ssp { staleness }
    }
}

impl BarrierControl for Ssp {
    fn name(&self) -> &'static str {
        "ssp"
    }

    fn view(&self) -> ViewRequirement {
        ViewRequirement::Global
    }

    fn can_advance(&self, my_step: u64, view: &[u64]) -> bool {
        view.iter()
            .all(|&s| my_step.saturating_sub(s) <= self.staleness)
    }

    fn staleness(&self) -> u64 {
        self.staleness
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::barrier::Bsp;
    use crate::testing::property;

    #[test]
    fn staleness_window() {
        let s = Ssp::new(3);
        assert!(s.can_advance(3, &[0]));   // lag exactly 3
        assert!(!s.can_advance(4, &[0]));  // lag 4
        assert!(s.can_advance(0, &[10]));  // behind, never blocked
    }

    #[test]
    fn zero_staleness_is_bsp() {
        property("SSP(0) == BSP", 200, |g| {
            let n = g.usize_in(0, 32);
            let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, 12)).collect();
            let my = g.u64_in(0, 12);
            assert_eq!(
                Ssp::new(0).can_advance(my, &steps),
                Bsp.can_advance(my, &steps)
            );
        });
    }

    #[test]
    fn infinite_staleness_is_asp() {
        property("SSP(inf) == ASP", 100, |g| {
            let n = g.usize_in(0, 32);
            let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, u64::MAX / 2)).collect();
            let my = g.u64_in(0, u64::MAX / 2);
            assert!(Ssp::new(u64::MAX).can_advance(my, &steps));
        });
    }

    #[test]
    fn no_underflow_on_behind_workers() {
        // my_step < peer step must not underflow the lag computation.
        assert!(Ssp::new(0).can_advance(0, &[u64::MAX]));
    }
}
