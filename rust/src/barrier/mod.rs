//! Barrier control — the paper's core subject.
//!
//! A *barrier control method* decides whether a worker that has finished
//! computing step `s` may advance to step `s+1`, given a **view** of peer
//! steps. The five methods of the paper (§6.1):
//!
//! | method | predicate over the view | view |
//! |--------|--------------------------|------|
//! | BSP    | ∀j: sⱼ ≥ s               | global |
//! | SSP(θ) | ∀j: s − sⱼ ≤ θ           | global |
//! | ASP    | ⊤                        | none  |
//! | pBSP(β)   | ∀j∈S: sⱼ ≥ s          | sample of β |
//! | pSSP(β,θ) | ∀j∈S: s − sⱼ ≤ θ      | sample of β |
//!
//! All five reduce to one predicate — `min(view) + staleness ≥ s` — so the
//! probabilistic variants are literally the classic ones composed with the
//! **sampling primitive** ([`crate::sampling`]): `pX = X ∘ sample(β)`.
//! That composition is expressed by [`Probabilistic`], mirroring the
//! paper's claim that sampling composes with *any* existing barrier.
//!
//! Execution layers do not evaluate these predicates by hand: they go
//! through [`policy::BarrierPolicy`], the single admission core (which
//! is also where DSSP-style online adaptation of θ/β lives). The
//! centralised-oracle decision path is [`decide_with_oracle`], used as
//! the cross-layer equivalence oracle in tests.
//!
//! The generalisation lattice (paper §6.1) is tested as properties in
//! `barrier::tests` and `rust/tests/barrier_properties.rs`:
//!
//! * `pBSP(β≥P) = BSP`, `pBSP(0) = ASP`
//! * `pSSP(β, 0) = pBSP(β)`, `SSP(0) = BSP`, `SSP(∞) = ASP`
//! * `pSSP(β≥P, θ) = SSP(θ)`

mod asp;
mod bsp;
pub mod policy;
mod probabilistic;
mod quorum;
mod ssp;

pub use asp::Asp;
pub use bsp::Bsp;
pub use policy::{AdaptiveConfig, BarrierPolicy, BarrierStats};
pub use probabilistic::Probabilistic;
pub use quorum::PQuorum;
pub use ssp::Ssp;

use crate::util::rng::Rng;

/// How much of the system a method must observe to decide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViewRequirement {
    /// The full set of peer steps (requires global state — BSP/SSP).
    Global,
    /// A uniform random sample of β peers (PSP family).
    Sample(usize),
    /// No view at all (ASP).
    None,
}

/// A barrier control method: a pure decision function over a step view.
///
/// Implementations must be `Send + Sync` — in the distributed engines every
/// worker thread evaluates its own barrier.
pub trait BarrierControl: Send + Sync {
    /// Human-readable name, used in reports ("bsp", "pssp", ...).
    fn name(&self) -> &'static str;

    /// The view this method needs ([`ViewRequirement::Global`] methods are
    /// the ones that cannot be fully distributed — the paper's key
    /// systems argument).
    fn view(&self) -> ViewRequirement;

    /// May a worker at `my_step` advance, given `view` (peer steps)?
    ///
    /// `view` contains the steps of exactly the peers the method asked to
    /// observe; for [`ViewRequirement::None`] it is empty.
    fn can_advance(&self, my_step: u64, view: &[u64]) -> bool;

    /// The staleness bound this method enforces over its view (0 for
    /// BSP-like, θ for SSP-like, `u64::MAX` for ASP). Used by the
    /// simulator's incremental release index.
    fn staleness(&self) -> u64;

    /// True when the predicate depends only on the minimum of the view
    /// (all ∀-window methods). Lets hot paths stream `min` instead of
    /// materialising the sample; quorum-style methods return false.
    fn min_view_sufficient(&self) -> bool {
        true
    }
}

/// Barrier method selector — config/CLI-facing description of a method.
///
/// `build()` turns it into the executable trait object; `Display`/`parse`
/// round-trip for config files (e.g. `pssp:10:4` = β=10, θ=4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Bsp,
    Ssp { staleness: u64 },
    Asp,
    Pbsp { sample: usize },
    Pssp { sample: usize, staleness: u64 },
    /// Quorum-PSP extension (§3.2): advance when ≥ quorum_pct% of the
    /// sample is within the staleness window. 100% == pSSP.
    Pquorum { sample: usize, staleness: u64, quorum_pct: u8 },
}

impl Method {
    /// Instantiate the method.
    pub fn build(self) -> Box<dyn BarrierControl> {
        match self {
            Method::Bsp => Box::new(Bsp),
            Method::Ssp { staleness } => Box::new(Ssp::new(staleness)),
            Method::Asp => Box::new(Asp),
            Method::Pbsp { sample } => Box::new(Probabilistic::new(Bsp, sample)),
            Method::Pssp { sample, staleness } => {
                Box::new(Probabilistic::new(Ssp::new(staleness), sample))
            }
            Method::Pquorum { sample, staleness, quorum_pct } => Box::new(
                PQuorum::new(sample, staleness, quorum_pct as f64 / 100.0),
            ),
        }
    }

    /// Parse `bsp | ssp:θ | asp | pbsp:β | pssp:β:θ | pquorum:β:θ:q%`.
    ///
    /// Round-trips with `Display` for every variant; malformed strings
    /// (unknown names, missing/extra fields, non-numeric or out-of-range
    /// values such as a quorum above 100%) return `None`.
    pub fn parse(s: &str) -> Option<Method> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["bsp"] => Some(Method::Bsp),
            ["asp"] => Some(Method::Asp),
            ["ssp", t] => Some(Method::Ssp { staleness: t.parse().ok()? }),
            ["ssp"] => Some(Method::Ssp { staleness: 4 }),
            ["pbsp", b] => Some(Method::Pbsp { sample: b.parse().ok()? }),
            ["pbsp"] => Some(Method::Pbsp { sample: 10 }),
            ["pssp", b, t] => Some(Method::Pssp {
                sample: b.parse().ok()?,
                staleness: t.parse().ok()?,
            }),
            ["pssp"] => Some(Method::Pssp { sample: 10, staleness: 4 }),
            ["pquorum", b, t, q] => {
                let quorum_pct: u8 = q.parse().ok()?;
                if quorum_pct > 100 {
                    return None; // PQuorum::new would reject q > 1.0
                }
                Some(Method::Pquorum {
                    sample: b.parse().ok()?,
                    staleness: t.parse().ok()?,
                    quorum_pct,
                })
            }
            _ => None,
        }
    }

    /// The five standard configurations the paper's figures compare,
    /// with its defaults (θ=4, β = 1% of 1000 nodes = 10).
    pub fn paper_five(sample: usize, staleness: u64) -> Vec<Method> {
        vec![
            Method::Bsp,
            Method::Ssp { staleness },
            Method::Asp,
            Method::Pbsp { sample },
            Method::Pssp { sample, staleness },
        ]
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Method::Bsp => write!(f, "bsp"),
            Method::Ssp { staleness } => write!(f, "ssp:{staleness}"),
            Method::Asp => write!(f, "asp"),
            Method::Pbsp { sample } => write!(f, "pbsp:{sample}"),
            Method::Pssp { sample, staleness } => write!(f, "pssp:{sample}:{staleness}"),
            Method::Pquorum { sample, staleness, quorum_pct } => {
                write!(f, "pquorum:{sample}:{staleness}:{quorum_pct}")
            }
        }
    }
}

/// Decide with an explicitly-provided sampler: draws the view the method
/// requires from `all_steps` (the oracle's table) and evaluates it.
///
/// This is the *centralised* PSP scenario (§5: "the central server applies
/// sampling primitive and PSP is as trivial as a counting process"); the
/// distributed scenario draws the view from the overlay instead
/// ([`crate::sampling::OverlaySampler`]).
pub fn decide_with_oracle(
    method: &dyn BarrierControl,
    my_step: u64,
    all_steps: &[u64],
    rng: &mut Rng,
    scratch: &mut Vec<usize>,
) -> bool {
    match method.view() {
        ViewRequirement::None => method.can_advance(my_step, &[]),
        ViewRequirement::Global => method.can_advance(my_step, all_steps),
        ViewRequirement::Sample(beta) => {
            rng.sample_into(all_steps.len(), beta, scratch);
            if scratch.is_empty() {
                method.can_advance(my_step, &[])
            } else if method.min_view_sufficient() {
                // Evaluate without materialising the sampled steps: the
                // predicate is min-based, so stream it.
                let mut min = u64::MAX;
                for &i in scratch.iter() {
                    min = min.min(all_steps[i]);
                }
                method.can_advance(my_step, std::slice::from_ref(&min))
            } else {
                let view: Vec<u64> =
                    scratch.iter().map(|&i| all_steps[i]).collect();
                method.can_advance(my_step, &view)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    fn adv(m: Method, my: u64, view: &[u64]) -> bool {
        m.build().can_advance(my, view)
    }

    #[test]
    fn bsp_blocks_until_everyone_reaches_my_step() {
        assert!(adv(Method::Bsp, 3, &[3, 3, 4]));
        assert!(!adv(Method::Bsp, 3, &[2, 3, 4]));
        assert!(adv(Method::Bsp, 0, &[0, 0]));
    }

    #[test]
    fn ssp_allows_bounded_staleness() {
        let m = Method::Ssp { staleness: 4 };
        assert!(adv(m, 5, &[1, 5, 9]));   // lag 4 == θ: ok
        assert!(!adv(m, 6, &[1, 5, 9]));  // lag 5 > θ: block
        assert!(adv(m, 0, &[100]));       // being behind never blocks
    }

    #[test]
    fn asp_always_advances() {
        assert!(adv(Method::Asp, 42, &[]));
        assert!(adv(Method::Asp, 42, &[0, 0, 0]));
    }

    #[test]
    fn empty_view_always_advances() {
        // A sample of size 0 is ASP (paper: S = ∅ ⇒ ASP).
        for m in [Method::Bsp, Method::Ssp { staleness: 2 }] {
            assert!(adv(m, 10, &[]));
        }
    }

    #[test]
    fn method_parse_roundtrip_all_six_variants() {
        // every variant, including boundary parameter values
        for m in [
            Method::Bsp,
            Method::Asp,
            Method::Ssp { staleness: 0 },
            Method::Ssp { staleness: 7 },
            Method::Pbsp { sample: 0 },
            Method::Pbsp { sample: 16 },
            Method::Pssp { sample: 10, staleness: 4 },
            Method::Pssp { sample: 1, staleness: 0 },
            Method::Pquorum { sample: 8, staleness: 3, quorum_pct: 75 },
            Method::Pquorum { sample: 10, staleness: 4, quorum_pct: 80 },
            Method::Pquorum { sample: 10, staleness: 4, quorum_pct: 0 },
            Method::Pquorum { sample: 10, staleness: 4, quorum_pct: 100 },
        ] {
            let rendered = m.to_string();
            assert_eq!(Method::parse(&rendered), Some(m), "{rendered}");
        }
    }

    #[test]
    fn method_parse_defaults_without_parameters() {
        assert_eq!(Method::parse("ssp"), Some(Method::Ssp { staleness: 4 }));
        assert_eq!(Method::parse("pbsp"), Some(Method::Pbsp { sample: 10 }));
        assert_eq!(
            Method::parse("pssp"),
            Some(Method::Pssp { sample: 10, staleness: 4 })
        );
    }

    #[test]
    fn method_parse_rejects_malformed_strings() {
        for bad in [
            "",
            "nope",
            "bsp:1",          // bsp takes no parameters
            "asp:0",
            "ssp:",           // missing value
            "ssp:abc",        // non-numeric
            "ssp:-3",         // negative staleness
            "ssp:4:4",        // extra field
            "pbsp:",
            "pbsp:ten",
            "pssp:10",        // θ missing when β given
            "pssp:10:",
            "pssp:x:4",
            "pssp:10:4:1",    // extra field
            "pquorum",        // pquorum has no default form
            "pquorum:10:4",   // quorum missing
            "pquorum:10:4:101", // quorum over 100%
            "pquorum:10:4:-1",
            "pquorum:10:4:80:9", // extra field
            "PSSP:10:4",      // case-sensitive
        ] {
            assert_eq!(Method::parse(bad), None, "'{bad}' should be rejected");
        }
    }

    #[test]
    fn paper_five_has_expected_methods() {
        let five = Method::paper_five(10, 4);
        assert_eq!(five.len(), 5);
        assert_eq!(five[0], Method::Bsp);
        assert_eq!(five[2], Method::Asp);
    }

    #[test]
    fn view_requirements() {
        assert_eq!(Method::Bsp.build().view(), ViewRequirement::Global);
        assert_eq!(Method::Asp.build().view(), ViewRequirement::None);
        assert_eq!(
            Method::Pbsp { sample: 5 }.build().view(),
            ViewRequirement::Sample(5)
        );
    }

    #[test]
    fn prop_pbsp_full_sample_equals_bsp() {
        property("pBSP(P) == BSP", 200, |g| {
            let n = g.usize_in(1, 64);
            let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, 20)).collect();
            let my = g.u64_in(0, 20);
            let bsp = Bsp;
            let pbsp = Probabilistic::new(Bsp, n);
            let mut rng = g.rng();
            let mut scratch = Vec::new();
            let a = decide_with_oracle(&bsp, my, &steps, &mut rng, &mut scratch);
            let b = decide_with_oracle(&pbsp, my, &steps, &mut rng, &mut scratch);
            assert_eq!(a, b, "steps={steps:?} my={my}");
        });
    }

    #[test]
    fn prop_pbsp_zero_sample_equals_asp() {
        property("pBSP(0) == ASP", 100, |g| {
            let n = g.usize_in(1, 64);
            let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, 20)).collect();
            let my = g.u64_in(0, 20);
            let pbsp = Probabilistic::new(Bsp, 0);
            let mut rng = g.rng();
            let mut scratch = Vec::new();
            assert!(decide_with_oracle(&pbsp, my, &steps, &mut rng, &mut scratch));
        });
    }

    #[test]
    fn prop_pssp_zero_staleness_equals_pbsp() {
        property("pSSP(β,0) == pBSP(β)", 200, |g| {
            let n = g.usize_in(1, 64);
            let beta = g.usize_in(0, n);
            let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, 10)).collect();
            let my = g.u64_in(0, 10);
            let pssp = Probabilistic::new(Ssp::new(0), beta);
            let pbsp = Probabilistic::new(Bsp, beta);
            // same sample must be drawn: use identical rng seeds
            let mut r1 = g.rng();
            let mut r2 = r1.clone();
            let mut s1 = Vec::new();
            let mut s2 = Vec::new();
            assert_eq!(
                decide_with_oracle(&pssp, my, &steps, &mut r1, &mut s1),
                decide_with_oracle(&pbsp, my, &steps, &mut r2, &mut s2),
            );
        });
    }

    #[test]
    fn prop_monotone_in_staleness() {
        // If SSP(θ) lets you through, SSP(θ'>θ) must too.
        property("SSP monotone in staleness", 200, |g| {
            let n = g.usize_in(1, 32);
            let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, 30)).collect();
            let my = g.u64_in(0, 30);
            let t1 = g.u64_in(0, 10);
            let t2 = t1 + g.u64_in(0, 10);
            let a = Ssp::new(t1).can_advance(my, &steps);
            let b = Ssp::new(t2).can_advance(my, &steps);
            assert!(!a || b, "θ={t1} passed but θ={t2} blocked");
        });
    }

    #[test]
    fn prop_sampled_view_never_stricter_than_global() {
        // If the *global* predicate passes, any sampled subset passes too
        // (min over subset ≥ min over all).
        property("sample ⊆ global ⇒ no stricter", 300, |g| {
            let n = g.usize_in(1, 64);
            let beta = g.usize_in(0, n);
            let staleness = g.u64_in(0, 5);
            let steps: Vec<u64> = (0..n).map(|_| g.u64_in(0, 15)).collect();
            let my = g.u64_in(0, 15);
            let global = Ssp::new(staleness).can_advance(my, &steps);
            if global {
                let p = Probabilistic::new(Ssp::new(staleness), beta);
                let mut rng = g.rng();
                let mut scratch = Vec::new();
                assert!(decide_with_oracle(&p, my, &steps, &mut rng, &mut scratch));
            }
        });
    }
}
