//! Bulk Synchronous Parallel (Valiant 1990) — paper Algorithm 1 / eq. (1).

use super::{BarrierControl, ViewRequirement};

/// BSP: a worker may advance past step `s` only when **every** observed
/// peer has reached `s` (`∀j: sⱼ ≥ s`, i.e. lockstep supersteps).
///
/// Deterministic and serialisable, but progress is gated on the slowest
/// worker — see Fig 2 experiments for the straggler collapse.
#[derive(Debug, Clone, Copy, Default)]
pub struct Bsp;

impl BarrierControl for Bsp {
    fn name(&self) -> &'static str {
        "bsp"
    }

    fn view(&self) -> ViewRequirement {
        ViewRequirement::Global
    }

    fn can_advance(&self, my_step: u64, view: &[u64]) -> bool {
        view.iter().all(|&s| s >= my_step)
    }

    fn staleness(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lockstep_semantics() {
        assert!(Bsp.can_advance(2, &[2, 2, 2]));
        assert!(Bsp.can_advance(2, &[2, 3, 7])); // others ahead is fine
        assert!(!Bsp.can_advance(2, &[1, 2, 3]));
        assert!(!Bsp.can_advance(u64::MAX, &[u64::MAX - 1]));
    }

    #[test]
    fn single_node_system_never_blocks() {
        // A system of one worker observes an empty peer view.
        assert!(Bsp.can_advance(0, &[]));
        assert!(Bsp.can_advance(1_000_000, &[]));
    }
}
