//! Versioned model-snapshot store for the simulator's SGD mode.
//!
//! The pre-refactor simulator cloned the full `dim`-sized server model
//! into every worker on every advance — O(dim) time per step and
//! O(n_nodes · dim) resident memory, the term that made 10⁵-node SGD
//! sweeps infeasible. This store replaces the clone with a **version
//! id**: the server model is an append-only sequence of versions (one
//! per applied update), workers pin the version they pulled, and the
//! store keeps just enough history to reconstruct any pinned version
//! **bit-exactly**:
//!
//! * `cur` — the live model at version `head`;
//! * a bounded ring of the last `retain` update **deltas**, stored as
//!   [`DeltaPayload`]s: a compressed update is recorded in its wire
//!   form (top-k / quantized — a fraction of `dim` resident floats),
//!   while a dense update costs one copy into the payload's shared
//!   buffer (the incoming `lr·g` buffer is recycled into the
//!   [`SnapshotStore::take_buf`] pool, so steady-state allocation is
//!   still zero);
//! * materialised **checkpoints** every `CHECKPOINT_STRIDE` versions
//!   inside the ring;
//! * a **spill map** for pinned versions that fall off the ring (old
//!   pins of blocked/departed stragglers), de-duplicated by version.
//!
//! Reading version `v` replays deltas forward from the nearest
//! checkpoint at or below `v` into a cached scratch buffer; because the
//! server itself produced version `v` by the identical subtraction
//! sequence, the reconstruction is bit-identical to the pre-refactor
//! cloned snapshot (asserted against an eager-clone oracle in the tests
//! below and at whole-simulation level in `tests/sim_golden.rs`).
//! Consecutive reads are usually at adjacent versions, so the scratch
//! cache makes the common read O(dim · version-gap) ≈ O(dim).
//!
//! Memory: O(retain · dim + distinct-spilled · dim) — bounded by the
//! configured window instead of the cluster size.

use std::collections::{BTreeMap, VecDeque};

use crate::engine::delta::DeltaPayload;

/// Sentinel for "no version pinned".
pub const NO_VERSION: u64 = u64::MAX;

/// Materialise a full checkpoint every this many versions.
const CHECKPOINT_STRIDE: u64 = 16;

/// Bounded-history versioned store over a dense `f32` model vector.
#[derive(Debug, Clone)]
pub struct SnapshotStore {
    /// Live model — version `head`.
    cur: Vec<f32>,
    head: u64,
    /// `deltas[i]` transformed version `base + i` into `base + i + 1`
    /// (by subtraction — [`DeltaPayload::sub_from`]).
    deltas: VecDeque<DeltaPayload>,
    /// Oldest version reconstructable from the ring.
    base: u64,
    /// Materialised `(version, model)` checkpoints, ascending; the first
    /// one is always exactly at `base`.
    checkpoints: VecDeque<(u64, Vec<f32>)>,
    /// Maximum deltas retained before the window slides (spilling any
    /// still-pinned versions it passes).
    retain: usize,
    /// version -> number of outstanding pins.
    refs: BTreeMap<u64, u32>,
    /// Exact copies of pinned versions that fell off the ring.
    spilled: BTreeMap<u64, Vec<f32>>,
    /// Reconstruction cache: `scratch` holds version `scratch_v`.
    scratch: Vec<f32>,
    scratch_v: u64,
    /// Recycled delta buffers (capacity reuse for `take_buf`).
    pool: Vec<Vec<f32>>,
    /// Lifetime spill count (stat; exposed for tests and benches).
    spills: u64,
}

impl SnapshotStore {
    /// Create a store at version 0 holding `init`, retaining at least
    /// `retain` versions of history (clamped to one checkpoint stride).
    pub fn new(init: Vec<f32>, retain: usize) -> SnapshotStore {
        let mut checkpoints = VecDeque::new();
        checkpoints.push_back((0, init.clone()));
        SnapshotStore {
            cur: init,
            head: 0,
            deltas: VecDeque::new(),
            base: 0,
            checkpoints,
            retain: retain.max(CHECKPOINT_STRIDE as usize * 2),
            refs: BTreeMap::new(),
            spilled: BTreeMap::new(),
            scratch: Vec::new(),
            scratch_v: NO_VERSION,
            pool: Vec::new(),
            spills: 0,
        }
    }

    /// Current version id.
    pub fn version(&self) -> u64 {
        self.head
    }

    /// The live model (version `head`).
    pub fn head_slice(&self) -> &[f32] {
        &self.cur
    }

    /// Pin the current head version (a worker pulling the model).
    /// O(log pins) — no copy.
    pub fn pin_head(&mut self) -> u64 {
        *self.refs.entry(self.head).or_insert(0) += 1;
        self.head
    }

    /// Release a pin taken earlier. `NO_VERSION` is a no-op.
    pub fn unpin(&mut self, v: u64) {
        if v == NO_VERSION {
            return;
        }
        let count = self.refs.get_mut(&v).expect("unpin of unpinned version");
        *count -= 1;
        if *count == 0 {
            self.refs.remove(&v);
            self.spilled.remove(&v);
        }
    }

    /// Atomically release `old` and pin the head (a worker advancing).
    pub fn repin(&mut self, old: u64) -> u64 {
        self.unpin(old);
        self.pin_head()
    }

    /// A `dim`-sized zeroed buffer for the caller to fill with the next
    /// delta, recycled from evicted ring entries when possible.
    pub fn take_buf(&mut self) -> Vec<f32> {
        match self.pool.pop() {
            Some(mut b) => {
                b.clear();
                b.resize(self.cur.len(), 0.0);
                b
            }
            None => vec![0.0; self.cur.len()],
        }
    }

    /// Apply an exact dense update: `w[i] -= delta[i]` for every
    /// element, advancing `head` by one and recording the delta in the
    /// ring. Bit-identical to the pre-payload code; the spent buffer is
    /// recycled into the [`SnapshotStore::take_buf`] pool.
    pub fn apply_delta(&mut self, mut delta: Vec<f32>) {
        debug_assert_eq!(delta.len(), self.cur.len());
        let payload = DeltaPayload::dense(&delta[..]);
        if self.pool.len() < 8 {
            delta.clear();
            self.pool.push(delta);
        }
        self.apply_payload(payload);
    }

    /// Apply an update in whatever payload form the origin shipped —
    /// compressed payloads are recorded in the ring as-is (no
    /// densification), so history memory shrinks with the wire bytes.
    pub fn apply_payload(&mut self, delta: DeltaPayload) {
        debug_assert_eq!(delta.dim(), self.cur.len());
        delta.sub_from(&mut self.cur);
        self.head += 1;
        self.deltas.push_back(delta);
        if self.head % CHECKPOINT_STRIDE == 0 {
            self.checkpoints.push_back((self.head, self.cur.clone()));
        }
        self.trim();
    }

    /// Slide the window forward one checkpoint interval at a time,
    /// spilling exact copies of any versions still pinned.
    fn trim(&mut self) {
        while self.deltas.len() > self.retain && self.checkpoints.len() > 1 {
            let new_base = self.checkpoints[1].0;
            let pinned: Vec<u64> = self
                .refs
                .range(self.base..new_base)
                .map(|(&v, _)| v)
                .filter(|v| !self.spilled.contains_key(v))
                .collect();
            for v in pinned {
                let w = self.rebuild(v);
                self.spilled.insert(v, w);
                self.spills += 1;
            }
            for _ in self.base..new_base {
                self.deltas.pop_front().expect("delta ring underflow");
            }
            self.checkpoints.pop_front();
            self.base = new_base;
        }
    }

    /// Materialise version `v` from the ring (checkpoint + forward
    /// delta replay). `v` must be inside `[base, head]`.
    fn rebuild(&self, v: u64) -> Vec<f32> {
        let ci = self.checkpoints.partition_point(|&(cv, _)| cv <= v) - 1;
        let (cv, cw) = &self.checkpoints[ci];
        let mut w = cw.clone();
        for i in (cv - self.base)..(v - self.base) {
            self.deltas[i as usize].sub_from(&mut w);
        }
        w
    }

    /// Read version `v` — bit-identical to the model as it was when `v`
    /// was the head. `v` must be pinned (or the head itself).
    pub fn get(&mut self, v: u64) -> &[f32] {
        if v == self.head {
            return &self.cur;
        }
        if let Some(w) = self.spilled.get(&v) {
            return w;
        }
        assert!(
            v >= self.base && v < self.head,
            "version {v} outside retained window [{}, {}]",
            self.base,
            self.head
        );
        // NO_VERSION (u64::MAX) never satisfies `scratch_v <= v`.
        let cached = self.scratch_v >= self.base && self.scratch_v <= v;
        if cached {
            // Forward-replay from the cache: consecutive reads advance a
            // few versions at a time, so this is the O(dim) common case.
            for i in (self.scratch_v - self.base)..(v - self.base) {
                self.deltas[i as usize].sub_from(&mut self.scratch);
            }
        } else {
            let w = self.rebuild(v);
            self.scratch = w;
        }
        self.scratch_v = v;
        &self.scratch
    }

    /// Number of versions currently reconstructable from the ring.
    pub fn retained(&self) -> usize {
        self.deltas.len() + 1
    }

    /// Versions ever spilled (pinned past the window) — a health stat:
    /// large values mean `retain` is too small for the workload.
    pub fn spill_count(&self) -> u64 {
        self.spills
    }

    /// Outstanding pins across all versions.
    pub fn pin_count(&self) -> usize {
        self.refs.values().map(|&c| c as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Eager-clone oracle: every version kept as a full copy.
    struct Oracle {
        versions: Vec<Vec<f32>>,
    }

    impl Oracle {
        fn new(init: Vec<f32>) -> Oracle {
            Oracle { versions: vec![init] }
        }

        fn apply(&mut self, delta: &[f32]) {
            let mut next = self.versions.last().unwrap().clone();
            for (w, d) in next.iter_mut().zip(delta) {
                *w -= d;
            }
            self.versions.push(next);
        }
    }

    fn random_delta(dim: usize, rng: &mut Rng) -> Vec<f32> {
        (0..dim).map(|_| (rng.next_f32() - 0.5) * 0.1).collect()
    }

    #[test]
    fn head_and_version_track_updates() {
        let mut s = SnapshotStore::new(vec![1.0, 2.0], 64);
        assert_eq!(s.version(), 0);
        s.apply_delta(vec![0.5, -0.5]);
        assert_eq!(s.version(), 1);
        assert_eq!(s.head_slice(), &[0.5, 2.5]);
    }

    #[test]
    fn reads_are_bit_identical_to_eager_clones() {
        let dim = 17;
        let mut rng = Rng::new(7);
        let init: Vec<f32> = (0..dim).map(|_| rng.next_f32()).collect();
        let mut store = SnapshotStore::new(init.clone(), 64);
        let mut oracle = Oracle::new(init);
        // Pin a scattering of versions as we go, then read them all back
        // in a jumbled order.
        let mut pins: Vec<u64> = Vec::new();
        for step in 0..500 {
            if step % 3 == 0 {
                pins.push(store.pin_head());
            }
            let d = random_delta(dim, &mut rng);
            oracle.apply(&d);
            store.apply_delta(d);
        }
        // Jumbled read order: forward cache hits, backward rebuilds,
        // spilled versions, and the head.
        let mut order = pins.clone();
        rng.shuffle(&mut order);
        for &v in &order {
            let got = store.get(v).to_vec();
            let want = &oracle.versions[v as usize];
            assert_eq!(&got, want, "version {v} diverged");
        }
        assert_eq!(store.head_slice(), oracle.versions.last().unwrap().as_slice());
    }

    /// Compressed payloads recorded via [`SnapshotStore::apply_payload`]
    /// must replay exactly like subtracting their dense expansion — the
    /// ring just stores fewer resident floats.
    #[test]
    fn compressed_payload_history_replays_bit_identically() {
        let dim = 12;
        let mut rng = Rng::new(0x5EED_5AFE);
        let init: Vec<f32> = (0..dim).map(|_| rng.next_f32()).collect();
        let mut store = SnapshotStore::new(init.clone(), 48);
        let mut oracle = Oracle::new(init);
        let mut pins: Vec<u64> = Vec::new();
        for step in 0..300 {
            if step % 5 == 0 {
                pins.push(store.pin_head());
            }
            let d = random_delta(dim, &mut rng);
            // Cycle the variants so forward replay crosses all of them.
            let j = step % (dim - 1) + 1; // ascending with 0, in range
            let p = match step % 3 {
                0 => DeltaPayload::dense(d),
                1 => DeltaPayload::TopK {
                    dim: dim as u32,
                    idx: vec![0, j as u32].into(),
                    val: vec![d[0], d[j]].into(),
                },
                _ => DeltaPayload::QuantI8 {
                    scale: 0.01,
                    codes: d.iter().map(|&x| (x * 100.0) as i8).collect::<Vec<_>>().into(),
                },
            };
            oracle.apply(&p.to_dense());
            store.apply_payload(p);
        }
        let mut order = pins.clone();
        rng.shuffle(&mut order);
        for &v in &order {
            assert_eq!(
                store.get(v),
                oracle.versions[v as usize].as_slice(),
                "version {v} diverged"
            );
        }
        assert_eq!(store.head_slice(), oracle.versions.last().unwrap().as_slice());
        assert!(store.spill_count() > 0, "test never exercised the spill path");
    }

    #[test]
    fn old_pins_spill_once_and_dedup() {
        let dim = 4;
        let mut store = SnapshotStore::new(vec![0.0; dim], 32);
        // Three pins of the same early version.
        let a = store.pin_head();
        let b = store.pin_head();
        let c = store.pin_head();
        assert_eq!(a, b);
        for _ in 0..400 {
            store.apply_delta(vec![0.01; dim]);
        }
        // The pinned version fell well off the 32-delta ring: it must
        // have been spilled exactly once despite three pins.
        assert_eq!(store.spill_count(), 1);
        let w = store.get(a).to_vec();
        assert_eq!(w, vec![0.0; dim]);
        store.unpin(a);
        store.unpin(b);
        store.unpin(c);
        assert_eq!(store.pin_count(), 0);
    }

    #[test]
    fn unpinned_versions_are_reclaimed() {
        let dim = 3;
        let mut store = SnapshotStore::new(vec![0.0; dim], 32);
        let v = store.pin_head();
        for _ in 0..200 {
            store.apply_delta(vec![0.1; dim]);
        }
        assert!(store.spill_count() > 0);
        store.unpin(v);
        // Spilled copy is dropped with its last pin.
        assert_eq!(store.pin_count(), 0);
        assert!(store.spilled.is_empty());
    }

    #[test]
    fn repin_moves_the_pin_to_head() {
        let mut store = SnapshotStore::new(vec![0.0; 2], 64);
        let v0 = store.pin_head();
        store.apply_delta(vec![1.0, 1.0]);
        let v1 = store.repin(v0);
        assert_eq!(v1, 1);
        assert_eq!(store.pin_count(), 1);
        // v0 is no longer pinned; reading it is only legal via the ring
        // (still retained here).
        assert_eq!(store.get(v0), &[0.0, 0.0]);
    }

    #[test]
    fn retained_window_is_bounded() {
        let dim = 8;
        let mut store = SnapshotStore::new(vec![0.0; dim], 48);
        for _ in 0..10_000 {
            store.apply_delta(vec![0.001; dim]);
        }
        // retain is clamped up to >= 2 strides and the window slides in
        // stride units, so allow one extra stride of slack.
        assert!(
            store.retained() <= 48 + 2 * CHECKPOINT_STRIDE as usize,
            "window grew unbounded: {}",
            store.retained()
        );
        assert_eq!(store.spill_count(), 0, "nothing was pinned");
    }
}
