//! Event scheduling for the discrete-event simulator.
//!
//! Two schedulers implement the same [`EventScheduler`] interface:
//!
//! * [`EventQueue`] — a **calendar queue** (bucketed timer wheel, Brown
//!   1988): events hash into day-sized buckets by time, so push and pop
//!   are O(1) amortised instead of the O(log n) of a binary heap. At
//!   10⁴–10⁵ nodes the heap's sift-downs dominate the simulator's hot
//!   loop; the calendar queue removes that ceiling.
//! * [`HeapQueue`] — the pre-calendar binary-heap implementation, kept
//!   as the **golden-trace oracle**: both schedulers pop in exactly the
//!   same (time, seq) order, which the property tests below and the
//!   whole-simulation tests in `tests/sim_golden.rs` assert.
//!
//! Ordering contract (both impls): events pop in ascending `time`;
//! simultaneous events pop FIFO by insertion sequence. That total order
//! is what makes whole simulations bit-reproducible from their seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A worker finished computing its current step.
    ComputeDone { node: usize },
    /// A blocked sampled-barrier worker re-samples its view.
    /// `step` guards against stale rechecks after the node advanced.
    Recheck { node: usize, step: u64 },
    /// A worker's pushed update reaches the server.
    UpdateArrive { node: usize },
    /// A globally-blocked worker is released by a rising minimum.
    Release { node: usize },
    /// Periodic timeline sampling tick.
    SampleTimeline,
    /// Churn: a new node joins.
    Join,
    /// Churn: a random node leaves gracefully — the membership plane
    /// observes the departure immediately (explicit goodbye).
    Leave,
    /// Churn: a random node crash-stops. Unlike [`EventKind::Leave`] the
    /// victim stays in the step table — poisoning samples and pinning the
    /// global minimum — until failure detection confirms the death.
    Crash,
    /// The failure detector's suspect/confirm timeline elapsed for a
    /// crashed node: remove it from the tracked membership.
    ConfirmDead { node: usize },
    /// A parameter-server shard actor crash-stops: pushes/pulls against
    /// it stall until the shard is re-homed onto a replica.
    ShardCrash,
    /// Shard re-home complete (promotion + bulk handoff): workers may
    /// push/pull shard `shard` again.
    ShardRehomed { shard: usize },
}

/// A scheduled event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest event pops first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The scheduler interface the simulator's hot loop is generic over.
///
/// Implementations must pop in ascending `(time, seq)` order — the
/// whole-trajectory reproducibility contract.
pub trait EventScheduler: Default {
    /// Schedule `kind` at absolute time `time` (seconds).
    fn push(&mut self, time: f64, kind: EventKind);
    /// Pop the earliest event.
    fn pop(&mut self) -> Option<Event>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Calendar queue
// ---------------------------------------------------------------------------

/// Minimum bucket count (power of two).
const MIN_BUCKETS: usize = 16;
/// Maximum bucket count (power of two): beyond this the wheel stops
/// growing and per-bucket occupancy rises instead — graceful O(len/2²⁰)
/// degradation rather than an O(len) rebuild on every push.
const MAX_BUCKETS: usize = 1 << 20;
/// Resize up when average occupancy exceeds this many events per bucket.
const GROW_AT: usize = 4;

/// Calendar-queue scheduler: O(1) amortised push/pop.
///
/// Buckets cover consecutive `width`-second "days"; an event lands in
/// bucket `day(time) mod n_buckets`. Popping scans days from the cursor
/// forward, taking the (time, seq)-minimum of the first non-empty day —
/// day ranges are disjoint and ordered, so that is the global minimum.
/// If a whole lap of the wheel finds nothing (all events more than
/// `n_buckets` days ahead), a direct O(n) scan relocates the cursor; the
/// periodic re-sizing keeps `width` matched to event density, making
/// that fallback rare.
#[derive(Debug)]
pub struct EventQueue {
    buckets: Vec<Vec<Event>>,
    /// `buckets.len() - 1`; bucket count is always a power of two.
    mask: usize,
    /// Reciprocal of the day width (multiply, don't divide, in `day_of`
    /// — push and pop must compute identical day indices).
    inv_width: f64,
    /// Day index of the pop cursor; monotone non-decreasing.
    day: u64,
    /// Time of the last popped event. In a DES no event is ever pushed
    /// before it, and (since pop always returns the minimum) no stored
    /// event precedes it either — so it is the one safe anchor for the
    /// cursor when `resize` changes the day width.
    floor: f64,
    len: usize,
    seq: u64,
}

impl Default for EventQueue {
    fn default() -> EventQueue {
        EventQueue::new()
    }
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            mask: MIN_BUCKETS - 1,
            inv_width: 1.0 / 0.1, // 100ms days until the first re-size
            day: 0,
            floor: 0.0,
            len: 0,
            seq: 0,
        }
    }

    #[inline]
    fn day_of(&self, time: f64) -> u64 {
        (time * self.inv_width) as u64
    }

    /// Rebuild with a bucket count and day width matched to the current
    /// contents. Deterministic: depends only on the stored events.
    fn resize(&mut self) {
        let n_buckets = self.len.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        let events: Vec<Event> =
            self.buckets.iter_mut().flat_map(std::mem::take).collect();
        // Width heuristic: spread the live time range over ~2 events per
        // day. All-equal times (or a single event) keep the old width.
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for e in &events {
            lo = lo.min(e.time);
            hi = hi.max(e.time);
        }
        if hi > lo {
            let width = ((hi - lo) / events.len() as f64 * 2.0).max(1e-9);
            self.inv_width = 1.0 / width;
        }
        self.buckets = (0..n_buckets).map(|_| Vec::new()).collect();
        self.mask = n_buckets - 1;
        // Re-anchor the cursor at the last popped time — NOT at the
        // earliest stored event: events may still be pushed between the
        // two (a handler at t scheduling t+δ), and the lap scan never
        // looks behind the cursor.
        self.day = self.day_of(self.floor);
        for e in events {
            let b = (self.day_of(e.time) as usize) & self.mask;
            self.buckets[b].push(e);
        }
    }
}

impl EventScheduler for EventQueue {
    fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite() && time >= 0.0, "bad event time {time}");
        debug_assert!(time >= self.floor, "push at {time} before last pop {}", self.floor);
        let seq = self.seq;
        self.seq += 1;
        let b = (self.day_of(time) as usize) & self.mask;
        self.buckets[b].push(Event { time, seq, kind });
        self.len += 1;
        // Guard on MAX_BUCKETS: once the wheel is maxed out a resize
        // could no longer lower occupancy, and re-triggering it on every
        // push would turn O(1) insertion quadratic.
        if self.len > self.buckets.len() * GROW_AT && self.buckets.len() < MAX_BUCKETS {
            self.resize();
        }
    }

    fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        let n_buckets = self.buckets.len();
        // Scan at most one lap of the wheel from the cursor day.
        for i in 0..n_buckets as u64 {
            let day = self.day + i;
            let b = (day as usize) & self.mask;
            let mut best: Option<usize> = None;
            for (j, e) in self.buckets[b].iter().enumerate() {
                // Accept only this day's events; later "years" sharing the
                // bucket wait for their lap. Recomputing day_of keeps the
                // test bit-consistent with the placement in push().
                if self.day_of(e.time) != day {
                    continue;
                }
                best = match best {
                    None => Some(j),
                    Some(k) => {
                        let cur = &self.buckets[b][k];
                        if (e.time, e.seq) < (cur.time, cur.seq) {
                            Some(j)
                        } else {
                            Some(k)
                        }
                    }
                };
            }
            if let Some(j) = best {
                self.day = day;
                let e = self.buckets[b].swap_remove(j);
                self.floor = e.time;
                self.len -= 1;
                if self.buckets.len() > MIN_BUCKETS && self.len * 8 < self.buckets.len() {
                    self.resize();
                }
                return Some(e);
            }
        }
        // Everything is more than a lap ahead: locate the global minimum
        // directly and re-anchor the cursor there. Rare by construction.
        let mut best: Option<(usize, usize)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (j, e) in bucket.iter().enumerate() {
                best = match best {
                    None => Some((b, j)),
                    Some((bb, jj)) => {
                        let cur = &self.buckets[bb][jj];
                        if (e.time, e.seq) < (cur.time, cur.seq) {
                            Some((b, j))
                        } else {
                            Some((bb, jj))
                        }
                    }
                };
            }
        }
        let (b, j) = best.expect("len > 0 but no event found");
        let e = self.buckets[b].swap_remove(j);
        self.day = self.day_of(e.time);
        self.floor = e.time;
        self.len -= 1;
        Some(e)
    }

    fn len(&self) -> usize {
        self.len
    }
}

// ---------------------------------------------------------------------------
// Binary-heap oracle (pre-refactor implementation)
// ---------------------------------------------------------------------------

/// Min-heap scheduler with deterministic tie-breaking — the original
/// `EventQueue`. O(log n) per operation; kept as the reference oracle
/// for golden-trace tests and for the heap-vs-calendar benchmark in
/// `benches/simulator.rs`.
#[derive(Debug, Default)]
pub struct HeapQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl HeapQueue {
    pub fn new() -> HeapQueue {
        HeapQueue { heap: BinaryHeap::with_capacity(1024), seq: 0 }
    }
}

impl EventScheduler for HeapQueue {
    fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite() && time >= 0.0, "bad event time {time}");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::SampleTimeline);
        q.push(1.0, EventKind::Join);
        q.push(2.0, EventKind::Leave);
        assert_eq!(q.pop().unwrap().kind, EventKind::Join);
        assert_eq!(q.pop().unwrap().kind, EventKind::Leave);
        assert_eq!(q.pop().unwrap().kind, EventKind::SampleTimeline);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for node in 0..10 {
            q.push(1.0, EventKind::ComputeDone { node });
        }
        for node in 0..10 {
            assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone { node });
        }
    }

    #[test]
    fn prop_monotone_pop_order() {
        property("event queue pops monotone times", 100, |g| {
            let mut q = EventQueue::new();
            let n = g.usize_in(0, 200);
            for _ in 0..n {
                q.push(g.f64_in(0.0, 100.0), EventKind::SampleTimeline);
            }
            let mut last = -1.0;
            while let Some(e) = q.pop() {
                assert!(e.time >= last);
                last = e.time;
            }
        });
    }

    #[test]
    fn len_tracks_push_pop() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1.0, EventKind::Join);
        q.push(2.0, EventKind::Leave);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn far_future_events_pop_correctly() {
        // Events many "years" past the wheel exercise the direct-search
        // fallback and the cursor re-anchoring.
        let mut q = EventQueue::new();
        q.push(10_000.0, EventKind::Join);
        q.push(0.5, EventKind::Leave);
        q.push(50_000.0, EventKind::SampleTimeline);
        assert_eq!(q.pop().unwrap().kind, EventKind::Leave);
        assert_eq!(q.pop().unwrap().kind, EventKind::Join);
        assert_eq!(q.pop().unwrap().kind, EventKind::SampleTimeline);
        assert!(q.pop().is_none());
    }

    #[test]
    fn survives_many_resizes() {
        let mut q = EventQueue::new();
        for i in 0..5000 {
            q.push(i as f64 * 0.001, EventKind::ComputeDone { node: i });
        }
        for i in 0..5000 {
            assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone { node: i });
        }
        assert!(q.is_empty());
    }

    /// Regression (code review): a shrink-resize while the remaining
    /// events sit far ahead must not advance the cursor past times that
    /// are still legally pushable — the resize anchors at the last
    /// popped time, never at the earliest stored event.
    #[test]
    fn resize_does_not_orphan_pushes_at_current_time() {
        let mut q = EventQueue::new();
        // Grow the wheel well past MIN_BUCKETS…
        for i in 0..2000 {
            q.push(10.0 + i as f64 * 1e-3, EventKind::ComputeDone { node: i });
        }
        // …plus one far-future event that will be all that remains.
        q.push(100.0, EventKind::SampleTimeline);
        // Drain the cluster; shrink-resizes fire along the way.
        let mut last = 0.0;
        for _ in 0..2000 {
            last = q.pop().unwrap().time;
        }
        assert!(last < 13.0);
        // A handler at `last` schedules follow-ups just after it.
        q.push(last + 0.5, EventKind::Join);
        q.push(last, EventKind::Leave);
        assert_eq!(q.pop().unwrap().kind, EventKind::Leave);
        assert_eq!(q.pop().unwrap().kind, EventKind::Join);
        assert_eq!(q.pop().unwrap().kind, EventKind::SampleTimeline);
        assert!(q.pop().is_none());
    }

    /// The satellite property test: on random interleaved workloads the
    /// calendar queue pops exactly the (time, seq) sequence the old
    /// binary heap does — including duplicate times, same-time pushes
    /// after pops, and clustered + sparse mixtures.
    #[test]
    fn prop_calendar_matches_heap_oracle() {
        property("calendar queue == heap oracle", 150, |g| {
            let mut cal = EventQueue::new();
            let mut heap = HeapQueue::new();
            let mut now = 0.0f64;
            let ops = g.usize_in(1, 400);
            for _ in 0..ops {
                if g.usize_in(0, 2) > 0 || cal.is_empty() {
                    // Push 1–4 events at or after the current time; small
                    // strides force ties and bucket collisions, large
                    // strides force the far-future path.
                    for _ in 0..g.usize_in(1, 4) {
                        let dt = match g.usize_in(0, 9) {
                            0 => 0.0,
                            1..=6 => g.f64_in(0.0, 2.0),
                            _ => g.f64_in(0.0, 500.0),
                        };
                        let node = g.usize_in(0, 50);
                        cal.push(now + dt, EventKind::ComputeDone { node });
                        heap.push(now + dt, EventKind::ComputeDone { node });
                    }
                } else {
                    let a = cal.pop().unwrap();
                    let b = heap.pop().unwrap();
                    assert_eq!(a, b, "pop diverged: {a:?} vs {b:?}");
                    assert_eq!(a.kind, b.kind);
                    now = a.time;
                }
                assert_eq!(cal.len(), heap.len());
            }
            while let Some(b) = heap.pop() {
                let a = cal.pop().expect("calendar ran dry early");
                assert_eq!(a, b, "drain diverged: {a:?} vs {b:?}");
                assert_eq!(a.kind, b.kind);
            }
            assert!(cal.pop().is_none());
        });
    }
}
