//! Event queue for the discrete-event simulator.
//!
//! A binary heap keyed by (time, sequence). The sequence number breaks
//! ties deterministically (FIFO among simultaneous events), which makes
//! whole simulations bit-reproducible from their seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A worker finished computing its current step.
    ComputeDone { node: usize },
    /// A blocked sampled-barrier worker re-samples its view.
    /// `step` guards against stale rechecks after the node advanced.
    Recheck { node: usize, step: u64 },
    /// A worker's pushed update reaches the server.
    UpdateArrive { node: usize },
    /// A globally-blocked worker is released by a rising minimum.
    Release { node: usize },
    /// Periodic timeline sampling tick.
    SampleTimeline,
    /// Churn: a new node joins.
    Join,
    /// Churn: a random node leaves.
    Leave,
}

/// A scheduled event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest event pops first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap of events with deterministic tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::with_capacity(1024), seq: 0 }
    }

    /// Schedule `kind` at absolute time `time` (seconds).
    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite() && time >= 0.0, "bad event time {time}");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::SampleTimeline);
        q.push(1.0, EventKind::Join);
        q.push(2.0, EventKind::Leave);
        assert_eq!(q.pop().unwrap().kind, EventKind::Join);
        assert_eq!(q.pop().unwrap().kind, EventKind::Leave);
        assert_eq!(q.pop().unwrap().kind, EventKind::SampleTimeline);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for node in 0..10 {
            q.push(1.0, EventKind::ComputeDone { node });
        }
        for node in 0..10 {
            assert_eq!(q.pop().unwrap().kind, EventKind::ComputeDone { node });
        }
    }

    #[test]
    fn prop_monotone_pop_order() {
        property("event queue pops monotone times", 100, |g| {
            let mut q = EventQueue::new();
            let n = g.usize_in(0, 200);
            for _ in 0..n {
                q.push(g.f64_in(0.0, 100.0), EventKind::SampleTimeline);
            }
            let mut last = -1.0;
            while let Some(e) = q.pop() {
                assert!(e.time >= last);
                last = e.time;
            }
        });
    }

    #[test]
    fn len_tracks_push_pop() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1.0, EventKind::Join);
        q.push(2.0, EventKind::Leave);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }
}
