//! Deterministic discrete-event simulator of the paper's evaluation
//! cluster (Section 5): N heterogeneous workers running distributed SGD
//! under a configurable barrier control method, with stragglers, churn
//! and network delays.
//!
//! The *same barrier code* ([`crate::barrier`]) drives both this simulator
//! and the live thread-based engines ([`crate::engine`]); the simulator
//! exists so that the sweeps behind every figure are exact, fast and
//! reproducible from a seed — and fast enough that 10⁵-node clusters are
//! routine, not just the paper's 10³.
//!
//! ## Hot-path architecture (the 10× pass)
//!
//! * Events are scheduled on a **calendar queue** ([`EventQueue`]) — O(1)
//!   amortised push/pop instead of a binary heap's O(log n) — with the
//!   old heap retained as [`HeapQueue`], the golden-trace oracle.
//! * Node progress lives in [`StepTracker`]'s dense sliding-window
//!   histogram: O(1) advance and O(1) min/max.
//! * SGD snapshots are **version ids** into a bounded [`SnapshotStore`]
//!   ring instead of per-worker O(dim) clones, cutting pull cost to O(1)
//!   and memory from O(n_nodes·dim) to O(versions·dim) while staying
//!   bit-identical (`tests/sim_golden.rs`).
//! * Events past the horizon are never enqueued (they could never be
//!   processed), and churn victims are picked in O(1) from the tracker's
//!   dense active list.
//!
//! ## Worker lifecycle
//!
//! ```text
//!   pull model snapshot ──► compute for D ~ iter-time dist ──► push update
//!        ▲                                                        │
//!        └──────────── barrier decision (may wait) ◄──────────────┘
//! ```
//!
//! * Global-view methods (BSP/SSP) block until the tracked global minimum
//!   step reaches `my_step − θ`; releases are event-driven via the
//!   [`StepTracker`] incremental minimum (no polling).
//! * Sampled methods (pBSP/pSSP) draw a fresh β-sample per attempt; a
//!   failed attempt schedules a re-check after `recheck_interval`
//!   (a real node would poll its sampled peers the same way). Each
//!   attempt costs 2β control messages.
//! * ASP never blocks.
//!
//! ## Optional real SGD (`SgdConfig`)
//!
//! With SGD enabled each worker holds the version id of the model it
//! pulled when its iteration started and, on completion, pushes the
//! *actual* MSE gradient of a minibatch drawn from a shared synthetic
//! dataset (generated from a ground-truth parameter vector). The server
//! applies updates on arrival. This reproduces the paper's Fig 1d/2b
//! error metric: `‖w_server − w_true‖₂` normalised by its initial value.
//!
//! ## Time-varying load + adaptive barriers
//!
//! Admission flows through [`crate::barrier::BarrierPolicy`] — the same
//! decision core the live engines consult. With
//! [`ClusterConfig::adaptive`] set, every node gets its *own* policy and
//! the DSSP-style controller retunes its effective θ/β online from the
//! observed wait/compute ratio; [`ClusterConfig::load_profile`] supplies
//! the time-varying heterogeneity (flash-crowd straggler bursts, diurnal
//! load) that makes any fixed θ wrong somewhere. Both knobs are `None`
//! by default, draw **no** randomness when off, and leave the seeded
//! golden trajectories bit-identical.

mod events;
mod snapshots;

pub use events::{Event, EventKind, EventQueue, EventScheduler, HeapQueue};
pub use snapshots::{SnapshotStore, NO_VERSION};

use crate::barrier::{AdaptiveConfig, BarrierPolicy, Method, ViewRequirement};
use crate::engine::delta::{CompressConfig, DeltaEncoder};
use crate::model::linear::{Dataset, LinearModel};
use crate::sampling::StepTracker;
use crate::util::rng::Rng;

/// Iteration-time distribution family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TimeDist {
    /// Exponential with the node's mean (heavy spread — default; matches
    /// the wide ASP dispersion in Fig 1a).
    Exponential,
    /// Normal with coefficient of variation `cv`, truncated at mean/10.
    Normal { cv: f64 },
    /// Pareto with given shape (>1), scaled to the node's mean
    /// (heavy-tailed stragglers "in distribution" rather than injected).
    Pareto { shape: f64 },
}

impl TimeDist {
    fn sample(self, mean: f64, rng: &mut Rng) -> f64 {
        match self {
            TimeDist::Exponential => rng.exponential(mean),
            TimeDist::Normal { cv } => {
                rng.normal_with(mean, mean * cv).max(mean / 10.0)
            }
            TimeDist::Pareto { shape } => {
                // scale so that E[X] = mean: E = scale*shape/(shape-1)
                let scale = mean * (shape - 1.0) / shape;
                rng.pareto(scale, shape)
            }
        }
    }
}

/// Churn model: Poisson join/leave/crash processes.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChurnConfig {
    /// Mean joins per simulated second.
    pub join_rate: f64,
    /// Mean graceful leaves per simulated second (explicit goodbye: the
    /// membership plane removes the node immediately).
    pub leave_rate: f64,
    /// Mean crash-stops per simulated second. A crash victim goes silent
    /// but *stays in the step table* — sampled barriers keep observing
    /// its frozen step and BSP/SSP keep waiting on it — until the
    /// failure detector's suspect/confirm timeline
    /// ([`ClusterConfig::crash_detect_secs`]) elapses and a
    /// [`EventKind::ConfirmDead`] removes it. This is the simulator-side
    /// model of the engine's membership plane
    /// ([`crate::engine::membership`]).
    pub crash_rate: f64,
}

/// Straggler injection (paper Fig 2): a fraction of nodes run `slowdown`×
/// slower on average.
#[derive(Debug, Clone, Copy)]
pub struct StragglerConfig {
    pub fraction: f64,
    pub slowdown: f64,
}

/// Time-varying heterogeneity (`exp ext_adaptive`): a deterministic
/// multiplier on a node's mean iteration time, evaluated at the moment
/// each iteration *starts*. Pure function of `(node, t)` — no RNG draws,
/// so `None` replays pre-existing seeded trajectories bit-identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadProfile {
    /// The first `⌊fraction·n_nodes⌋` nodes (the [`StragglerConfig`]
    /// convention) run `slowdown`× slower during `[start, start+duration)`
    /// — a flash crowd of stragglers appearing and disappearing mid-run,
    /// the regime where any *fixed* staleness bound is wrong twice.
    FlashCrowd { fraction: f64, slowdown: f64, start: f64, duration: f64 },
    /// Smooth sinusoidal load: `mean × (1 + amplitude·sin(2π(t/period +
    /// phase)))`, phase-shifted per node so the cluster breathes unevenly.
    Diurnal { amplitude: f64, period: f64 },
}

impl LoadProfile {
    /// Multiplier for node `node` (of an initial population `n`) at time
    /// `t`. Clamped below so pathological amplitudes stay positive.
    pub fn factor(&self, node: usize, n: usize, t: f64) -> f64 {
        let f = match *self {
            LoadProfile::FlashCrowd { fraction, slowdown, start, duration } => {
                let in_crowd = (node as f64) < fraction * n as f64;
                if in_crowd && t >= start && t < start + duration {
                    slowdown
                } else {
                    1.0
                }
            }
            LoadProfile::Diurnal { amplitude, period } => {
                let phase = node as f64 / n.max(1) as f64;
                1.0 + amplitude
                    * (std::f64::consts::TAU * (t / period + phase)).sin()
            }
        };
        f.max(0.05)
    }
}

/// Real-SGD workload attached to the simulation (Fig 1d/1e/2b).
#[derive(Debug, Clone)]
pub struct SgdConfig {
    /// Model dimension (paper: 1000).
    pub dim: usize,
    /// Minibatch rows per worker iteration.
    pub batch: usize,
    /// Shared synthetic dataset rows.
    pub pool: usize,
    /// Per-*round* cluster learning rate: each individual worker update
    /// applies `lr / P`. This is the standard data-parallel scaling —
    /// under BSP all P workers push gradients computed at the same
    /// snapshot, so an unscaled per-update rate would multiply the
    /// effective step by P and diverge for large clusters.
    pub lr: f32,
    /// Observation noise in the synthetic data.
    pub noise: f32,
    /// Model versions the snapshot store keeps reconstructable; pins
    /// that fall further behind are spilled (exactly) on demand. Larger
    /// values trade memory for fewer spills under heavy blocking. The
    /// store clamps this up to its minimum window (two checkpoint
    /// strides, currently 32) — values below that are effectively 32.
    pub versions: usize,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig {
            dim: 1000,
            batch: 32,
            pool: 4096,
            lr: 0.5,
            noise: 0.1,
            versions: 256,
        }
    }
}

/// Full cluster configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub n_nodes: usize,
    pub seed: u64,
    /// Simulated horizon in seconds (paper: 40).
    pub duration: f64,
    /// Base mean iteration time (seconds).
    pub mean_iter_time: f64,
    /// Per-node speed heterogeneity: node base mean is drawn uniformly
    /// from `mean_iter_time * [1-jitter, 1+jitter]`.
    pub speed_jitter: f64,
    pub iter_dist: TimeDist,
    pub stragglers: Option<StragglerConfig>,
    /// Mean one-way network delay for update messages (exponential).
    pub net_delay_mean: f64,
    /// Probability an update message is lost in transit (unreliable
    /// wide-area links, §3). Lost updates are counted separately; barrier
    /// progress is unaffected (control plane has its own retries).
    pub loss_rate: f64,
    /// Back-off before a blocked sampled-barrier worker re-samples.
    pub recheck_interval: f64,
    pub churn: Option<ChurnConfig>,
    /// Failure-detection latency for crash-stop churn: seconds between a
    /// crash and its `ConfirmDead` (the suspect + confirm timeline of the
    /// engine's SWIM-style detector, collapsed to one constant at
    /// simulation scale).
    pub crash_detect_secs: f64,
    /// Mean parameter-server shard-actor crash-stops per simulated
    /// second (Poisson, like [`ChurnConfig`] rates but for the *server*
    /// side). 0 disables the process entirely — no RNG draws, so
    /// pre-existing seeded trajectories replay bit-identically. Each
    /// crash stalls every worker's pushes until the shard is re-homed
    /// onto a replica ([`ClusterConfig::shard_rehome_secs`]) — the
    /// simulator-scale model of the live engine's replication plane
    /// ([`crate::engine::paramserver`]).
    pub shard_crash_rate: f64,
    /// Seconds from a shard-actor crash to its re-home completing
    /// (failure confirmation + promotion + bulk handoff); workers whose
    /// iterations finish inside the window are deferred to its end.
    pub shard_rehome_secs: f64,
    /// Server shards the crash process picks victims from (matches the
    /// live engine's `n_shards`; only meaningful with
    /// `shard_crash_rate > 0`).
    pub n_shards: usize,
    /// Record timelines every this many simulated seconds.
    pub sample_interval: f64,
    pub sgd: Option<SgdConfig>,
    /// Delta compression for SGD updates: every worker's pushed update
    /// goes through a per-worker [`DeltaEncoder`] (error feedback
    /// included) and the snapshot ring records the compressed payload.
    /// `None` (the default) bypasses the encoder entirely — no RNG
    /// draws, no arithmetic change, so the seeded golden trajectories
    /// replay bit-identically. `Some` with `mode = "dense"` keeps the
    /// arithmetic exact too (only byte accounting turns on), which is
    /// what the `ext_compress` ablation uses as its baseline.
    pub compress: Option<CompressConfig>,
    /// Deterministic time-varying load (flash crowds, diurnal swings).
    /// `None` (the default) is bit-identical to the pre-profile code.
    pub load_profile: Option<LoadProfile>,
    /// DSSP-style online adaptation of the barrier's effective θ/β: each
    /// node gets its own [`BarrierPolicy`] and retunes locally from its
    /// observed wait/compute ratio. `None` (the default) keeps one shared
    /// static policy — decisions and RNG stream bit-identical to the
    /// pre-adaptive code.
    pub adaptive: Option<AdaptiveConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_nodes: 1000,
            seed: 42,
            duration: 40.0,
            mean_iter_time: 1.0,
            speed_jitter: 0.3,
            iter_dist: TimeDist::Exponential,
            stragglers: None,
            net_delay_mean: 0.05,
            loss_rate: 0.0,
            recheck_interval: 0.25,
            churn: None,
            crash_detect_secs: 1.0,
            shard_crash_rate: 0.0,
            shard_rehome_secs: 0.5,
            n_shards: 1,
            sample_interval: 5.0,
            sgd: None,
            compress: None,
            load_profile: None,
            adaptive: None,
        }
    }
}

/// Everything the experiment harness needs from one run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Barrier method simulated.
    pub method: Method,
    /// Final step of every node active at the end.
    pub final_steps: Vec<u64>,
    /// (time, cumulative update messages received by the server).
    pub updates_timeline: Vec<(f64, u64)>,
    /// (time, normalised model error) — only when SGD is enabled.
    pub error_timeline: Vec<(f64, f64)>,
    /// Total update messages received by the server.
    pub update_msgs: u64,
    /// Update messages lost in transit (loss_rate > 0).
    pub lost_msgs: u64,
    /// Total control messages (barrier state reports + sampling traffic).
    pub control_msgs: u64,
    /// Total barrier crossings (sum over nodes of steps taken).
    pub total_advances: u64,
    /// Discrete events processed (simulator throughput metric).
    pub events: u64,
    /// Crash-stops executed (`ChurnConfig::crash_rate` victims).
    pub crashes: u64,
    /// Server-side shard-actor crash-stops executed
    /// (`ClusterConfig::shard_crash_rate`).
    pub shard_crashes: u64,
    /// Worker iterations deferred because they completed while a crashed
    /// shard was still being re-homed.
    pub shard_stalls: u64,
    /// Departed nodes (graceful leaves and crash-stops) in victim-pick
    /// order — the seeded churn trajectory the golden tests pin, so an
    /// enumeration-order change in victim selection is caught instead of
    /// silently shifting every seeded figure.
    pub churn_victims: Vec<u32>,
    /// Barrier crossings that blocked at least once (unified counter —
    /// same semantics as [`crate::engine::EngineReport::barrier_waits`]).
    pub barrier_waits: u64,
    /// Failed admission evaluations. The event-driven simulator parks
    /// global-view nodes rather than polling, so for BSP/SSP this counts
    /// park episodes; sampled methods count failed re-check attempts.
    pub stall_ticks: u64,
    /// Adaptation rounds fired across all per-node controllers (0 when
    /// [`ClusterConfig::adaptive`] is off).
    pub retunes: u64,
    /// (time, mean effective θ, mean effective β) over active nodes —
    /// recorded on timeline ticks, only when adaptation is on.
    pub adapt_timeline: Vec<(f64, f64, f64)>,
    /// Wire bytes of every SGD update payload shipped (summed over the
    /// per-worker encoders) — 0 unless [`ClusterConfig::compress`] is
    /// set. The bytes/step lever `ext_compress` measures.
    pub payload_bytes: u64,
    /// Total L1 mass the lossy encoders carried forward as error
    /// feedback (0 for dense / compression off).
    pub fed_back_mass: f64,
    /// Host wall-clock seconds spent simulating (perf metric).
    pub wall_secs: f64,
}

impl SimResult {
    pub fn mean_progress(&self) -> f64 {
        crate::util::stats::mean(
            &self.final_steps.iter().map(|&s| s as f64).collect::<Vec<_>>(),
        )
    }

    pub fn final_error(&self) -> Option<f64> {
        self.error_timeline.last().map(|&(_, e)| e)
    }
}

/// Node runtime status.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Status {
    /// Computing; will finish at the stored time.
    Computing,
    /// Finished compute, blocked at the barrier.
    Blocked,
    /// Departed (churn).
    Gone,
}

struct NodeState {
    status: Status,
    /// Mean iteration time for this node (includes straggler slowdown).
    mean_iter: f64,
    /// Snapshot-store version pinned at iteration start (SGD mode only;
    /// [`NO_VERSION`] otherwise).
    version: u64,
    /// Minibatch seed for the in-flight iteration.
    batch_seed: u64,
    /// Update messages in flight to the server (schedules outstanding).
    pending: u32,
    /// When the in-flight iteration started (barrier observation only —
    /// maintained unconditionally, consumed by the policy's stats).
    iter_started: f64,
    /// When the node finished computing and reached the barrier.
    barrier_entered: f64,
}

/// The run's barrier-decision handles: one shared static policy (every
/// node decides identically; counters aggregate), or one policy per node
/// when the adaptive controller is on — adaptation is per-node and
/// local, the paper's fully-distributed argument.
enum Policies {
    Shared(BarrierPolicy),
    PerNode { method: Method, cfg: AdaptiveConfig, nodes: Vec<BarrierPolicy> },
}

impl Policies {
    fn new(method: Method, adaptive: Option<AdaptiveConfig>, n: usize) -> Policies {
        match adaptive {
            // Per-node policies only when the method actually has a knob
            // to move (SSP/pSSP/pQuorum); BSP/ASP/pBSP stay shared.
            Some(cfg)
                if BarrierPolicy::with_adaptive(method, Some(cfg))
                    .is_adaptive() =>
            {
                let nodes = (0..n)
                    .map(|_| BarrierPolicy::with_adaptive(method, Some(cfg)))
                    .collect();
                Policies::PerNode { method, cfg, nodes }
            }
            _ => Policies::Shared(BarrierPolicy::new(method)),
        }
    }

    fn of(&mut self, node: usize) -> &mut BarrierPolicy {
        match self {
            Policies::Shared(p) => p,
            Policies::PerNode { nodes, .. } => &mut nodes[node],
        }
    }

    /// A node joined: give it a fresh controller (starting from the base
    /// method, not a neighbour's adapted state — it has no observations).
    fn joined(&mut self) {
        if let Policies::PerNode { method, cfg, nodes } = self {
            nodes.push(BarrierPolicy::with_adaptive(*method, Some(*cfg)));
        }
    }

    /// Lifetime (barrier_waits, stall_ticks, retunes) over all policies.
    fn totals(&self) -> (u64, u64, u64) {
        match self {
            Policies::Shared(p) => {
                (p.stats().barrier_waits, p.stats().stall_ticks, p.retunes())
            }
            Policies::PerNode { nodes, .. } => {
                nodes.iter().fold((0, 0, 0), |(w, s, r), p| {
                    (
                        w + p.stats().barrier_waits,
                        s + p.stats().stall_ticks,
                        r + p.retunes(),
                    )
                })
            }
        }
    }
}

/// Schedule `kind` at `t` unless it lies beyond the horizon — such events
/// could never be processed (the run loop stops at the first of them), so
/// skipping them keeps the queue small. Trajectories are unchanged: the
/// relative order of retained pushes, and hence every (time, seq)
/// tie-break among events that actually fire, is preserved. Returns
/// whether the event was enqueued.
#[inline]
fn schedule<Q: EventScheduler>(queue: &mut Q, horizon: f64, t: f64, kind: EventKind) -> bool {
    if t <= horizon {
        queue.push(t, kind);
        true
    } else {
        false
    }
}

/// The simulator. Construct with [`Simulator::new`], run with
/// [`Simulator::run`]; one instance per (config, method) pair.
pub struct Simulator {
    cfg: ClusterConfig,
    method: Method,
}

impl Simulator {
    pub fn new(cfg: ClusterConfig, method: Method) -> Simulator {
        Simulator { cfg, method }
    }

    /// Mean iteration time for `node` starting an iteration at `t`: the
    /// node's drawn base mean, scaled by the load profile when one is on.
    fn iter_mean(&self, node: usize, t: f64, base: f64) -> f64 {
        match self.cfg.load_profile {
            None => base,
            Some(p) => base * p.factor(node, self.cfg.n_nodes, t),
        }
    }

    /// Run the simulation to the configured horizon on the calendar
    /// queue (the production scheduler).
    pub fn run(&self) -> SimResult {
        self.run_with::<EventQueue>()
    }

    /// Run on the pre-refactor binary-heap scheduler. Slower, trajectory
    /// -identical — the oracle for the golden-trace tests and the
    /// heap-vs-calendar comparison in `benches/simulator.rs`.
    pub fn run_reference(&self) -> SimResult {
        self.run_with::<HeapQueue>()
    }

    fn run_with<Q: EventScheduler>(&self) -> SimResult {
        let start = std::time::Instant::now();
        let cfg = &self.cfg;
        let horizon = cfg.duration;
        let mut rng = Rng::new(cfg.seed);
        let mut queue = Q::default();
        let mut tracker = StepTracker::new(cfg.n_nodes);
        let mut scratch: Vec<usize> = Vec::new();
        let mut view: Vec<u64> = Vec::new();

        // SGD state (optional).
        let mut sgd = cfg
            .sgd
            .as_ref()
            .map(|s| SgdState::new(s, cfg.compress, cfg.n_nodes, &mut rng));

        // Per-node state.
        let mut nodes: Vec<NodeState> = (0..cfg.n_nodes)
            .map(|i| {
                let mut mean = cfg.mean_iter_time
                    * rng.uniform(1.0 - cfg.speed_jitter, 1.0 + cfg.speed_jitter);
                if let Some(st) = cfg.stragglers {
                    // First ⌊fraction·n⌋ nodes are the stragglers; the seeded
                    // uniform speed draw above keeps them otherwise typical.
                    if (i as f64) < st.fraction * cfg.n_nodes as f64 {
                        mean *= st.slowdown;
                    }
                }
                NodeState {
                    status: Status::Computing,
                    mean_iter: mean,
                    version: NO_VERSION,
                    batch_seed: 0,
                    pending: 0,
                    iter_started: 0.0,
                    barrier_entered: 0.0,
                }
            })
            .collect();

        // Barrier-decision handles (shared static, or per-node adaptive).
        let mut policies = Policies::new(self.method, cfg.adaptive, cfg.n_nodes);

        // Kick off: every node starts computing step 0 at t=0.
        for (i, node) in nodes.iter_mut().enumerate() {
            if let Some(s) = sgd.as_mut() {
                node.version = s.store.pin_head();
                node.batch_seed = rng.next_u64();
            }
            let mean = self.iter_mean(i, 0.0, node.mean_iter);
            let d = cfg.iter_dist.sample(mean, &mut rng);
            schedule(&mut queue, horizon, d, EventKind::ComputeDone { node: i });
        }
        // Timeline sampling ticks.
        let mut tick = cfg.sample_interval;
        while tick <= cfg.duration + 1e-9 {
            schedule(&mut queue, horizon, tick, EventKind::SampleTimeline);
            tick += cfg.sample_interval;
        }
        // Churn processes. Crash scheduling draws only when crash_rate is
        // set, so pre-membership configurations replay bit-identically.
        if let Some(churn) = cfg.churn {
            if churn.join_rate > 0.0 {
                let t = rng.exponential(1.0 / churn.join_rate);
                schedule(&mut queue, horizon, t, EventKind::Join);
            }
            if churn.leave_rate > 0.0 {
                let t = rng.exponential(1.0 / churn.leave_rate);
                schedule(&mut queue, horizon, t, EventKind::Leave);
            }
            if churn.crash_rate > 0.0 {
                let t = rng.exponential(1.0 / churn.crash_rate);
                schedule(&mut queue, horizon, t, EventKind::Crash);
            }
        }
        // Server-side shard crashes: like churn, the process draws from
        // the RNG only when enabled, so rate-0 configurations replay the
        // pre-shard-crash event stream bit-identically.
        if cfg.shard_crash_rate > 0.0 {
            let t = rng.exponential(1.0 / cfg.shard_crash_rate);
            schedule(&mut queue, horizon, t, EventKind::ShardCrash);
        }

        // Blocked bookkeeping.
        // Global methods: required-min-step -> blocked node list.
        let mut blocked_global: std::collections::BTreeMap<u64, Vec<u32>> =
            std::collections::BTreeMap::new();

        let mut update_msgs: u64 = 0;
        let mut lost_msgs: u64 = 0;
        let mut control_msgs: u64 = 0;
        let mut total_advances: u64 = 0;
        let mut events: u64 = 0;
        let mut crashes: u64 = 0;
        let mut shard_crashes: u64 = 0;
        let mut shard_stalls: u64 = 0;
        // Shard-crash stall window: while any shard is mid-re-home,
        // finishing iterations cannot push and are deferred to the end of
        // the window (monotone: each crash can only extend it).
        let mut shards_down: u32 = 0;
        let mut stall_until: f64 = 0.0;
        let mut churn_victims: Vec<u32> = Vec::new();
        let mut updates_timeline = Vec::new();
        let mut error_timeline = Vec::new();
        let mut adapt_timeline = Vec::new();

        // Adaptation moves θ/β, never the view *shape* — safe to latch.
        let is_global =
            matches!(self.method.build().view(), ViewRequirement::Global);

        while let Some(ev) = queue.pop() {
            if ev.time > cfg.duration {
                break;
            }
            events += 1;
            let t = ev.time;
            match ev.kind {
                EventKind::ComputeDone { node } => {
                    if nodes[node].status == Status::Gone {
                        continue;
                    }
                    // A crashed shard is mid-re-home: the push cannot be
                    // served, so the whole completion is deferred to the
                    // end of the stall window (the re-home event carries
                    // an earlier sequence number, so it fires first and
                    // the deferred completion proceeds normally).
                    if shards_down > 0 {
                        shard_stalls += 1;
                        let done = EventKind::ComputeDone { node };
                        schedule(&mut queue, horizon, stall_until, done);
                        continue;
                    }
                    // Push the update for the just-finished step; lossy
                    // links may drop it (the server never sees it).
                    if cfg.loss_rate > 0.0 && rng.bernoulli(cfg.loss_rate) {
                        lost_msgs += 1;
                    } else {
                        update_msgs += 1;
                        let delay = rng.exponential(cfg.net_delay_mean);
                        // Count only arrivals that will actually fire, so
                        // `pending == 0` reliably means "no in-flight
                        // reads" when reclaiming a departed node's pin.
                        let arrive = EventKind::UpdateArrive { node };
                        if schedule(&mut queue, horizon, t + delay, arrive) {
                            nodes[node].pending += 1;
                        }
                    }
                    // Global methods: one step-report control message.
                    if is_global {
                        control_msgs += 1;
                    }
                    // Reaching the barrier: wait time is measured from here.
                    nodes[node].barrier_entered = t;
                    // Barrier decision.
                    self.try_advance(
                        node, t, &mut nodes, &mut tracker, &mut rng, &mut scratch,
                        &mut view, &mut queue, &mut blocked_global, &mut control_msgs,
                        &mut total_advances, &mut sgd, &mut policies,
                    );
                }
                EventKind::Recheck { node, step } => {
                    if nodes[node].status != Status::Blocked
                        || tracker.step_of(node) != step
                    {
                        continue; // stale recheck
                    }
                    self.try_advance(
                        node, t, &mut nodes, &mut tracker, &mut rng, &mut scratch,
                        &mut view, &mut queue, &mut blocked_global, &mut control_msgs,
                        &mut total_advances, &mut sgd, &mut policies,
                    );
                }
                EventKind::UpdateArrive { node } => {
                    nodes[node].pending -= 1;
                    if let Some(s) = sgd.as_mut() {
                        s.apply_update(node, &nodes);
                        let st = &mut nodes[node];
                        if st.status == Status::Gone && st.pending == 0 {
                            // Last in-flight update of a departed node:
                            // its snapshot version can be reclaimed.
                            s.store.unpin(st.version);
                            st.version = NO_VERSION;
                        }
                    }
                }
                EventKind::SampleTimeline => {
                    updates_timeline.push((t, update_msgs));
                    if let Some(s) = sgd.as_ref() {
                        error_timeline.push((t, s.normalised_error()));
                    }
                    if let Policies::PerNode { nodes: pols, .. } = &policies {
                        let mut active = 0u64;
                        let (mut tsum, mut bsum) = (0.0f64, 0.0f64);
                        for (i, p) in pols.iter().enumerate() {
                            if tracker.is_active(i) {
                                active += 1;
                                tsum += p.staleness() as f64;
                                bsum += p.sample_size() as f64;
                            }
                        }
                        if active > 0 {
                            let n = active as f64;
                            adapt_timeline.push((t, tsum / n, bsum / n));
                        }
                    }
                }
                EventKind::Join => {
                    let id = tracker.join();
                    let mean_iter = cfg.mean_iter_time
                        * rng.uniform(1.0 - cfg.speed_jitter, 1.0 + cfg.speed_jitter);
                    let version = match sgd.as_mut() {
                        Some(s) => {
                            s.joined();
                            s.store.pin_head()
                        }
                        None => NO_VERSION,
                    };
                    nodes.push(NodeState {
                        status: Status::Computing,
                        mean_iter,
                        version,
                        batch_seed: rng.next_u64(),
                        pending: 0,
                        iter_started: t,
                        barrier_entered: t,
                    });
                    policies.joined();
                    let mean = self.iter_mean(id, t, nodes[id].mean_iter);
                    let d = cfg.iter_dist.sample(mean, &mut rng);
                    let done = EventKind::ComputeDone { node: id };
                    schedule(&mut queue, horizon, t + d, done);
                    if let Some(churn) = cfg.churn {
                        let next = t + rng.exponential(1.0 / churn.join_rate);
                        schedule(&mut queue, horizon, next, EventKind::Join);
                    }
                }
                EventKind::Leave => {
                    // Pick a random active victim in O(1) from the dense
                    // active list (uniform: k is uniform over the set).
                    if tracker.len() > 1 {
                        let victims = tracker.len();
                        let k = rng.next_below(victims as u64) as usize;
                        let victim = tracker.active_id_at(k);
                        // A crashed-but-unconfirmed node is still in the
                        // active list; it cannot leave twice.
                        if nodes[victim].status != Status::Gone {
                            churn_victims.push(victim as u32);
                            nodes[victim].status = Status::Gone;
                            if let Some(s) = sgd.as_mut() {
                                if nodes[victim].pending == 0 {
                                    s.store.unpin(nodes[victim].version);
                                    nodes[victim].version = NO_VERSION;
                                }
                            }
                            if let Some(new_min) = tracker.leave(victim) {
                                release_blocked(
                                    new_min, t, &mut blocked_global, &mut queue,
                                );
                            }
                        }
                    }
                    if let Some(churn) = cfg.churn {
                        let next = t + rng.exponential(1.0 / churn.leave_rate);
                        schedule(&mut queue, horizon, next, EventKind::Leave);
                    }
                }
                EventKind::Crash => {
                    // Same uniform victim pick as Leave, but the tracker
                    // keeps the victim: its frozen step poisons samples
                    // and pins the global minimum until the failure
                    // detector confirms the death — the realistic stall a
                    // crash inflicts on synchronous-parallel barriers.
                    if tracker.len() > 1 {
                        let victims = tracker.len();
                        let k = rng.next_below(victims as u64) as usize;
                        let victim = tracker.active_id_at(k);
                        if nodes[victim].status != Status::Gone {
                            churn_victims.push(victim as u32);
                            crashes += 1;
                            nodes[victim].status = Status::Gone;
                            let confirm = EventKind::ConfirmDead { node: victim };
                            let at = t + cfg.crash_detect_secs;
                            schedule(&mut queue, horizon, at, confirm);
                        }
                    }
                    if let Some(churn) = cfg.churn {
                        let next = t + rng.exponential(1.0 / churn.crash_rate);
                        schedule(&mut queue, horizon, next, EventKind::Crash);
                    }
                }
                EventKind::ConfirmDead { node } => {
                    // Suspect/confirm elapsed: the membership plane
                    // removes the victim, releasing anything its frozen
                    // step was blocking.
                    if tracker.is_active(node) {
                        if let Some(s) = sgd.as_mut() {
                            let st = &mut nodes[node];
                            if st.pending == 0 && st.version != NO_VERSION {
                                s.store.unpin(st.version);
                                st.version = NO_VERSION;
                            }
                        }
                        if let Some(new_min) = tracker.leave(node) {
                            release_blocked(
                                new_min, t, &mut blocked_global, &mut queue,
                            );
                        }
                    }
                }
                EventKind::ShardCrash => {
                    // Victim shard (uniform); re-home completes after the
                    // confirm + promote + handoff window.
                    let shard = rng.next_below(cfg.n_shards.max(1) as u64) as usize;
                    shard_crashes += 1;
                    shards_down += 1;
                    let done_at = t + cfg.shard_rehome_secs;
                    stall_until = stall_until.max(done_at);
                    schedule(&mut queue, horizon, done_at, EventKind::ShardRehomed { shard });
                    let next = t + rng.exponential(1.0 / cfg.shard_crash_rate);
                    schedule(&mut queue, horizon, next, EventKind::ShardCrash);
                }
                EventKind::ShardRehomed { shard: _ } => {
                    shards_down -= 1;
                }
                EventKind::Release { node } => {
                    if nodes[node].status != Status::Blocked {
                        continue;
                    }
                    self.advance_now(
                        node, t, &mut nodes, &mut tracker, &mut rng, &mut queue,
                        &mut blocked_global, &mut total_advances, &mut sgd,
                        &mut control_msgs, &mut policies,
                    );
                }
            }
        }

        let final_steps = (0..nodes.len())
            .filter(|&i| tracker.is_active(i))
            .map(|i| tracker.step_of(i))
            .collect();
        let (barrier_waits, stall_ticks, retunes) = policies.totals();
        let (payload_bytes, fed_back_mass) = match &sgd {
            Some(s) => (
                s.encoders.iter().map(|e| e.payload_bytes).sum(),
                s.encoders.iter().map(|e| e.fed_back_mass).sum(),
            ),
            None => (0, 0.0),
        };
        SimResult {
            method: self.method,
            final_steps,
            updates_timeline,
            error_timeline,
            update_msgs,
            lost_msgs,
            control_msgs,
            total_advances,
            events,
            crashes,
            shard_crashes,
            shard_stalls,
            churn_victims,
            barrier_waits,
            stall_ticks,
            retunes,
            adapt_timeline,
            payload_bytes,
            fed_back_mass,
            wall_secs: start.elapsed().as_secs_f64(),
        }
    }

    /// Evaluate the barrier for `node` (at barrier after finishing its
    /// step) and either advance it or park it (blocked map / recheck).
    ///
    /// The decision arithmetic lives in the node's [`BarrierPolicy`] —
    /// this layer only *acquires the view* (streamed tracker minimum, or
    /// a materialised sample for quorum methods) and feeds the outcome
    /// back for the wait/lag statistics window.
    #[allow(clippy::too_many_arguments)]
    fn try_advance<Q: EventScheduler>(
        &self,
        node: usize,
        t: f64,
        nodes: &mut [NodeState],
        tracker: &mut StepTracker,
        rng: &mut Rng,
        scratch: &mut Vec<usize>,
        view: &mut Vec<u64>,
        queue: &mut Q,
        blocked_global: &mut std::collections::BTreeMap<u64, Vec<u32>>,
        control_msgs: &mut u64,
        total_advances: &mut u64,
        sgd: &mut Option<SgdState>,
        policies: &mut Policies,
    ) {
        let my_step = tracker.step_of(node);
        let pol = policies.of(node);
        let view_req = pol.view();
        let (pass, lag) = match view_req {
            ViewRequirement::None => (true, None),
            ViewRequirement::Global => {
                let min = tracker.min_step();
                (pol.admit_min(my_step, Some(min)),
                    Some(my_step.saturating_sub(min)))
            }
            ViewRequirement::Sample(beta) => {
                *control_msgs += 2 * beta as u64; // query + reply per peer
                if pol.min_view_sufficient() {
                    match tracker.sample_min(node, beta, rng, scratch) {
                        // no peers observable => ASP semantics
                        None => (true, None),
                        Some(min) => (pol.admit_min(my_step, Some(min)),
                            Some(my_step.saturating_sub(min))),
                    }
                } else {
                    // quorum-style predicates need the full sampled view
                    tracker.sample_steps(node, beta, rng, scratch, view);
                    let lag = view
                        .iter()
                        .min()
                        .map(|&m| my_step.saturating_sub(m));
                    (pol.admit_view(my_step, view), lag)
                }
            }
        };
        pol.record_decision(pass, lag);
        let staleness = pol.staleness();
        if pass {
            self.advance_now(
                node, t, nodes, tracker, rng, queue, blocked_global,
                total_advances, sgd, control_msgs, policies,
            );
        } else {
            nodes[node].status = Status::Blocked;
            match view_req {
                ViewRequirement::Global => {
                    // Release when global min reaches my_step - θ (the
                    // *effective* θ this node blocked under).
                    let threshold = my_step.saturating_sub(staleness);
                    blocked_global.entry(threshold).or_default().push(node as u32);
                }
                ViewRequirement::Sample(_) => {
                    // Re-sample after a back-off (with ±50% jitter so
                    // blocked nodes don't re-check in lockstep).
                    let back = self.cfg.recheck_interval * rng.uniform(0.5, 1.5);
                    let recheck = EventKind::Recheck { node, step: my_step };
                    schedule(queue, self.cfg.duration, t + back, recheck);
                }
                ViewRequirement::None => unreachable!("ASP never blocks"),
            }
        }
    }

    /// Cross the barrier: advance the step, start the next iteration, and
    /// release any globally-blocked nodes the new minimum unblocks.
    #[allow(clippy::too_many_arguments)]
    fn advance_now<Q: EventScheduler>(
        &self,
        node: usize,
        t: f64,
        nodes: &mut [NodeState],
        tracker: &mut StepTracker,
        rng: &mut Rng,
        queue: &mut Q,
        blocked_global: &mut std::collections::BTreeMap<u64, Vec<u32>>,
        total_advances: &mut u64,
        sgd: &mut Option<SgdState>,
        control_msgs: &mut u64,
        policies: &mut Policies,
    ) {
        *total_advances += 1;
        // Feed the crossing into the policy's observation window: how
        // long this step computed vs how long it waited at the barrier.
        // Draws no randomness — the RNG stream below is untouched.
        let wait = (t - nodes[node].barrier_entered).max(0.0);
        let busy = (nodes[node].barrier_entered - nodes[node].iter_started).max(0.0);
        policies.of(node).record_crossing(wait, busy);
        nodes[node].status = Status::Computing;
        nodes[node].iter_started = t;
        // Pin a fresh snapshot version for the next iteration (O(1); the
        // pre-refactor code cloned the full model here).
        if let Some(s) = sgd.as_mut() {
            nodes[node].version = s.store.repin(nodes[node].version);
            nodes[node].batch_seed = rng.next_u64();
        }
        let mean = self.iter_mean(node, t, nodes[node].mean_iter);
        let d = self.cfg.iter_dist.sample(mean, rng);
        schedule(queue, self.cfg.duration, t + d, EventKind::ComputeDone { node });
        if let Some(new_min) = tracker.advance(node) {
            // A rising minimum is broadcast to blocked nodes; count one
            // control message per released node (the release notification).
            let released = release_blocked(new_min, t, blocked_global, queue);
            *control_msgs += released;
        }
    }
}

/// Move all globally-blocked nodes whose threshold the new minimum
/// satisfies onto the event queue (Release events at the current time).
/// Returns how many were released.
fn release_blocked<Q: EventScheduler>(
    new_min: u64,
    t: f64,
    blocked_global: &mut std::collections::BTreeMap<u64, Vec<u32>>,
    queue: &mut Q,
) -> u64 {
    let mut released = 0;
    loop {
        let Some((&thr, _)) = blocked_global.iter().next() else { break };
        if thr > new_min {
            break;
        }
        let list = blocked_global.remove(&thr).unwrap();
        for node in list {
            queue.push(t, EventKind::Release { node: node as usize });
            released += 1;
        }
    }
    released
}

/// Server-side SGD state over the shared synthetic dataset. The model
/// lives in a [`SnapshotStore`]; workers reference versions, never copies.
struct SgdState {
    model: LinearModel,
    data: Dataset,
    store: SnapshotStore,
    w_true: Vec<f32>,
    init_error: f64,
    lr: f32,
    batch: usize,
    /// Per-worker payload encoders ([`ClusterConfig::compress`]); empty
    /// when compression is off — updates then take the legacy dense
    /// path untouched.
    encoders: Vec<DeltaEncoder>,
    compress: Option<CompressConfig>,
}

impl SgdState {
    fn new(
        cfg: &SgdConfig,
        compress: Option<CompressConfig>,
        n_nodes: usize,
        rng: &mut Rng,
    ) -> SgdState {
        let data = Dataset::synthetic(cfg.pool, cfg.dim, cfg.noise, rng);
        let server_w = vec![0.0f32; cfg.dim];
        let init_error = crate::util::stats::l2_dist(&server_w, &data.w_true);
        let encoders = match compress {
            Some(c) => {
                (0..n_nodes).map(|_| DeltaEncoder::new(c, cfg.dim)).collect()
            }
            None => Vec::new(),
        };
        SgdState {
            model: LinearModel::new(cfg.dim),
            w_true: data.w_true.clone(),
            data,
            store: SnapshotStore::new(server_w, cfg.versions),
            init_error,
            // per-update rate = per-round rate / P (see SgdConfig::lr)
            lr: cfg.lr / n_nodes.max(1) as f32,
            batch: cfg.batch,
            encoders,
            compress,
        }
    }

    /// A node joined: give it a fresh encoder (empty residual — it has
    /// shipped nothing yet).
    fn joined(&mut self) {
        if let Some(c) = self.compress {
            self.encoders.push(DeltaEncoder::new(c, self.w_true.len()));
        }
    }

    /// Apply the update node `node` computed against its pinned snapshot
    /// version — bit-identical to the pre-refactor cloned-snapshot path
    /// when compression is off.
    fn apply_update(&mut self, node: usize, nodes: &[NodeState]) {
        let st = &nodes[node];
        if st.version == NO_VERSION {
            return;
        }
        let w = self.store.get(st.version);
        let grad =
            self.model.minibatch_grad(&self.data, w, st.batch_seed, self.batch);
        let mut delta = self.store.take_buf();
        for (d, g) in delta.iter_mut().zip(grad) {
            *d = self.lr * g;
        }
        match self.encoders.get_mut(node) {
            Some(enc) => {
                let payload = enc.encode(delta);
                self.store.apply_payload(payload);
            }
            None => self.store.apply_delta(delta),
        }
    }

    fn normalised_error(&self) -> f64 {
        crate::util::stats::l2_dist(self.store.head_slice(), &self.w_true)
            / self.init_error
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(n: usize, seed: u64) -> ClusterConfig {
        ClusterConfig {
            n_nodes: n,
            seed,
            duration: 20.0,
            mean_iter_time: 1.0,
            ..ClusterConfig::default()
        }
    }

    fn run(cfg: ClusterConfig, m: Method) -> SimResult {
        Simulator::new(cfg, m).run()
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(tiny_cfg(50, 7), Method::Pssp { sample: 5, staleness: 2 });
        let b = run(tiny_cfg(50, 7), Method::Pssp { sample: 5, staleness: 2 });
        assert_eq!(a.final_steps, b.final_steps);
        assert_eq!(a.update_msgs, b.update_msgs);
        assert_eq!(a.control_msgs, b.control_msgs);
    }

    #[test]
    fn different_seeds_differ() {
        let a = run(tiny_cfg(50, 1), Method::Asp);
        let b = run(tiny_cfg(50, 2), Method::Asp);
        assert_ne!(a.final_steps, b.final_steps);
    }

    #[test]
    fn bsp_is_lockstep() {
        let r = run(tiny_cfg(40, 3), Method::Bsp);
        let min = *r.final_steps.iter().min().unwrap();
        let max = *r.final_steps.iter().max().unwrap();
        assert!(max - min <= 1, "BSP spread {min}..{max}");
    }

    #[test]
    fn ssp_respects_staleness_bound() {
        for staleness in [0u64, 2, 4, 8] {
            let r = run(tiny_cfg(40, 4), Method::Ssp { staleness });
            let min = *r.final_steps.iter().min().unwrap();
            let max = *r.final_steps.iter().max().unwrap();
            assert!(
                max - min <= staleness + 1,
                "SSP(θ={staleness}) spread {min}..{max}"
            );
        }
    }

    #[test]
    fn asp_fastest_bsp_slowest() {
        let bsp = run(tiny_cfg(60, 5), Method::Bsp);
        let ssp = run(tiny_cfg(60, 5), Method::Ssp { staleness: 4 });
        let asp = run(tiny_cfg(60, 5), Method::Asp);
        assert!(asp.mean_progress() > ssp.mean_progress());
        assert!(ssp.mean_progress() > bsp.mean_progress());
    }

    #[test]
    fn pbsp_between_asp_and_bsp() {
        let bsp = run(tiny_cfg(60, 6), Method::Bsp);
        let asp = run(tiny_cfg(60, 6), Method::Asp);
        let pbsp = run(tiny_cfg(60, 6), Method::Pbsp { sample: 5 });
        assert!(pbsp.mean_progress() >= bsp.mean_progress());
        assert!(pbsp.mean_progress() <= asp.mean_progress());
    }

    #[test]
    fn pbsp_sample_zero_equals_asp_progress() {
        let asp = run(tiny_cfg(40, 8), Method::Asp);
        let p0 = run(tiny_cfg(40, 8), Method::Pbsp { sample: 0 });
        // identical rng consumption => identical trajectories
        assert_eq!(asp.final_steps, p0.final_steps);
    }

    #[test]
    fn update_messages_counted() {
        let r = run(tiny_cfg(30, 9), Method::Asp);
        assert_eq!(r.update_msgs, r.total_advances + pending_updates(&r));
        assert!(r.update_msgs > 0);
        // timeline is monotone
        for w in r.updates_timeline.windows(2) {
            assert!(w[0].1 <= w[1].1);
            assert!(w[0].0 < w[1].0);
        }
    }

    /// updates pushed == advances + nodes that pushed but stayed blocked/
    /// in-flight at the horizon; bound the difference by node count.
    fn pending_updates(r: &SimResult) -> u64 {
        r.update_msgs - r.total_advances
    }

    #[test]
    fn sampled_methods_cost_control_messages() {
        let pbsp = run(tiny_cfg(40, 10), Method::Pbsp { sample: 8 });
        assert!(pbsp.control_msgs >= 16 * pbsp.total_advances / 2);
        let asp = run(tiny_cfg(40, 10), Method::Asp);
        assert_eq!(asp.control_msgs, 0);
    }

    #[test]
    fn stragglers_slow_bsp_more_than_asp() {
        let mk = |st| ClusterConfig {
            stragglers: st,
            ..tiny_cfg(60, 11)
        };
        let some = Some(StragglerConfig { fraction: 0.1, slowdown: 4.0 });
        let bsp_clean = run(mk(None), Method::Bsp).mean_progress();
        let bsp_slow = run(mk(some), Method::Bsp).mean_progress();
        let asp_clean = run(mk(None), Method::Asp).mean_progress();
        let asp_slow = run(mk(some), Method::Asp).mean_progress();
        let bsp_ratio = bsp_slow / bsp_clean;
        let asp_ratio = asp_slow / asp_clean;
        assert!(
            bsp_ratio < asp_ratio,
            "BSP ratio {bsp_ratio} should drop below ASP ratio {asp_ratio}"
        );
    }

    #[test]
    fn sgd_error_decreases() {
        let cfg = ClusterConfig {
            sgd: Some(SgdConfig { dim: 100, ..SgdConfig::default() }),
            ..tiny_cfg(30, 12)
        };
        let r = run(cfg, Method::Pssp { sample: 5, staleness: 4 });
        let first = r.error_timeline.first().unwrap().1;
        let last = r.error_timeline.last().unwrap().1;
        assert!(last < first, "error should decrease: {first} -> {last}");
        assert!(last < 0.9, "normalised error {last}");
    }

    #[test]
    fn sgd_with_tiny_version_window_still_learns() {
        // A minimum-size snapshot ring (versions=1 clamps to the store's
        // 32-delta floor) must produce results identical to a roomy one:
        // any read past the window is served by an exact spill.
        let mk = |versions| ClusterConfig {
            sgd: Some(SgdConfig { dim: 50, versions, ..SgdConfig::default() }),
            ..tiny_cfg(25, 16)
        };
        let m = Method::Pbsp { sample: 4 };
        let tight = run(mk(1), m);
        let roomy = run(mk(4096), m);
        assert_eq!(tight.final_steps, roomy.final_steps);
        let bits = |r: &SimResult| -> Vec<u64> {
            r.error_timeline.iter().map(|&(_, e)| e.to_bits()).collect()
        };
        assert_eq!(bits(&tight), bits(&roomy), "spilled reads must be exact");
    }

    #[test]
    fn compress_off_and_dense_mode_share_a_trajectory() {
        // `compress: None` and an explicit dense-mode config differ only
        // in byte accounting — the arithmetic (and hence the bitwise
        // error trajectory) must be identical.
        let mk = |compress| ClusterConfig {
            sgd: Some(SgdConfig { dim: 60, ..SgdConfig::default() }),
            compress,
            ..tiny_cfg(25, 31)
        };
        let m = Method::Pssp { sample: 5, staleness: 2 };
        let off = run(mk(None), m);
        let dense = run(mk(Some(CompressConfig::default())), m);
        assert_eq!(off.final_steps, dense.final_steps);
        let bits = |r: &SimResult| -> Vec<u64> {
            r.error_timeline.iter().map(|&(_, e)| e.to_bits()).collect()
        };
        assert_eq!(bits(&off), bits(&dense), "dense mode must stay exact");
        assert_eq!(off.payload_bytes, 0);
        assert!(dense.payload_bytes > 0, "dense mode still counts bytes");
        assert_eq!(dense.fed_back_mass, 0.0);
    }

    #[test]
    fn topk_compression_cuts_payload_bytes_4x_and_still_learns() {
        let mk = |compress| ClusterConfig {
            sgd: Some(SgdConfig { dim: 160, ..SgdConfig::default() }),
            compress,
            churn: Some(ChurnConfig {
                join_rate: 0.3, // joins exercise encoder growth
                leave_rate: 0.0,
                crash_rate: 0.0,
            }),
            ..tiny_cfg(25, 32)
        };
        let m = Method::Pssp { sample: 5, staleness: 2 };
        let dense = run(mk(Some(CompressConfig::default())), m);
        let topk = run(mk(CompressConfig::parse("topk", 10, "i8")), m);
        // Same seed, same event stream — only the payloads shrink.
        assert_eq!(dense.update_msgs, topk.update_msgs);
        assert!(topk.payload_bytes > 0);
        assert!(
            topk.payload_bytes * 4 <= dense.payload_bytes,
            "top-k bytes {} not 4x under dense {}",
            topk.payload_bytes,
            dense.payload_bytes
        );
        assert!(topk.fed_back_mass > 0.0, "lossy mode never fed back");
        let first = topk.error_timeline.first().unwrap().1;
        let last = topk.error_timeline.last().unwrap().1;
        assert!(last < first, "error should decrease: {first} -> {last}");
    }

    #[test]
    fn churn_keeps_running() {
        let cfg = ClusterConfig {
            churn: Some(ChurnConfig { join_rate: 0.5, leave_rate: 0.5, crash_rate: 0.0 }),
            ..tiny_cfg(30, 13)
        };
        for m in Method::paper_five(5, 4) {
            let r = run(cfg.clone(), m);
            assert!(!r.final_steps.is_empty());
            assert!(r.total_advances > 0, "{m}: no progress under churn");
        }
    }

    #[test]
    fn churn_with_sgd_reclaims_departed_pins() {
        let cfg = ClusterConfig {
            churn: Some(ChurnConfig { join_rate: 1.0, leave_rate: 1.0, crash_rate: 0.0 }),
            sgd: Some(SgdConfig { dim: 40, ..SgdConfig::default() }),
            ..tiny_cfg(20, 17)
        };
        let r = run(cfg, Method::Pssp { sample: 4, staleness: 4 });
        assert!(r.total_advances > 0);
        assert!(r.final_error().is_some());
    }

    #[test]
    fn crash_churn_confirms_victims_and_keeps_running() {
        let cfg = ClusterConfig {
            churn: Some(ChurnConfig {
                join_rate: 0.5,
                leave_rate: 0.0,
                crash_rate: 0.5,
            }),
            crash_detect_secs: 0.5,
            ..tiny_cfg(30, 21)
        };
        for m in Method::paper_five(5, 4) {
            let r = run(cfg.clone(), m);
            assert!(r.crashes > 0, "{m}: no crash fired in 20s at 0.5/s");
            assert_eq!(r.crashes as usize, r.churn_victims.len());
            assert!(r.total_advances > 0, "{m}: no progress under crash churn");
        }
        // Seed-deterministic, including the victim stream.
        let a = run(cfg.clone(), Method::Pssp { sample: 5, staleness: 2 });
        let b = run(cfg, Method::Pssp { sample: 5, staleness: 2 });
        assert_eq!(a.churn_victims, b.churn_victims);
        assert_eq!(a.final_steps, b.final_steps);
    }

    #[test]
    fn slow_crash_detection_stalls_bsp_harder() {
        // A crash victim pins the BSP minimum until ConfirmDead fires, so
        // progress must be monotone in detection speed: the same crash
        // schedule with a 5s suspect/confirm timeline can only do worse
        // than with a 0.05s one.
        let mk = |detect| ClusterConfig {
            churn: Some(ChurnConfig {
                join_rate: 0.0,
                leave_rate: 0.0,
                crash_rate: 0.4,
            }),
            crash_detect_secs: detect,
            ..tiny_cfg(40, 22)
        };
        let fast = run(mk(0.05), Method::Bsp);
        let slow = run(mk(5.0), Method::Bsp);
        assert!(fast.crashes > 0 && slow.crashes > 0);
        assert!(
            fast.mean_progress() > slow.mean_progress(),
            "fast-detect BSP {} should out-progress slow-detect {}",
            fast.mean_progress(),
            slow.mean_progress()
        );
    }

    #[test]
    fn crash_with_sgd_reclaims_pins_after_confirmation() {
        let cfg = ClusterConfig {
            churn: Some(ChurnConfig {
                join_rate: 1.0,
                leave_rate: 0.5,
                crash_rate: 0.5,
            }),
            crash_detect_secs: 0.5,
            sgd: Some(SgdConfig { dim: 40, ..SgdConfig::default() }),
            ..tiny_cfg(20, 23)
        };
        let r = run(cfg, Method::Pssp { sample: 4, staleness: 4 });
        assert!(r.total_advances > 0);
        assert!(r.crashes > 0);
        assert!(r.final_error().is_some());
    }

    #[test]
    fn shard_crashes_stall_but_never_stop_progress() {
        let mk = |rate| ClusterConfig {
            shard_crash_rate: rate,
            shard_rehome_secs: 0.5,
            n_shards: 8,
            ..tiny_cfg(30, 24)
        };
        for m in Method::paper_five(5, 4) {
            let r = run(mk(0.4), m);
            assert!(r.shard_crashes > 0, "{m}: no shard crash in 20s at 0.4/s");
            assert!(r.shard_stalls > 0, "{m}: crashes never deferred a push");
            assert!(r.total_advances > 0, "{m}: no progress under shard crashes");
        }
        // Stall windows cost progress: the same seed without the crash
        // process must do at least as well.
        let faulty = run(mk(0.4), Method::Asp);
        let clean = run(mk(0.0), Method::Asp);
        assert_eq!(clean.shard_crashes, 0);
        assert_eq!(clean.shard_stalls, 0);
        assert!(clean.mean_progress() >= faulty.mean_progress());
        // Seed-deterministic, like every other churn process.
        let a = run(mk(0.4), Method::Pssp { sample: 5, staleness: 2 });
        let b = run(mk(0.4), Method::Pssp { sample: 5, staleness: 2 });
        assert_eq!(a.final_steps, b.final_steps);
        assert_eq!(a.shard_crashes, b.shard_crashes);
        assert_eq!(a.shard_stalls, b.shard_stalls);
    }

    #[test]
    fn shard_crash_rate_zero_replays_the_legacy_trajectory() {
        // The rate-0 guard must keep the event stream bit-identical to a
        // config that predates the shard-crash fields entirely.
        let base = tiny_cfg(40, 25);
        let with_fields = ClusterConfig {
            shard_crash_rate: 0.0,
            shard_rehome_secs: 123.0, // irrelevant when the rate is 0
            n_shards: 16,
            ..tiny_cfg(40, 25)
        };
        let m = Method::Pssp { sample: 5, staleness: 2 };
        let a = run(base, m);
        let b = run(with_fields, m);
        assert_eq!(a.final_steps, b.final_steps);
        assert_eq!(a.update_msgs, b.update_msgs);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn barrier_wait_counters_track_blocking() {
        // ASP never blocks; BSP in a heterogeneous cluster must.
        let asp = run(tiny_cfg(40, 26), Method::Asp);
        assert_eq!(asp.barrier_waits, 0);
        assert_eq!(asp.stall_ticks, 0);
        let bsp = run(tiny_cfg(40, 26), Method::Bsp);
        assert!(bsp.barrier_waits > 0, "BSP never waited?");
        assert!(bsp.stall_ticks > 0);
        let pssp = run(tiny_cfg(40, 26), Method::Pssp { sample: 8, staleness: 1 });
        assert!(pssp.barrier_waits > 0, "tight pSSP never waited?");
        // Sampled methods re-check: ticks can exceed wait episodes.
        assert!(pssp.stall_ticks >= pssp.barrier_waits);
        // Static runs never retune and record no adaptation timeline.
        assert_eq!(bsp.retunes, 0);
        assert!(bsp.adapt_timeline.is_empty());
    }

    #[test]
    fn adaptive_off_and_knobless_methods_replay_the_legacy_trajectory() {
        // `adaptive: None` is the default — and attaching a controller to
        // a method with no adaptable knobs (pBSP) must also change
        // nothing: both fall back to the shared static policy.
        let m = Method::Pbsp { sample: 5 };
        let a = run(tiny_cfg(40, 27), m);
        let b = run(
            ClusterConfig {
                adaptive: Some(AdaptiveConfig::default()),
                ..tiny_cfg(40, 27)
            },
            m,
        );
        assert_eq!(a.final_steps, b.final_steps);
        assert_eq!(a.update_msgs, b.update_msgs);
        assert_eq!(a.control_msgs, b.control_msgs);
        assert_eq!(a.events, b.events);
        assert_eq!(b.retunes, 0);
    }

    #[test]
    fn load_profile_none_replays_and_flash_crowd_slows_progress() {
        let m = Method::Bsp;
        let clean = run(tiny_cfg(40, 28), m);
        let with_field = run(
            ClusterConfig { load_profile: None, ..tiny_cfg(40, 28) },
            m,
        );
        assert_eq!(clean.final_steps, with_field.final_steps);
        assert_eq!(clean.events, with_field.events);
        // A mid-run flash crowd must cost BSP progress.
        let crowd = run(
            ClusterConfig {
                load_profile: Some(LoadProfile::FlashCrowd {
                    fraction: 0.1,
                    slowdown: 6.0,
                    start: 5.0,
                    duration: 10.0,
                }),
                ..tiny_cfg(40, 28)
            },
            m,
        );
        assert!(
            crowd.mean_progress() < clean.mean_progress(),
            "flash crowd should slow BSP: {} !< {}",
            crowd.mean_progress(),
            clean.mean_progress()
        );
    }

    #[test]
    fn adaptive_pssp_retunes_and_is_deterministic() {
        let mk = || ClusterConfig {
            load_profile: Some(LoadProfile::FlashCrowd {
                fraction: 0.15,
                slowdown: 8.0,
                start: 4.0,
                duration: 8.0,
            }),
            adaptive: Some(AdaptiveConfig { window: 4, ..AdaptiveConfig::default() }),
            ..tiny_cfg(40, 29)
        };
        let m = Method::Pssp { sample: 8, staleness: 1 };
        let a = run(mk(), m);
        assert!(a.retunes > 0, "controller never fired");
        assert!(!a.adapt_timeline.is_empty());
        // The flash crowd must push mean effective θ above the base at
        // some point of the run.
        let max_theta = a
            .adapt_timeline
            .iter()
            .map(|&(_, th, _)| th)
            .fold(0.0f64, f64::max);
        assert!(max_theta > 1.0, "θ never loosened past base 1: {max_theta}");
        let b = run(mk(), m);
        assert_eq!(a.final_steps, b.final_steps);
        assert_eq!(a.retunes, b.retunes);
        assert_eq!(a.adapt_timeline, b.adapt_timeline);
    }

    #[test]
    fn diurnal_profile_factor_is_bounded_and_phase_shifted() {
        let p = LoadProfile::Diurnal { amplitude: 0.8, period: 20.0 };
        for node in [0usize, 13, 99] {
            for t in [0.0, 3.0, 11.5, 19.0, 40.0] {
                let f = p.factor(node, 100, t);
                assert!((0.05..=1.8).contains(&f), "factor {f} out of range");
            }
        }
        // Different nodes see different phases at the same instant.
        assert_ne!(p.factor(10, 100, 7.0), p.factor(60, 100, 7.0));
    }

    #[test]
    fn single_node_cluster_progresses_under_all_methods() {
        for m in Method::paper_five(5, 4) {
            let r = run(tiny_cfg(1, 14), m);
            assert!(r.final_steps[0] > 0, "{m}");
        }
    }

    #[test]
    fn zero_duration_produces_no_events() {
        let cfg = ClusterConfig { duration: 0.0, ..tiny_cfg(10, 15) };
        let r = run(cfg, Method::Asp);
        assert_eq!(r.total_advances, 0);
        assert!(r.final_steps.iter().all(|&s| s == 0));
    }
}
