//! `actor` — the leader entrypoint of the Actor/PSP framework.
//!
//! See `actor --help` (or [`actor_psp::cli::USAGE`]) for subcommands.

use anyhow::{bail, Result};

use std::sync::Arc;

use actor_psp::barrier::{AdaptiveConfig, Method};
use actor_psp::cli::{Args, USAGE};
use actor_psp::config::{parse_departure, parse_kill_shard, parse_partitions, Config};
use actor_psp::engine::delta::{CompressConfig, CompressMode};
use actor_psp::engine::gossip::GossipConfig;
use actor_psp::engine::membership::MembershipConfig;
use actor_psp::engine::node::{self, Monitor, Workload};
use actor_psp::engine::p2p::{self, Dissemination, P2pConfig};
use actor_psp::engine::paramserver::{self, PsConfig};
use actor_psp::engine::transport::{
    FaultConfig, FaultyTransport, TcpTransport, TransportConfig,
};
use actor_psp::exp::{self, ExpOpts};
use actor_psp::model::linear::{minibatch_grad_fn, Dataset};
use actor_psp::runtime::{Manifest, Runtime};
use actor_psp::sim::{ClusterConfig, SgdConfig, Simulator};
use actor_psp::theory::{mean_bound, variance_bound, BoundParams};
use actor_psp::train::{psp_train_lm, train_lm, Corpus, TransformerTrainer};
use actor_psp::util::rng::Rng;
use actor_psp::util::stats::{l2_dist, Summary};

fn main() {
    actor_psp::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    let args = match Args::parse(
        argv,
        &["quick", "sgd", "full-mesh", "no-membership", "adaptive"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "exp" => cmd_exp(args),
        "sim" => cmd_sim(args),
        "ps" => cmd_ps(args),
        "p2p" => cmd_p2p(args),
        "node" => cmd_node(args),
        "join" => cmd_join(args),
        "train" => cmd_train(args),
        "bounds" => cmd_bounds(args),
        "info" => cmd_info(args),
        other => bail!("unknown subcommand '{other}'\n\n{USAGE}"),
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    args.check_known(&[
        "nodes", "duration", "seed", "sample", "staleness", "out", "quick",
        "jobs", "config",
    ])?;
    let id = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    // config file first ([exp] section), CLI flags override
    let mut opts = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.exp_opts()?,
        None => ExpOpts::default(),
    };
    if let Some(v) = args.parse_flag::<usize>("nodes")? {
        opts.nodes = v;
    }
    if let Some(v) = args.parse_flag::<f64>("duration")? {
        opts.duration = v;
    }
    if let Some(v) = args.parse_flag::<u64>("seed")? {
        opts.seed = v;
    }
    if let Some(v) = args.parse_flag::<usize>("sample")? {
        opts.sample = v;
    }
    if let Some(v) = args.parse_flag::<u64>("staleness")? {
        opts.staleness = v;
    }
    if let Some(v) = args.parse_flag::<usize>("jobs")? {
        opts.jobs = v;
    }
    opts.quick = args.switch("quick");
    opts.out_dir = args.get("out").map(Into::into);
    exp::run(id, &opts)?;
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let mut known = vec![
        "method", "nodes", "duration", "seed", "sgd", "config", "quick",
        "crash-rate", "detect", "shard-crash-rate", "shard-rehome", "shards",
    ];
    known.extend_from_slice(COMPRESS_FLAGS);
    known.extend_from_slice(ADAPTIVE_FLAGS);
    args.check_known(&known)?;
    // config file first, CLI flags override
    let mut cluster = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.cluster_config()?,
        None => ClusterConfig::default(),
    };
    let method = match args.get("method") {
        Some(m) => Method::parse(m)
            .ok_or_else(|| anyhow::anyhow!("bad --method '{m}'"))?,
        None => match args.get("config") {
            Some(path) => {
                Config::load(std::path::Path::new(path))?.barrier_method()?
            }
            None => Method::Pssp { sample: 10, staleness: 4 },
        },
    };
    if let Some(n) = args.parse_flag::<usize>("nodes")? {
        cluster.n_nodes = n;
    }
    if let Some(d) = args.parse_flag::<f64>("duration")? {
        cluster.duration = d;
    }
    if let Some(s) = args.parse_flag::<u64>("seed")? {
        cluster.seed = s;
    }
    if args.switch("sgd") && cluster.sgd.is_none() {
        cluster.sgd = Some(SgdConfig::default());
    }
    if let Some(rate) = args.parse_flag::<f64>("crash-rate")? {
        let mut churn = cluster.churn.unwrap_or_default();
        churn.crash_rate = rate;
        let any = churn.join_rate > 0.0 || churn.leave_rate > 0.0 || churn.crash_rate > 0.0;
        cluster.churn = any.then_some(churn);
    }
    if let Some(secs) = args.parse_flag::<f64>("detect")? {
        cluster.crash_detect_secs = secs;
    }
    if let Some(rate) = args.parse_flag::<f64>("shard-crash-rate")? {
        cluster.shard_crash_rate = rate;
    }
    if let Some(secs) = args.parse_flag::<f64>("shard-rehome")? {
        cluster.shard_rehome_secs = secs;
    }
    if let Some(n) = args.parse_flag::<usize>("shards")? {
        cluster.n_shards = n.max(1);
    }
    cluster.compress = compress_flags(args)?;
    cluster.adaptive = adaptive_flags(args)?;
    let adaptive_on = cluster.adaptive.is_some();

    println!(
        "simulating {} nodes for {:.0}s under {method} (seed {})",
        cluster.n_nodes, cluster.duration, cluster.seed
    );
    let detect_secs = cluster.crash_detect_secs;
    let r = Simulator::new(cluster, method).run();
    let steps: Vec<f64> = r.final_steps.iter().map(|&s| s as f64).collect();
    let s = Summary::of(&steps);
    println!(
        "progress: mean {:.2}  p50 {:.0}  spread [{:.0}, {:.0}]  iqr {:.1}",
        s.mean,
        s.p50,
        s.min,
        s.max,
        s.iqr()
    );
    println!(
        "messages: {} updates, {} control; advances {}; events {} \
         ({:.2}M events/s host)",
        r.update_msgs,
        r.control_msgs,
        r.total_advances,
        r.events,
        r.events as f64 / r.wall_secs.max(1e-9) / 1e6,
    );
    if adaptive_on {
        let (theta, beta) = r
            .adapt_timeline
            .last()
            .map(|&(_, t, b)| (t, b))
            .unwrap_or((0.0, 0.0));
        println!(
            "barrier: {} wait(s), {} stall tick(s), {} retune(s); final mean \
             effective θ {theta:.1} β {beta:.1}",
            r.barrier_waits, r.stall_ticks, r.retunes,
        );
    }
    if r.crashes > 0 {
        println!(
            "churn: {} crash-stop(s) (detect latency {:.2}s), {} departure(s) total",
            r.crashes,
            detect_secs,
            r.churn_victims.len(),
        );
    }
    if r.shard_crashes > 0 {
        println!(
            "shard faults: {} shard crash(es), {} deferred completion(s)",
            r.shard_crashes, r.shard_stalls,
        );
    }
    if r.payload_bytes > 0 {
        println!(
            "compression: {} payload B ({:.1} B/update), fed-back mass {:.3}",
            r.payload_bytes,
            r.payload_bytes as f64 / r.update_msgs.max(1) as f64,
            r.fed_back_mass,
        );
    }
    if let Some(e) = r.final_error() {
        println!("final normalised model error: {e:.4}");
    }
    Ok(())
}

/// Run the live sharded parameter-server engine on the pure-Rust linear
/// SGD workload and print the progress/message/throughput summary.
fn cmd_ps(args: &Args) -> Result<()> {
    let mut known = vec![
        "config", "workers", "steps", "method", "dim", "lr", "seed", "shards",
        "push-batch", "schedule-blocks", "replication", "vnodes", "kill-shard",
    ];
    known.extend_from_slice(COMPRESS_FLAGS);
    known.extend_from_slice(ADAPTIVE_FLAGS);
    args.check_known(&known)?;
    // config file first, CLI flags override
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.ps_config()?,
        None => PsConfig::default(),
    };
    if let Some(m) = args.get("method") {
        cfg.method =
            Method::parse(m).ok_or_else(|| anyhow::anyhow!("bad --method '{m}'"))?;
    }
    if let Some(v) = args.parse_flag::<usize>("workers")? {
        cfg.n_workers = v;
    }
    if let Some(v) = args.parse_flag::<u64>("steps")? {
        cfg.steps_per_worker = v;
    }
    if let Some(v) = args.parse_flag::<usize>("dim")? {
        cfg.dim = v;
    }
    if let Some(v) = args.parse_flag::<f32>("lr")? {
        cfg.lr = v;
    }
    if let Some(v) = args.parse_flag::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.parse_flag::<usize>("shards")? {
        cfg.n_shards = v.max(1);
    }
    if let Some(v) = args.parse_flag::<usize>("push-batch")? {
        cfg.push_batch = v.max(1);
    }
    if let Some(v) = args.parse_flag::<usize>("schedule-blocks")? {
        cfg.schedule_blocks = (v > 0).then_some(v);
    }
    if let Some(v) = args.parse_flag::<usize>("replication")? {
        cfg.replication = v;
    }
    if let Some(v) = args.parse_flag::<usize>("vnodes")? {
        cfg.vnodes = v;
    }
    if let Some(s) = args.get("kill-shard") {
        cfg.kill_shard = Some(parse_kill_shard(s)?);
    }
    if let Some(c) = compress_flags(args)? {
        cfg.compress = c;
    }
    cfg.adaptive = adaptive_flags(args)?;

    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    let rows = (cfg.dim * 8).clamp(256, 4096);
    let data = Arc::new(Dataset::synthetic(rows, cfg.dim, 0.05, &mut rng));
    let w_true = data.w_true.clone();
    let grad = minibatch_grad_fn(Arc::clone(&data), 32);

    println!(
        "parameter server: {} workers x {} steps, d={} under {} \
         ({} shard(s), push batch {}, replication {}, vnodes {})",
        cfg.n_workers,
        cfg.steps_per_worker,
        cfg.dim,
        cfg.method,
        cfg.n_shards,
        cfg.push_batch,
        cfg.replication,
        cfg.vnodes,
    );
    let init_err = l2_dist(&vec![0.0; cfg.dim], &w_true);
    // A lost shard (every candidate dead before re-home) is a loud error
    // plus whatever the run salvaged — not a process abort.
    let (r, lost) = match paramserver::try_run(&cfg, vec![0.0; cfg.dim], grad) {
        Ok(r) => (r, false),
        Err(e) => {
            eprintln!("ENGINE ERROR: {e}");
            eprintln!("partial report follows (counters up to the abort):");
            (e.partial, true)
        }
    };
    let total_steps: u64 = r.steps.iter().sum();
    println!(
        "steps {}  update msgs {}  control msgs {}  error {:.4} -> {:.4}",
        total_steps,
        r.update_msgs,
        r.control_msgs,
        init_err,
        l2_dist(&r.model, &w_true),
    );
    if cfg.adaptive.is_some() {
        println!(
            "barrier: {} wait(s), {} stall tick(s); effective θ {:?} β {:?}",
            r.barrier_waits, r.stall_ticks, r.eff_staleness, r.eff_sample,
        );
    }
    if r.confirmed_dead > 0 || r.replica_pulls > 0 || r.handoff_bytes > 0 {
        println!(
            "durability: {} shard death(s) confirmed, {} replica-served \
             pull(s), {} handoff byte(s)",
            r.confirmed_dead, r.replica_pulls, r.handoff_bytes,
        );
    }
    if !cfg.compress.is_dense() {
        println!(
            "compression: {} — {} payload B ({:.1} B/push), fed-back mass {:.3}",
            r.compress_mode,
            r.payload_bytes,
            r.payload_bytes as f64 / r.update_msgs.max(1) as f64,
            r.fed_back_mass,
        );
    }
    println!(
        "wall {:.3}s  ({:.1}k worker-steps/s, {:.1}k pushes/s)",
        r.wall_secs,
        total_steps as f64 / r.wall_secs.max(1e-9) / 1e3,
        r.update_msgs as f64 / r.wall_secs.max(1e-9) / 1e3,
    );
    if lost {
        bail!("parameter-server run aborted on a lost shard (see above)");
    }
    Ok(())
}

/// Run the fully-distributed p2p engine: replicated model, gossip-plane
/// delta dissemination (or the legacy full mesh with --full-mesh), and
/// per-worker overlay-sampled barriers.
fn cmd_p2p(args: &Args) -> Result<()> {
    let mut known = vec![
        "config", "workers", "steps", "method", "dim", "lr", "seed", "fanout",
        "flush", "ttl", "full-mesh", "crash", "leave", "suspect-ms",
        "confirm-ms", "no-membership",
    ];
    known.extend_from_slice(COMPRESS_FLAGS);
    known.extend_from_slice(ADAPTIVE_FLAGS);
    args.check_known(&known)?;
    // config file first, CLI flags override
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.p2p_config()?,
        None => P2pConfig::default(),
    };
    if let Some(m) = args.get("method") {
        cfg.method =
            Method::parse(m).ok_or_else(|| anyhow::anyhow!("bad --method '{m}'"))?;
    }
    if let Some(v) = args.parse_flag::<usize>("workers")? {
        cfg.n_workers = v;
    }
    if let Some(v) = args.parse_flag::<u64>("steps")? {
        cfg.steps_per_worker = v;
    }
    if let Some(v) = args.parse_flag::<usize>("dim")? {
        cfg.dim = v;
    }
    if let Some(v) = args.parse_flag::<f32>("lr")? {
        cfg.lr = v;
    }
    if let Some(v) = args.parse_flag::<u64>("seed")? {
        cfg.seed = v;
    }
    if args.switch("full-mesh") {
        cfg.dissemination = Dissemination::FullMesh;
    } else {
        // start from whatever the config file picked, then apply knobs
        let mut g = match &cfg.dissemination {
            Dissemination::Gossip(g) => g.clone(),
            Dissemination::FullMesh => GossipConfig::default(),
        };
        let mut touched = false;
        if let Some(v) = args.parse_flag::<usize>("fanout")? {
            g.fanout = v;
            touched = true;
        }
        if let Some(v) = args.parse_flag::<u64>("flush")? {
            g.flush_every = v.max(1);
            touched = true;
        }
        if let Some(v) = args.parse_flag::<u32>("ttl")? {
            g.ttl = v;
            touched = true;
        }
        if touched || matches!(cfg.dissemination, Dissemination::Gossip(_)) {
            cfg.dissemination = Dissemination::Gossip(g);
        }
    }
    // Membership plane: threshold overrides, or off entirely. The flags
    // never silently re-enable a plane the config file disabled, and the
    // positivity rule matches the [membership] section's.
    if args.switch("no-membership") {
        cfg.membership = None;
    } else {
        let suspect = args.parse_flag::<f64>("suspect-ms")?;
        let confirm = args.parse_flag::<f64>("confirm-ms")?;
        if suspect.is_some() || confirm.is_some() {
            let Some(mut m) = cfg.membership.clone() else {
                bail!(
                    "--suspect-ms/--confirm-ms have no effect while the \
                     config file sets [membership] enabled = false"
                );
            };
            if let Some(v) = suspect {
                if v <= 0.0 {
                    bail!("--suspect-ms must be positive");
                }
                m.suspect_after = (v * 1000.0) as u64;
            }
            if let Some(v) = confirm {
                if v <= 0.0 {
                    bail!("--confirm-ms must be positive");
                }
                m.confirm_after = (v * 1000.0) as u64;
            }
            cfg.membership = Some(m);
        }
    }
    // Scripted departures (crash-stop / graceful leave).
    if let Some(s) = args.get("crash") {
        cfg.churn.push(parse_departure(s, false)?);
    }
    if let Some(s) = args.get("leave") {
        cfg.churn.push(parse_departure(s, true)?);
    }
    if let Some(c) = compress_flags(args)? {
        cfg.compress = c;
    }
    cfg.adaptive = adaptive_flags(args)?;

    let mut rng = Rng::new(cfg.seed ^ 0xD157);
    let rows = (cfg.dim * 8).clamp(256, 4096);
    let data = Arc::new(Dataset::synthetic(rows, cfg.dim, 0.05, &mut rng));
    let w_true = data.w_true.clone();
    let grad = minibatch_grad_fn(Arc::clone(&data), 32);

    let plane = match &cfg.dissemination {
        Dissemination::FullMesh => "full-mesh".to_string(),
        Dissemination::Gossip(g) => format!(
            "gossip fanout={} flush={} ttl={}",
            g.fanout, g.flush_every, g.ttl
        ),
    };
    println!(
        "p2p engine: {} workers x {} steps, d={} under {} ({plane})",
        cfg.n_workers, cfg.steps_per_worker, cfg.dim, cfg.method,
    );
    let init_err = l2_dist(&vec![0.0; cfg.dim], &w_true);
    let r = p2p::run(&cfg, vec![0.0; cfg.dim], grad);
    let total_steps: u64 = r.steps.iter().sum();
    let mesh_msgs = total_steps * (cfg.n_workers.saturating_sub(1)) as u64;
    println!(
        "steps {}  update msgs {} ({:.2}/worker-step; full mesh would send {})  \
         control msgs {}",
        total_steps,
        r.update_msgs,
        r.update_msgs as f64 / total_steps.max(1) as f64,
        mesh_msgs,
        r.control_msgs,
    );
    println!(
        "rumors: {} applied, {} dup-dropped, {} copies; {} late delta(s) dropped \
         ({} missing, {} discarded)",
        r.applied_rumors, r.dup_rumors, r.rumor_copies, r.dropped_deltas,
        r.missing_rumors, r.discarded_msgs,
    );
    if !r.departed.is_empty() || r.confirmed_dead > 0 {
        println!(
            "membership: departed {:?}; {} death confirmation(s), {} repair \
             msg(s), {} rumor(s) repaired",
            r.departed, r.confirmed_dead, r.repair_msgs, r.repaired_rumors,
        );
    }
    if cfg.adaptive.is_some() {
        println!(
            "barrier: {} wait(s), {} stall tick(s); effective θ {:?} β {:?}",
            r.barrier_waits, r.stall_ticks, r.eff_staleness, r.eff_sample,
        );
    }
    if !cfg.compress.is_dense() {
        println!(
            "compression: {} — {} payload B ({:.1} B/update), fed-back mass {:.3}",
            r.compress_mode,
            r.payload_bytes,
            r.payload_bytes as f64 / r.update_msgs.max(1) as f64,
            r.fed_back_mass,
        );
    }
    println!(
        "error {:.4} -> {:.4}  wall {:.3}s",
        init_err,
        l2_dist(&r.model, &w_true),
        r.wall_secs,
    );
    Ok(())
}

/// Shared flag handling for the deployment plane: `[transport]` config
/// section first, CLI flags override.
fn transport_flags(args: &Args) -> Result<TransportConfig> {
    let mut tcfg = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.transport_config()?,
        None => TransportConfig::default(),
    };
    if let Some(v) = args.get("listen") {
        tcfg.listen = v.to_string();
    }
    if let Some(v) = args.get("monitor") {
        tcfg.monitor = Some(v.to_string());
    }
    if let Some(v) = args.parse_flag::<f64>("linger")? {
        if v < 0.0 {
            bail!("--linger must be non-negative");
        }
        tcfg.linger_secs = v;
    }
    Ok(tcfg)
}

/// Membership flags for the deployed seed: `[membership]` config
/// section first (default: enabled, same thresholds as the p2p engine),
/// CLI overrides. Joiners never pass these — detection timing reaches
/// them inside the Welcome, so the cluster agrees from one place.
fn membership_flags(args: &Args) -> Result<Option<MembershipConfig>> {
    let mut mem = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.membership_config()?,
        None => Some(MembershipConfig::default()),
    };
    if args.switch("no-membership") {
        return Ok(None);
    }
    let suspect = args.parse_flag::<f64>("suspect-ms")?;
    let confirm = args.parse_flag::<f64>("confirm-ms")?;
    if suspect.is_some() || confirm.is_some() {
        let Some(mut m) = mem else {
            bail!(
                "--suspect-ms/--confirm-ms have no effect while the \
                 config file sets [membership] enabled = false"
            );
        };
        if let Some(v) = suspect {
            if v <= 0.0 {
                bail!("--suspect-ms must be positive");
            }
            m.suspect_after = (v * 1000.0) as u64;
        }
        if let Some(v) = confirm {
            if v <= 0.0 {
                bail!("--confirm-ms must be positive");
            }
            m.confirm_after = (v * 1000.0) as u64;
        }
        mem = Some(m);
    }
    Ok(mem)
}

/// Fault-injection flags: `[fault]` config section first, `--fault-*`
/// overrides — any one of them enables the decorator when the section
/// is absent. Faults are per-process: each node wraps only its own
/// transport, so asymmetric chaos is expressible.
fn fault_flags(args: &Args) -> Result<Option<FaultConfig>> {
    let mut fc = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.fault_config()?,
        None => None,
    };
    let prob = |name: &str| -> Result<Option<f64>> {
        match args.parse_flag::<f64>(name)? {
            Some(v) if !(0.0..=1.0).contains(&v) => {
                bail!("--{name} must be a probability in [0, 1]")
            }
            v => Ok(v),
        }
    };
    let ms = |name: &str| -> Result<Option<std::time::Duration>> {
        match args.parse_flag::<f64>(name)? {
            Some(v) if v < 0.0 => bail!("--{name} must be non-negative"),
            Some(v) => Ok(Some(std::time::Duration::from_secs_f64(v / 1000.0))),
            None => Ok(None),
        }
    };
    if let Some(v) = prob("fault-drop")? {
        fc.get_or_insert_with(FaultConfig::default).drop_p = v;
    }
    if let Some(v) = prob("fault-dup")? {
        fc.get_or_insert_with(FaultConfig::default).dup_p = v;
    }
    if let Some(v) = prob("fault-delay")? {
        fc.get_or_insert_with(FaultConfig::default).delay_p = v;
    }
    if let Some(v) = prob("fault-reorder")? {
        fc.get_or_insert_with(FaultConfig::default).reorder_p = v;
    }
    if let Some(v) = ms("fault-delay-ms")? {
        fc.get_or_insert_with(FaultConfig::default).delay_max = v;
    }
    if let Some(v) = ms("fault-retry-ms")? {
        fc.get_or_insert_with(FaultConfig::default).retry = v;
    }
    if let Some(v) = ms("fault-heal-ms")? {
        fc.get_or_insert_with(FaultConfig::default).heal_after = Some(v);
    }
    if let Some(v) = args.parse_flag::<u64>("fault-seed")? {
        fc.get_or_insert_with(FaultConfig::default).seed = v;
    }
    if let Some(s) = args.get("fault-partition") {
        fc.get_or_insert_with(FaultConfig::default).partitions = parse_partitions(s)?;
    }
    Ok(fc)
}

/// Adaptive-barrier flags: `[barrier] adaptive = true` in the config
/// file first, CLI overrides. `--adaptive` switches the DSSP-style
/// controller on with defaults; any `--adaptive-*` value flag both
/// enables and tunes it. Deliberately **per-node-local**: joiners pass
/// their own flags — adaptation never rides the Welcome, because each
/// node retunes from the stragglers *it* observes.
fn adaptive_flags(args: &Args) -> Result<Option<AdaptiveConfig>> {
    let mut ac = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.barrier_adaptive()?,
        None => None,
    };
    if args.switch("adaptive") {
        ac.get_or_insert_with(AdaptiveConfig::default);
    }
    if let Some(v) = args.parse_flag::<u32>("adaptive-window")? {
        ac.get_or_insert_with(AdaptiveConfig::default).window = v;
    }
    if let Some(v) = args.parse_flag::<u64>("adaptive-max-staleness")? {
        ac.get_or_insert_with(AdaptiveConfig::default).max_staleness = v;
    }
    if let Some(v) = args.parse_flag::<usize>("adaptive-max-sample")? {
        ac.get_or_insert_with(AdaptiveConfig::default).max_sample = v;
    }
    Ok(ac.map(|a| a.normalized()))
}

/// Delta-compression flags: `[compress]` config section first, CLI
/// overrides merged on top (`--compress dense|topk|quant`, `--top-k N`,
/// `--quant i8|f16|i4`). `None` when neither file nor flags mention
/// compression — the exact legacy payloads.
fn compress_flags(args: &Args) -> Result<Option<CompressConfig>> {
    let file = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?.compress_config()?,
        None => None,
    };
    let mode = args.get("compress");
    let top_k = args.parse_flag::<usize>("top-k")?;
    let quant = args.get("quant");
    if mode.is_none() && top_k.is_none() && quant.is_none() {
        return Ok(file);
    }
    let base = file.unwrap_or_default();
    let (base_mode, base_quant) = match base.mode {
        CompressMode::Dense => ("dense", "i8"),
        CompressMode::TopK => ("topk", "i8"),
        CompressMode::QuantI8 => ("quant", "i8"),
        CompressMode::QuantF16 => ("quant", "f16"),
        CompressMode::QuantI4 => ("quant", "i4"),
    };
    // --quant alone is clearly asking for a quantized run.
    let implied = if quant.is_some() && base_mode == "dense" { "quant" } else { base_mode };
    CompressConfig::parse(
        mode.unwrap_or(implied),
        top_k.unwrap_or(base.top_k),
        quant.unwrap_or(base_quant),
    )
    .ok_or_else(|| {
        anyhow::anyhow!(
            "bad --compress/--quant (mode: dense|topk|quant; quant: i8|f16|i4)"
        )
    })
    .map(Some)
}

const COMPRESS_FLAGS: &[&str] = &["compress", "top-k", "quant"];

const ADAPTIVE_FLAGS: &[&str] = &[
    "adaptive", "adaptive-window", "adaptive-max-staleness",
    "adaptive-max-sample",
];

const FAULT_FLAGS: &[&str] = &[
    "fault-drop", "fault-dup", "fault-delay", "fault-delay-ms",
    "fault-retry-ms", "fault-reorder", "fault-partition", "fault-heal-ms",
    "fault-seed",
];

/// Seed a real multi-process cluster: bind, accept `n-1` joiners, hand
/// each the workload, then run as node 0 over TCP.
fn cmd_node(args: &Args) -> Result<()> {
    let mut known = vec![
        "config", "n", "listen", "monitor", "linger", "steps", "dim", "lr",
        "seed", "method", "fanout", "flush", "ttl", "drain-secs", "step-ms",
        "suspect-ms", "confirm-ms", "no-membership",
    ];
    known.extend_from_slice(COMPRESS_FLAGS);
    known.extend_from_slice(FAULT_FLAGS);
    known.extend_from_slice(ADAPTIVE_FLAGS);
    args.check_known(&known)?;
    let tcfg = transport_flags(args)?;
    let fault = fault_flags(args)?;
    let adaptive = adaptive_flags(args)?;
    let n: usize = args.flag_or("n", 3)?;
    if n < 1 {
        bail!("--n must be at least 1");
    }
    let method = match args.get("method") {
        Some(m) => Method::parse(m)
            .ok_or_else(|| anyhow::anyhow!("bad --method '{m}'"))?,
        None => Method::Pssp { sample: 2, staleness: 2 },
    };
    let step_ms: f64 = args.flag_or("step-ms", 0.0)?;
    if step_ms < 0.0 {
        bail!("--step-ms must be non-negative");
    }
    let wl = Workload {
        n,
        steps: args.flag_or("steps", 30)?,
        dim: args.flag_or("dim", 64)?,
        lr: args.flag_or("lr", 0.1)?,
        seed: args.flag_or("seed", 42)?,
        method,
        gossip: GossipConfig {
            fanout: args.flag_or("fanout", 2)?,
            flush_every: args.flag_or::<u64>("flush", 1)?.max(1),
            ttl: args.flag_or("ttl", 6)?,
        },
        drain_timeout: std::time::Duration::from_secs_f64(
            args.flag_or("drain-secs", 10.0)?,
        ),
        membership: membership_flags(args)?,
        compress: compress_flags(args)?.unwrap_or_default(),
    };
    let listener = std::net::TcpListener::bind(&tcfg.listen)?;
    let seed_addr = listener.local_addr()?.to_string();
    println!(
        "node 0 (seed): {} workers x {} steps, d={} under {}; listening on \
         {seed_addr}, waiting for {} joiner(s); membership {}",
        wl.n,
        wl.steps,
        wl.dim,
        wl.method,
        n - 1,
        match &wl.membership {
            Some(m) => format!(
                "on (suspect {}ms, confirm {}ms)",
                m.suspect_after / 1000,
                m.confirm_after / 1000
            ),
            None => "off".to_string(),
        },
    );
    let roster = node::seed_bootstrap(&listener, &wl, &seed_addr)?;
    run_deployed(
        0,
        &wl,
        listener,
        roster,
        &tcfg,
        fault,
        adaptive,
        std::time::Duration::from_secs_f64(step_ms / 1000.0),
    )
}

/// Join a cluster: `actor join <seed host:port>`. Everything about the
/// workload arrives in the seed's Welcome.
fn cmd_join(args: &Args) -> Result<()> {
    let mut known = vec!["config", "listen", "monitor", "linger", "drain-secs"];
    known.extend_from_slice(FAULT_FLAGS);
    known.extend_from_slice(ADAPTIVE_FLAGS);
    args.check_known(&known)?;
    let seed_addr = args
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("actor join needs the seed's host:port"))?;
    let tcfg = transport_flags(args)?;
    let fault = fault_flags(args)?;
    let adaptive = adaptive_flags(args)?;
    let listener = std::net::TcpListener::bind(&tcfg.listen)?;
    let my_addr = listener.local_addr()?.to_string();
    let drain =
        std::time::Duration::from_secs_f64(args.flag_or("drain-secs", 10.0)?);
    println!("joining {seed_addr} (listening on {my_addr})...");
    let (welcome, roster) = node::join_bootstrap(
        seed_addr,
        &my_addr,
        std::time::Duration::from_secs(60),
    )?;
    let wl = Workload::from_welcome(&welcome, drain).ok_or_else(|| {
        anyhow::anyhow!("seed sent unparseable method '{}'", welcome.method)
    })?;
    println!(
        "node {}: joined a cluster of {} ({} steps, d={} under {}; membership {})",
        welcome.id,
        wl.n,
        wl.steps,
        wl.dim,
        wl.method,
        if wl.membership.is_some() { "on" } else { "off" },
    );
    run_deployed(
        welcome.id as usize,
        &wl,
        listener,
        roster,
        &tcfg,
        fault,
        adaptive,
        std::time::Duration::ZERO,
    )
}

/// The deployed run itself, common to seed and joiners: TCP transport
/// over the bootstrap listener, the same synthetic linear workload as
/// the sim engines (derived from the cluster seed, so every process
/// regresses against the same ground truth), optional monitor, linger.
fn run_deployed(
    id: usize,
    wl: &Workload,
    listener: std::net::TcpListener,
    roster: Vec<(usize, String)>,
    tcfg: &TransportConfig,
    fault: Option<FaultConfig>,
    adaptive: Option<AdaptiveConfig>,
    step_pad: std::time::Duration,
) -> Result<()> {
    let monitor = match &tcfg.monitor {
        Some(addr) => {
            let m = Monitor::serve(addr)?;
            println!("node {id}: monitor on http://{}/", m.addr());
            Some(m)
        }
        None => None,
    };
    let mut transport = TcpTransport::with_listener(id, wl.n, listener)?;
    transport.set_backoff(tcfg.reconnect_min, tcfg.reconnect_max);
    transport.connect_peers(&roster);

    let mut rng = Rng::new(wl.seed ^ 0xDA7A);
    let rows = (wl.dim * 8).clamp(256, 4096);
    let data = Arc::new(Dataset::synthetic(rows, wl.dim, 0.05, &mut rng));
    let w_true = data.w_true.clone();
    let grad = minibatch_grad_fn(Arc::clone(&data), 32);

    let mut cfg = wl.node_config(id);
    cfg.step_pad = step_pad;
    cfg.adaptive = adaptive;
    if let Some(a) = &cfg.adaptive {
        println!(
            "node {id}: adaptive barrier on (window {}, θ ≤ {}, β ≤ {})",
            a.window, a.max_staleness, a.max_sample,
        );
    }
    let init_err = l2_dist(&vec![0.0; wl.dim], &w_true);
    // Both arms consume the transport: it drops (joining writer threads
    // and flushing their queues) before the linger, which only exists
    // to keep the monitor scrapeable.
    let (out, bytes_out, bytes_in, send_fail) = match fault {
        Some(fc) => {
            println!(
                "node {id}: fault injection on — drop {} dup {} delay {} \
                 reorder {} partitions {:?} heal {:?}",
                fc.drop_p, fc.dup_p, fc.delay_p, fc.reorder_p, fc.partitions,
                fc.heal_after,
            );
            let mut faulty = FaultyTransport::new(transport, fc);
            let out = node::run_node(&cfg, &mut faulty, grad, monitor.as_ref());
            let s = faulty.stats();
            println!(
                "node {id}: injected — {} dropped(retx), {} dup, {} delayed, \
                 {} reordered, {} partitioned",
                s.dropped, s.duplicated, s.delayed, s.reordered, s.partitioned,
            );
            let inner = faulty.inner();
            (out, inner.bytes_out(), inner.bytes_in(), inner.send_fail())
        }
        None => {
            let mut tr = transport;
            let out = node::run_node(&cfg, &mut tr, grad, monitor.as_ref());
            (out, tr.bytes_out(), tr.bytes_in(), tr.send_fail())
        }
    };
    let r = &out.report;
    println!(
        "node {id}: done — applied per origin {:?} ({} rumors, {} dups, {} copies)",
        out.applied_of, r.applied_rumors, r.dup_rumors, r.rumor_copies,
    );
    println!(
        "node {id}: {} update msgs, {} control msgs; {} dropped delta(s) \
         ({} missing, {} discarded); drain polls {}",
        r.update_msgs,
        r.control_msgs,
        r.dropped_deltas,
        r.missing_rumors,
        r.discarded_msgs,
        r.drain_polls,
    );
    if r.confirmed_dead > 0 || r.repair_msgs > 0 {
        println!(
            "node {id}: membership — {} death(s) confirmed, departed {:?}, \
             {} repair msg(s), {} repaired rumor(s), {} abandoned send(s)",
            r.confirmed_dead, r.departed, r.repair_msgs, r.repaired_rumors,
            send_fail,
        );
    }
    if cfg.adaptive.is_some() {
        println!(
            "node {id}: barrier — {} wait(s), {} stall tick(s); effective \
             θ {:?} β {:?}",
            r.barrier_waits, r.stall_ticks, r.eff_staleness, r.eff_sample,
        );
    }
    println!(
        "node {id}: error {init_err:.4} -> {:.4}  wall {:.3}s  wire {} B out / {} B in",
        l2_dist(&r.model, &w_true),
        r.wall_secs,
        bytes_out,
        bytes_in,
    );
    if tcfg.linger_secs > 0.0 {
        println!(
            "node {id}: lingering {:.1}s for monitor scrapes",
            tcfg.linger_secs
        );
        std::thread::sleep(std::time::Duration::from_secs_f64(tcfg.linger_secs));
    }
    let dropped = r.dropped_deltas;
    drop(monitor);
    if dropped > 0 {
        bail!("node {id} dropped {dropped} delta(s) — dissemination incomplete");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(&[
        "config", "steps", "lr", "seed", "workers", "method", "artifacts", "accum",
    ])?;
    let cfg = args.get_or("config", "tiny");
    let steps: u64 = args.flag_or("steps", 200)?;
    let lr: f32 = args.flag_or("lr", 0.1)?;
    let seed: u64 = args.flag_or("seed", 42)?;
    let workers: usize = args.flag_or("workers", 1)?;
    let dir = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(Manifest::default_dir);

    let rt = Runtime::with_dir(&dir)?;
    println!("platform: {}", rt.platform());
    let mut trainer = TransformerTrainer::new(rt, &cfg, seed as i32)?;
    println!(
        "model '{}': {} params ({} tensors), vocab {}, seq {}, batch {}; \
         uniform-loss baseline {:.3}",
        cfg,
        trainer.meta.param_count,
        trainer.meta.n_params,
        trainer.meta.vocab,
        trainer.meta.seq,
        trainer.meta.batch,
        trainer.uniform_loss(),
    );
    let corpus = Corpus::synthetic(1 << 16, trainer.meta.vocab, seed ^ 0xC0);
    let log = if workers <= 1 {
        train_lm(&mut trainer, &corpus, steps, lr, seed)?
    } else {
        let method = match args.get("method") {
            Some(m) => Method::parse(m)
                .ok_or_else(|| anyhow::anyhow!("bad --method '{m}'"))?,
            None => Method::Pssp { sample: 3, staleness: 2 },
        };
        let accum: usize = args.flag_or("accum", 1)?;
        println!(
            "PSP-paced data-parallel: {workers} workers under {method} \
             (accum {accum})"
        );
        psp_train_lm(
            &mut trainer, &corpus, method, workers, steps, lr, seed, None, accum,
        )?
    };
    for (step, loss) in log
        .losses
        .iter()
        .step_by((steps as usize / 20).max(1))
        .chain(log.losses.last())
    {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    println!(
        "trained {} steps in {:.1}s ({:.2} steps/s); loss {:.3} -> {:.3} \
         (tail mean {:.3})",
        log.losses.len(),
        log.wall_secs,
        log.steps_per_sec,
        log.first_loss(),
        log.last_loss(),
        log.tail_mean(20),
    );
    Ok(())
}

fn cmd_bounds(args: &Args) -> Result<()> {
    args.check_known(&["beta", "staleness", "t", "fr"])?;
    let beta: usize = args.flag_or("beta", 10)?;
    let r: u64 = args.flag_or("staleness", 4)?;
    let t: u64 = args.flag_or("t", 10_000)?;
    let f_r: f64 = args.flag_or("fr", 0.9)?;
    let bp = BoundParams { beta, r, t, f_r };
    println!("PSP convergence bounds (Theorem 3): beta={beta} r={r} T={t} F(r)={f_r}");
    println!("  a = F(r)^beta        = {:.6}", bp.a());
    println!("  avg lag mean bound   = {:.6}", mean_bound(&bp));
    println!("  avg lag var bound    = {:.6}", variance_bound(&bp));
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.check_known(&["artifacts"])?;
    let dir = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(Manifest::default_dir);
    let rt = Runtime::with_dir(&dir)?;
    println!("platform: {}", rt.platform());
    println!("artifacts ({}):", dir.display());
    for a in &rt.manifest().artifacts {
        println!(
            "  {:28} {:12} {:>2} in / {:>2} out",
            a.name,
            a.kind,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}
