//! Command-line argument parsing (offline substitute for `clap`).
//!
//! Grammar: `actor <subcommand> [positional] [--flag value | --switch]`.
//! Each subcommand declares its flags; unknown flags are errors with a
//! usage dump.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments: a subcommand, positionals, and `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `switch_names` lists the valueless flags.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        switch_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    out.flags.insert(name.to_string(), val);
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = arg;
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn parse_flag<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{name} {v}: {e}")),
        }
    }

    pub fn flag_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.parse_flag(name)?.unwrap_or(default))
    }

    /// Error on flags not in the allowed set (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        for s in &self.switches {
            if !known.contains(&s.as_str()) {
                bail!("unknown switch --{s}");
            }
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
actor — Probabilistic Synchronous Parallel (Actor framework reproduction)

USAGE:
  actor exp <id|all> [--nodes N] [--duration S] [--seed N] [--sample B]
            [--staleness T] [--out DIR] [--quick] [--jobs J] [--config FILE]
      Regenerate a paper table/figure. ids: table1 fig1a..fig1e fig2a..fig2c
      fig3 fig4 fig5, or 'all'. Extensions (beyond the paper): abl_*
      ext_churn ext_loss ext_shards ext_p2p ext_crash ext_chaos
      ext_transport ext_adaptive ext_compress. Sweep grids fan out over J
      worker threads (default: one per core; reports are identical for
      every J).

  actor sim --method M [--nodes N] [--duration S] [--seed N] [--sgd]
            [--crash-rate F] [--detect S] [--shard-crash-rate F]
            [--shard-rehome S] [--shards K] [--compress ...] [--adaptive ...]
            [--config FILE]
      One simulated cluster run; prints the progress/error/message summary.
      M: bsp | ssp[:t] | asp | pbsp[:b] | pssp[:b[:t]] | pquorum:b:t:q
      --crash-rate adds F crash-stops/s (victims keep poisoning samples
      and pinning the BSP/SSP minimum until failure detection confirms
      them after --detect seconds). --shard-crash-rate adds F server
      shard-actor crashes/s; each stalls worker pushes until the shard is
      re-homed after --shard-rehome seconds.

  actor ps [--workers N] [--steps N] [--method M] [--dim D] [--lr F]
           [--seed N] [--shards K] [--push-batch B] [--schedule-blocks NB]
           [--replication R] [--vnodes V] [--kill-shard K:A] [--compress ...]
           [--adaptive ...] [--config FILE]
      Run the live sharded parameter-server engine (real threads, pure-Rust
      linear SGD): K model shards, gradients accumulated for B steps and
      scattered as one batched push per touched shard. --replication streams
      every applied batch to R ring-successor replicas; --vnodes places
      parameters by consistent hashing over V virtual positions per shard
      (0 = contiguous blocks); --kill-shard K:A crash-stops shard K after
      its A-th batch — training must finish with zero lost updates.

  actor p2p [--workers N] [--steps N] [--method M] [--dim D] [--lr F]
            [--seed N] [--fanout F] [--flush B] [--ttl T] [--full-mesh]
            [--crash W:S] [--leave W:S] [--suspect-ms F] [--confirm-ms F]
            [--no-membership] [--compress ...] [--adaptive ...] [--config FILE]
      Run the fully-distributed p2p engine (real threads, replicated
      model, overlay-sampled barriers). Deltas travel the gossip plane:
      F overlay-sampled shortcuts + the ring successor per forward, B
      steps compacted per rumor, T shortcut hops — O(n·fanout) messages
      per step. --full-mesh restores the legacy O(n²) broadcast.
      M must be asp | pbsp[:b] | pssp[:b[:t]] | pquorum:b:t:q.
      Crash-fault membership plane: --crash W:S crash-stops worker W at
      step S (no Done, no handoff — survivors must detect and repair);
      --leave W:S departs gracefully (store handoff + Leave). Suspect/
      confirm heartbeat thresholds via --suspect-ms/--confirm-ms;
      --no-membership disables detection (a crash then stalls survivors
      until drain_timeout).

  actor node [--n N] [--listen HOST:PORT] [--monitor HOST:PORT] [--linger S]
             [--steps N] [--dim D] [--lr F] [--seed N] [--method M]
             [--fanout F] [--flush B] [--ttl T] [--drain-secs S] [--step-ms F]
             [--suspect-ms F] [--confirm-ms F] [--no-membership]
             [--fault-drop P] [--fault-dup P] [--fault-delay P]
             [--fault-delay-ms F] [--fault-retry-ms F] [--fault-reorder P]
             [--fault-partition A:B,..] [--fault-heal-ms F] [--fault-seed N]
             [--compress ...] [--adaptive ...] [--config FILE]
      Seed a real multi-process cluster (deployment plane). Binds the
      listen address, accepts N-1 `actor join` processes, assigns ids in
      connect order, ships each the full workload, then runs as node 0:
      one worker per OS process, deltas and barrier state over TCP with
      a hand-rolled length-prefixed binary codec (reconnect + backoff;
      the protocol is idempotent, so resends are safe). --monitor serves
      ring topology + live report counters (and membership verdicts) as
      JSON over HTTP; --linger keeps the process (and monitor) alive S
      seconds after the run so CI can scrape final counters; --step-ms
      pads every step to F ms of synthetic compute (chaos-demo pacing).
      Crash-fault membership is ON by default: heartbeats ride the Step
      broadcast; a process silent past suspect+confirm is confirmed
      dead, evicted from every survivor's ring view, and its ring
      successor re-announces + re-injects its rumors from the custody
      store — a kill -9 costs ~suspect+confirm, not drain_timeout.
      Thresholds via --suspect-ms/--confirm-ms (shipped to joiners in
      the Welcome); --no-membership restores the stall-to-drain
      behavior. --fault-* wrap the wire in a seeded fault-injection
      decorator (drop = first-attempt loss with retransmit after
      --fault-retry-ms, plus duplicates/delays/reordering and
      one-directional --fault-partition A:B pairs, healing after
      --fault-heal-ms). Config sections: [transport], [membership],
      [fault].

  actor join <seed HOST:PORT> [--listen HOST:PORT] [--monitor HOST:PORT]
             [--linger S] [--drain-secs S] [--fault-*...] [--adaptive ...]
             [--config FILE]
      Join a seeded cluster: binds its own listener (default port 0 =
      OS-assigned), announces it to the seed, and receives its id plus
      the whole workload — a cluster is configured in exactly one place
      (membership timing included, via the Welcome). --fault-* flags
      inject faults on this process's wire only; --adaptive is likewise
      per-process — adaptation is a local decision and never rides the
      Welcome.

  Delta compression (sim, ps, p2p, node): --compress dense|topk|quant
  picks the update payload codec — topk ships the k largest-magnitude
  coordinates as (index, value) pairs (--top-k K, default 32), quant
  ships the full vector at reduced precision (--quant i8|f16|i4,
  default i8; --quant alone implies --compress quant). Truncated mass
  is fed back into the next update (error feedback), so lossy modes
  still converge. Joiners inherit the codec from the seed's Welcome.
  Config file: [compress] mode/top_k/quant. With compression off (the
  default), every engine replays bit-identically to previous releases.

  Adaptive barriers (sim, ps, p2p, node, join): --adaptive turns on the
  DSSP-style online controller — each node watches its own barrier wait
  fraction over a sliding window and retunes the staleness bound θ
  (ssp/pssp) and sample size β (pssp/pquorum) inside configured bounds;
  bsp/asp/pbsp have no tunable knob and stay static. Tuning flags (each
  implies --adaptive): --adaptive-window N (crossings per decision, 8),
  --adaptive-max-staleness T (64), --adaptive-max-sample B (64). Config
  file: [barrier] adaptive = true plus adaptive_* keys. With adaptation
  off, every engine replays bit-identically to previous releases.

  actor train [--config tiny|small|mid] [--steps N] [--lr F] [--seed N]
              [--workers N] [--method M] [--accum B] [--artifacts DIR]
      End-to-end LM training through the PJRT artifacts (L1+L2+L3).

  actor bounds [--beta B] [--staleness R] [--t T]
      Print the Theorem-3 convergence bounds for one configuration.

  actor info [--artifacts DIR]
      Show platform, manifest and artifact inventory.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["quick", "sgd"]).unwrap()
    }

    #[test]
    fn parses_subcommand_positionals_flags() {
        let a = args("exp fig1a --nodes 500 --quick");
        assert_eq!(a.subcommand, "exp");
        assert_eq!(a.positionals, vec!["fig1a"]);
        assert_eq!(a.get("nodes"), Some("500"));
        assert!(a.switch("quick"));
        assert!(!a.switch("sgd"));
    }

    #[test]
    fn typed_flags() {
        let a = args("sim --method pssp:10:4 --duration 12.5");
        assert_eq!(a.flag_or::<f64>("duration", 40.0).unwrap(), 12.5);
        assert_eq!(a.flag_or::<u64>("seed", 42).unwrap(), 42);
        assert!(a.flag_or::<u64>("duration", 1).is_err()); // 12.5 not u64
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(
            ["exp".to_string(), "--nodes".to_string()].into_iter(),
            &[],
        );
        assert!(r.is_err());
    }

    #[test]
    fn unknown_flags_caught() {
        let a = args("exp --nodes 5");
        assert!(a.check_known(&["nodes"]).is_ok());
        assert!(a.check_known(&["seed"]).is_err());
    }
}
