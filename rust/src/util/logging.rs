//! Leveled logger with wall-clock timestamps (offline substitute for
//! `tracing`/`env_logger`). Level comes from `ACTOR_LOG` (error|warn|info|
//! debug|trace) or the CLI `--log-level` flag.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell_lite::Lazy;

/// Log severity. Ordered so that a numeric comparison implements filtering.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => " WARN",
            Level::Info => " INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// Set the global level (also reads `ACTOR_LOG` on first use via `init`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Initialise from the environment; called once from `main`.
pub fn init() {
    if let Ok(v) = std::env::var("ACTOR_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
    Lazy::force(&START);
}

#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Core log call; use the macros instead.
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:10.4}s {} {module}] {msg}", level.tag());
}

/// `once_cell::sync::Lazy` replacement (std-only).
pub mod once_cell_lite {
    use std::sync::OnceLock;

    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Lazy { cell: OnceLock::new(), init }
        }

        pub fn force(this: &Self) -> &T {
            this.cell.get_or_init(&this.init)
        }
    }

    impl<T, F: Fn() -> T> std::ops::Deref for Lazy<T, F> {
        type Target = T;
        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Trace,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering_filters() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn lazy_initialises_once() {
        use super::once_cell_lite::Lazy;
        static COUNT: std::sync::atomic::AtomicU32 =
            std::sync::atomic::AtomicU32::new(0);
        static L: Lazy<u32> = Lazy::new(|| {
            COUNT.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            7
        });
        assert_eq!(*L, 7);
        assert_eq!(*L, 7);
        assert_eq!(COUNT.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
