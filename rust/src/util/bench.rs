//! Micro-benchmark harness (offline substitute for `criterion`).
//!
//! `cargo bench` targets are `harness = false` binaries built on this
//! module: warmup, then timed batches until a time budget is spent,
//! reporting mean / p50 / p99 per iteration and derived throughput.
//! Output format is one aligned line per benchmark, stable enough to
//! diff across the perf-pass iterations recorded in EXPERIMENTS.md §Perf.

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns.max(1e-9)
    }

    /// One aligned report line.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  ({:>14}/s)",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_count(self.per_sec()),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

fn fmt_count(c: f64) -> String {
    if c >= 1e6 {
        format!("{:.2}M", c / 1e6)
    } else if c >= 1e3 {
        format!("{:.2}k", c / 1e3)
    } else {
        format!("{c:.1}")
    }
}

/// Run `f` repeatedly for ~`budget` (after ~10% warmup); prints and
/// returns the result. `f` should include per-iteration work only —
/// hoist setup outside.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup.
    let warm_until = Instant::now() + budget.mul_f64(0.1);
    while Instant::now() < warm_until {
        f();
    }
    // Timed samples: batch iterations so per-sample overhead is amortised
    // for nanosecond-scale bodies, but keep batches small enough for
    // meaningful percentiles.
    let mut samples: Vec<f64> = Vec::new();
    let mut iters: u64 = 0;
    let t0 = Instant::now();
    // calibrate batch size to ~100µs per sample
    let probe = Instant::now();
    f();
    let one = probe.elapsed().as_nanos().max(1) as f64;
    let batch = ((100_000.0 / one).ceil() as u64).clamp(1, 1_000_000);
    while t0.elapsed() < budget {
        let s = Instant::now();
        for _ in 0..batch {
            f();
        }
        let per_iter = s.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(per_iter);
        iters += batch;
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len().max(1) as f64;
    let pick = |q: f64| {
        if samples.is_empty() {
            0.0
        } else {
            samples[((samples.len() - 1) as f64 * q) as usize]
        }
    };
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: pick(0.5),
        p99_ns: pick(0.99),
    };
    println!("{}", r.line());
    r
}

/// Time a single long-running closure (end-to-end benches).
pub fn bench_once<F: FnOnce() -> R, R>(name: &str, f: F) -> (R, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:<44} {:>12}        once  {secs:.3}s", "");
    (out, secs)
}

/// Machine-readable benchmark results: named entries of numeric metrics,
/// serialised as JSON (`results/bench_simulator.json` is the simulator's
/// perf baseline; CI uploads it as an artifact and gates regressions
/// against the checked-in copy).
#[derive(Debug, Clone, Default)]
pub struct BenchSuite {
    pub suite: String,
    /// (bench name, [(metric name, value)]) in insertion order.
    entries: Vec<(String, Vec<(String, f64)>)>,
}

impl BenchSuite {
    pub fn new(suite: &str) -> BenchSuite {
        BenchSuite { suite: suite.to_string(), entries: Vec::new() }
    }

    /// Record (or extend) a bench entry.
    pub fn record(&mut self, bench: &str, metrics: &[(&str, f64)]) {
        let ms: Vec<(String, f64)> =
            metrics.iter().map(|&(k, v)| (k.to_string(), v)).collect();
        match self.entries.iter_mut().find(|(n, _)| n == bench) {
            Some((_, existing)) => existing.extend(ms),
            None => self.entries.push((bench.to_string(), ms)),
        }
    }

    /// Look up one metric of one bench.
    pub fn metric(&self, bench: &str, metric: &str) -> Option<f64> {
        let (_, ms) = self.entries.iter().find(|(n, _)| n == bench)?;
        ms.iter().find(|(k, _)| k == metric).map(|&(_, v)| v)
    }

    /// Bench names, in insertion order.
    pub fn benches(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn to_json(&self) -> Json {
        let benches = self
            .entries
            .iter()
            .map(|(name, ms)| {
                let metrics =
                    ms.iter().map(|(k, v)| (k.as_str(), Json::Num(*v))).collect();
                (name.as_str(), obj(metrics))
            })
            .collect();
        obj(vec![
            ("suite", Json::Str(self.suite.clone())),
            ("benches", obj(benches)),
        ])
    }

    /// Write as pretty JSON, creating parent directories.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Load a suite written by [`BenchSuite::write`].
    pub fn load(path: &Path) -> Result<BenchSuite> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&src).with_context(|| format!("parsing {}", path.display()))?;
        let suite = j.req_str("suite")?.to_string();
        let mut out = BenchSuite { suite, entries: Vec::new() };
        let benches = j
            .get("benches")
            .and_then(Json::as_obj)
            .context("missing 'benches' object")?;
        for (name, metrics) in benches {
            let Some(ms) = metrics.as_obj() else { continue };
            let vals: Vec<(String, f64)> = ms
                .iter()
                .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                .collect();
            out.entries.push((name.clone(), vals));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", Duration::from_millis(30), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters > 1000);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, secs) = bench_once("quick", || 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn suite_records_and_round_trips() {
        let mut s = BenchSuite::new("simulator");
        s.record("sim_n1000", &[("events_per_sec", 1.5e6), ("events", 42.0)]);
        s.record("sim_n1000", &[("wall_secs", 0.5)]);
        s.record("sim_n10000", &[("events_per_sec", 2.0e6)]);
        assert_eq!(s.metric("sim_n1000", "events_per_sec"), Some(1.5e6));
        assert_eq!(s.metric("sim_n1000", "wall_secs"), Some(0.5));
        assert_eq!(s.metric("nope", "x"), None);
        assert_eq!(s.benches(), vec!["sim_n1000", "sim_n10000"]);
        let dir = std::env::temp_dir().join(format!("psp-bench-{}", std::process::id()));
        let path = dir.join("suite.json");
        s.write(&path).unwrap();
        let loaded = BenchSuite::load(&path).unwrap();
        assert_eq!(loaded.suite, "simulator");
        assert_eq!(loaded.metric("sim_n10000", "events_per_sec"), Some(2.0e6));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_count(2.5e6).contains('M'));
    }
}
