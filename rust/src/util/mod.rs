//! Shared substrates: PRNG + distributions, statistics, JSON, logging.
//!
//! This environment is offline, so the usual crates (`rand`, `serde_json`,
//! `tracing`) are unavailable; these modules are small, deterministic,
//! fully-tested replacements tuned for what the coordinator needs.

pub mod bench;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
