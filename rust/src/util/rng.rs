//! Deterministic PRNG + samplers (offline substitute for the `rand` crate).
//!
//! `Rng` is xoshiro256++ seeded via splitmix64 — fast, well-distributed,
//! and stable across platforms, which makes every simulation in this repo
//! exactly reproducible from its seed (a property the paper's evaluation
//! methodology needs: each figure is a seeded run).

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-node RNGs in the simulator).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire's method (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in the inclusive range [lo, hi].
    #[inline]
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given mean (inverse-CDF).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u < 1.0 {
                break u;
            }
        };
        -mean * (1.0 - u).ln()
    }

    /// Pareto(scale, shape) — heavy-tailed compute times for stragglers.
    pub fn pareto(&mut self, scale: f64, shape: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        scale / u.powf(1.0 / shape)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) without replacement.
    ///
    /// This is the paper's sampling primitive at its lowest level. Uses
    /// Floyd's algorithm: O(k) expected time and allocation-free for the
    /// common small-β case when a scratch buffer is supplied via
    /// [`Rng::sample_into`].
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k.min(n));
        self.sample_into(n, k, &mut out);
        out
    }

    /// Allocation-free variant of [`Rng::sample_indices`] (hot path of the
    /// barrier decision; see benches/barrier.rs).
    pub fn sample_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        out.clear();
        let k = k.min(n);
        if k == 0 {
            return;
        }
        // Robert Floyd's sampling algorithm.
        for j in (n - k)..n {
            let t = self.next_below(j as u64 + 1) as usize;
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..10).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "mean={mean}");
        // exponentials are non-negative
        assert!((0..1000).all(|_| r.exponential(1.0) >= 0.0));
    }

    #[test]
    fn pareto_at_least_scale() {
        let mut r = Rng::new(19);
        for _ in 0..1000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(23);
        for _ in 0..200 {
            let n = 1 + r.next_below(50) as usize;
            let k = r.next_below(n as u64 + 1) as usize;
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_indices_k_ge_n_returns_all() {
        let mut r = Rng::new(29);
        let mut s = r.sample_indices(5, 10);
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sample_is_uniform() {
        // every index should appear in a 2-of-10 sample ~2000 times over 10k trials
        let mut r = Rng::new(31);
        let mut counts = [0u32; 10];
        for _ in 0..10_000 {
            for i in r.sample_indices(10, 2) {
                counts[i] += 1;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((1700..2300).contains(&c), "index {i}: {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(37);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(41);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..20).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
