//! Minimal JSON parser + writer (offline substitute for `serde_json`).
//!
//! Needed to read `artifacts/manifest.json` (written by the Python AOT
//! pipeline) and to emit experiment results. Supports the full JSON value
//! model; numbers are f64 (the manifest only contains small integers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document (strict; rejects trailing garbage).
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `j.get("a")?.get("b")` style.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers with contextual errors (manifest loading).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("field '{key}' is not a string"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow!("field '{key}' is not an array"))
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialise with 2-space indentation (for result files).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building result documents.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at offset {}, found '{}'",
                c as char,
                self.i,
                self.b[self.i] as char
            )
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at offset {}", c as char, self.i),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' found '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: best-effort (manifest is ASCII).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the full sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"tf_tiny_step","shape":[32,1000],"ok":true,"x":null,"f":1.5}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse(r#""héllo — ünïcode""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ünïcode");
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn reads_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(src) = std::fs::read_to_string(path) {
            let j = Json::parse(&src).unwrap();
            assert!(j.req_arr("artifacts").unwrap().len() >= 4);
        }
    }

    #[test]
    fn integers_serialise_without_decimal_point() {
        assert_eq!(Json::Num(32.0).to_string(), "32");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }
}
