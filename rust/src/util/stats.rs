//! Statistics helpers: summaries, percentiles, CDFs, histograms.
//!
//! The paper's figures are distributions (Fig 1a–c, 2c), ratios (Fig 2a,
//! 3) and time series (Fig 1d–e); this module produces all of them from
//! raw per-node measurements.

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                count: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0,
                p25: 0.0, p50: 0.0, p75: 0.0, p95: 0.0, p99: 0.0,
            };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = mean(xs);
        Summary {
            count: xs.len(),
            mean,
            std: std_dev(xs, mean),
            min: sorted[0],
            max: sorted[sorted.len() - 1],
            p25: percentile_sorted(&sorted, 25.0),
            p50: percentile_sorted(&sorted, 50.0),
            p75: percentile_sorted(&sorted, 75.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }

    /// Spread of the middle mass — the "tightness" the paper eyeballs in
    /// Fig 1a/1c (BSP tight, ASP spread).
    pub fn iqr(&self) -> f64 {
        self.p75 - self.p25
    }
}

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation around a given mean.
pub fn std_dev(xs: &[f64], mean: f64) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    (xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile over pre-sorted data, q in [0, 100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Empirical CDF: returns (value, fraction ≤ value) points, one per
/// distinct value. This is exactly what Fig 1b/1c plot.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    if xs.is_empty() {
        return Vec::new();
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    let mut out: Vec<(f64, f64)> = Vec::new();
    for (i, &v) in sorted.iter().enumerate() {
        let frac = (i + 1) as f64 / n;
        match out.last_mut() {
            Some(last) if last.0 == v => last.1 = frac,
            _ => out.push((v, frac)),
        }
    }
    out
}

/// Evaluate an ECDF at a point (fraction of xs ≤ x).
pub fn ecdf_at(xs: &[f64], x: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&v| v <= x).count() as f64 / xs.len() as f64
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; values outside
/// the range clamp to the edge buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64)
            .floor()
            .clamp(0.0, (bins - 1) as f64) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bucket midpoints, for reporting.
    pub fn midpoints(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }
}

/// L2 norm of a vector.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// L2 distance between two equal-length vectors — the paper's Fig 1d
/// "normalized error" metric is `l2_dist(w, w_true)` (optionally scaled).
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 4.0);
        assert!((percentile_sorted(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone_and_ends_at_one() {
        let xs = vec![3.0, 1.0, 2.0, 2.0, 5.0];
        let cdf = ecdf(&xs);
        assert_eq!(cdf.last().unwrap().1, 1.0);
        for w in cdf.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(ecdf_at(&xs, 2.0), 0.6);
        assert_eq!(ecdf_at(&xs, 0.5), 0.0);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.6, 9.9, -5.0, 50.0] {
            h.add(x);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.counts[0], 2); // 0.5 and clamped -5.0
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 2); // 9.9 and clamped 50.0
        assert_eq!(h.midpoints()[0], 0.5);
    }

    #[test]
    fn l2_helpers() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l2_dist(&[1.0, 1.0], &[4.0, 5.0]), 5.0);
    }

    #[test]
    fn std_dev_constant_is_zero() {
        let xs = vec![2.0; 50];
        assert_eq!(std_dev(&xs, 2.0), 0.0);
    }
}
