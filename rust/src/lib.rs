//! # Actor/PSP — Probabilistic Synchronous Parallel
//!
//! A Rust + JAX + Pallas reproduction of *Probabilistic Synchronous
//! Parallel* (Wang, Catterall & Mortier, 2017): a distributed learning
//! framework ("Actor") whose barrier control is built on a **sampling
//! primitive**, decoupling synchronisation from model consistency.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — barrier control (BSP/SSP/ASP/pBSP/pSSP), the
//!   sampling primitive, a chord-like structured overlay, map-reduce /
//!   parameter-server / p2p engines on an in-repo actor runtime, a
//!   deterministic discrete-event cluster simulator, the convergence-bound
//!   calculator of the paper's Section 6, and the experiment harness that
//!   regenerates every figure of Section 5.
//! * **L2** — JAX model definitions (`python/compile/model.py`), AOT-lowered
//!   to HLO text artifacts at build time.
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the per-worker
//!   compute hot-spots (fused linear SGD step; blocked attention).
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (the `xla`
//! crate) so the training hot path never touches Python.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod actor;
pub mod barrier;
pub mod cli;
pub mod config;
pub mod engine;
pub mod exp;
pub mod model;
pub mod overlay;
pub mod runtime;
pub mod sampling;
pub mod sim;
pub mod testing;
pub mod theory;
pub mod train;
pub mod util;

/// Crate-wide result type (anyhow-based, like the rest of the stack).
pub type Result<T> = anyhow::Result<T>;
