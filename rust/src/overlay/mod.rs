//! Structured overlay (chord-like ring) — the substrate that makes the
//! sampling primitive *correct* in the fully-distributed setting.
//!
//! Paper §3.2: "we can organise the nodes into a structured overlay (e.g.
//! chord or kademlia); the total number of nodes can be estimated by the
//! density of each zone ... using a structured overlay in the design
//! guarantees the following sampling process is correct, i.e. random
//! sampling."
//!
//! This module implements:
//!
//! * a 64-bit identifier ring with successor lists and finger tables
//!   ([`Ring`]) supporting join/leave (churn) and O(log n) lookup, with
//!   a node→id reverse index so owner-id recovery in the sampling hot
//!   path and `leave` under churn are O(log n) (not O(n) scans), and
//!   [`Ring::successor_node`] exposing the first successor-list entry —
//!   reused by the gossip model plane ([`crate::engine::gossip`]) as the
//!   completeness-carrying ring edge. Message accounting charges real
//!   work only: a self-lookup (the observer owns the key) costs 0 hops
//!   and a local successor-window read is free;
//! * **uniform node sampling** by looking up uniformly-random points of
//!   the id space ([`Ring::sample_nodes`]) — correct because node ids are
//!   uniformly distributed, with the small-arc bias corrected by
//!   resampling proportional to arc length (acceptance test);
//! * **system-size estimation** from zone density ([`Ring::estimate_size`]),
//!   the first of the two pieces of information PSP needs.
//!
//! [`OverlaySampler`] packages ring sampling + per-node step queries into
//! the view provider used by the fully-distributed engines (each node
//! runs its *own* barrier decision with no global state).

use std::collections::BTreeMap;

pub mod kademlia;

pub use kademlia::Kademlia;

use crate::util::rng::Rng;

/// Number of finger-table entries (id space is 64-bit).
const FINGERS: usize = 64;

/// A node's identifier on the ring.
pub type RingId = u64;

/// Hash a node's name/index to a ring id (splitmix-style mixing — uniform
/// over the id space, which the density estimator relies on).
pub fn node_ring_id(node: usize, namespace: u64) -> RingId {
    node_ring_id_v(node, 0, namespace)
}

/// Ring id of a node's `vnode`-th **virtual node**. `vnode == 0` is the
/// node's primary id and equals [`node_ring_id`] exactly, so single-vnode
/// rings (every pre-existing caller) are bit-identical to the pre-vnode
/// code. Higher vnodes fold an odd-constant multiple of the index into
/// the pre-mix state, giving each virtual position an independent
/// uniform draw — the load-balance fix for successor-placement skew
/// (a 1-vnode ring routinely lands 20–30× more keys on its luckiest
/// member than its unluckiest; see `benches/simulator.rs`).
pub fn node_ring_id_v(node: usize, vnode: usize, namespace: u64) -> RingId {
    let mut z = (node as u64)
        .wrapping_add((vnode as u64).wrapping_mul(0xD1B54A32D192ED03))
        .wrapping_add(0x9E3779B97F4A7C15)
        .wrapping_mul(namespace | 1);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A chord-like ring over registered nodes.
///
/// The authoritative membership is a sorted map id -> node; finger tables
/// are derived views used by `lookup` to emulate O(log n) routing and to
/// count the control messages a real deployment would spend. A reverse
/// node -> id index is maintained alongside so that owner-id recovery in
/// the sampling hot path and `leave` under churn are O(log n), not O(n)
/// scans over the membership.
#[derive(Debug, Clone)]
pub struct Ring {
    /// id -> application node index (every position: primary + vnodes).
    members: BTreeMap<RingId, usize>,
    /// application node index -> **primary** id (reverse index; kept in
    /// lockstep with `members` by `join`/`leave`).
    ids: BTreeMap<usize, RingId>,
    /// application node index -> extra virtual-node ids (vnode ≥ 1),
    /// present only for members joined via [`Ring::join_vnodes`].
    extra: BTreeMap<usize, Vec<RingId>>,
    namespace: u64,
}

impl Ring {
    pub fn new(namespace: u64) -> Ring {
        Ring {
            members: BTreeMap::new(),
            ids: BTreeMap::new(),
            extra: BTreeMap::new(),
            namespace,
        }
    }

    /// Build a ring over nodes 0..n.
    pub fn with_nodes(n: usize, namespace: u64) -> Ring {
        let mut r = Ring::new(namespace);
        for node in 0..n {
            r.join(node);
        }
        r
    }

    /// Ring positions (node count on single-vnode rings; primary + extra
    /// virtual positions when [`Ring::join_vnodes`] was used).
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// Distinct member nodes, regardless of how many virtual positions
    /// each occupies.
    pub fn nodes(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Add a node; returns its ring id. Rejoining an existing node is a
    /// no-op that returns its current id.
    pub fn join(&mut self, node: usize) -> RingId {
        if let Some(&id) = self.ids.get(&node) {
            return id;
        }
        let mut id = node_ring_id(node, self.namespace);
        // Linear-probe collisions (astronomically rare in 64-bit space).
        while self.members.contains_key(&id) {
            id = id.wrapping_add(1);
        }
        self.members.insert(id, node);
        self.ids.insert(node, id);
        id
    }

    /// Add a node occupying `vnodes` virtual positions (≥ 1; clamped).
    /// Position 0 is the node's primary id — identical to [`Ring::join`] —
    /// so a `vnodes == 1` ring is indistinguishable from a plain one.
    /// Returns the primary id; rejoining an existing node is a no-op.
    pub fn join_vnodes(&mut self, node: usize, vnodes: usize) -> RingId {
        if let Some(&id) = self.ids.get(&node) {
            return id;
        }
        let primary = self.join(node);
        let mut extras = Vec::new();
        for v in 1..vnodes.max(1) {
            let mut id = node_ring_id_v(node, v, self.namespace);
            while self.members.contains_key(&id) {
                id = id.wrapping_add(1);
            }
            self.members.insert(id, node);
            extras.push(id);
        }
        if !extras.is_empty() {
            self.extra.insert(node, extras);
        }
        primary
    }

    /// Remove a node by application index. O(log n) via the reverse index
    /// — churn-safe: high join/leave rates no longer cost a full
    /// membership scan per departure.
    pub fn leave(&mut self, node: usize) -> bool {
        self.evict(node).is_some()
    }

    /// Evict a node (crash-fault membership plane), returning the ring id
    /// it vacated — the position the membership layer needs to find the
    /// dead node's custodian (`successor(old_id + 1)`) after the entry is
    /// gone. Same O(log n) removal as [`Ring::leave`]; `None` when the
    /// node was not a member (eviction is idempotent across observers).
    pub fn evict(&mut self, node: usize) -> Option<RingId> {
        let id = self.ids.remove(&node)?;
        self.members.remove(&id);
        // Virtual positions vacate together with the primary.
        if let Some(extras) = self.extra.remove(&node) {
            for e in extras {
                self.members.remove(&e);
            }
        }
        Some(id)
    }

    /// The ring id of a registered node (None if not a member). Reads the
    /// reverse index, so probed collision ids are reported faithfully.
    pub fn ring_id_of(&self, node: usize) -> Option<RingId> {
        self.ids.get(&node).copied()
    }

    /// The next node clockwise after `node` (its first successor-list
    /// entry). None if `node` is absent or alone — the successor of a
    /// singleton ring is itself, which no caller wants as a peer. On
    /// vnode rings the walk skips the node's own virtual positions.
    pub fn successor_node(&self, node: usize) -> Option<usize> {
        self.successors_distinct(node, 1).first().copied()
    }

    /// Up to `r` **distinct** nodes walked clockwise from `node`'s
    /// primary id, skipping `node` itself (and all its virtual
    /// positions) plus repeat appearances of the same member — the
    /// successor list that replica placement hands each shard. Returns
    /// fewer than `r` entries when the ring has fewer other members.
    pub fn successors_distinct(&self, node: usize, r: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let Some(id) = self.ring_id_of(node) else { return out };
        let mut point = id.wrapping_add(1);
        for _ in 0..self.members.len() {
            let Some((sid, n)) = self.successor(point) else { break };
            if sid == id {
                break; // wrapped all the way around
            }
            if n != node && !out.contains(&n) {
                out.push(n);
                if out.len() == r {
                    break;
                }
            }
            point = sid.wrapping_add(1);
        }
        out
    }

    /// Successor of a point on the ring (wrapping).
    pub fn successor(&self, point: RingId) -> Option<(RingId, usize)> {
        self.members
            .range(point..)
            .next()
            .or_else(|| self.members.iter().next())
            .map(|(&id, &n)| (id, n))
    }

    /// Route a lookup from `from_id` to the successor of `key`, returning
    /// (owner node, hop count). Emulates finger-table greedy routing: each
    /// hop at least halves the clockwise distance, so hops ≈ log2(n).
    ///
    /// A self-lookup — the observer already owns the key — is purely
    /// local and costs **0 hops** (no control message is spent; charging
    /// one here used to inflate `control_msgs` in the p2p engine and the
    /// simulator-side accounting). Remote lookups cost ≥ 1.
    pub fn lookup(&self, from_id: RingId, key: RingId) -> Option<(usize, u32)> {
        if self.members.is_empty() {
            return None;
        }
        let (target_id, target_node) = self.successor(key)?;
        if from_id == target_id {
            return Some((target_node, 0));
        }
        let mut cur = from_id;
        let mut hops = 0u32;
        while cur != target_id {
            // Greedy finger: scan farthest-first and take the FIRST finger
            // that lands in (cur, target]; it is the farthest admissible
            // one, so the remaining 63 lookups are skipped (the perf-pass
            // change that took sample_nodes from ~210µs to ~30µs@n=1000 —
            // EXPERIMENTS.md §Perf).
            let dist = target_id.wrapping_sub(cur);
            let mut best = None;
            for k in (0..FINGERS).rev() {
                let span = 1u64 << k;
                if span > dist && dist > 0 {
                    continue; // finger would overshoot the target
                }
                let finger_point = cur.wrapping_add(span);
                if let Some((fid, _)) = self.successor(finger_point) {
                    // does fid lie in (cur, target_id] clockwise?
                    if in_arc(cur, fid, target_id) {
                        best = Some(fid);
                        break;
                    }
                }
            }
            match best {
                Some(fid) if fid != cur => {
                    cur = fid;
                    hops += 1;
                }
                _ => break,
            }
            if hops > FINGERS as u32 {
                break; // safety net; cannot happen with consistent tables
            }
        }
        Some((target_node, hops.max(1)))
    }

    /// Uniform random node sample of size ≤ β, excluding `observer`.
    ///
    /// Naive "successor of a random point" over-selects nodes owning long
    /// arcs (selection ∝ arc length). We use the **successor-window
    /// method**: route to the successor of a uniform point, fetch its
    /// window of `k` consecutive successors (chord nodes maintain exactly
    /// such successor lists), then pick uniformly *within* the window,
    /// accepting the draw with probability ∝ `k·E[arc] / window-span`.
    /// Windowing averages k arcs (relative bias 1/√k) and the acceptance
    /// step cancels the remaining span fluctuation; when k ≥ n the window
    /// is the whole ring and sampling is exactly uniform.
    ///
    /// Returns (sampled node indices, control messages spent).
    pub fn sample_nodes(
        &self,
        observer: usize,
        beta: usize,
        rng: &mut Rng,
    ) -> (Vec<usize>, u64) {
        let n = self.members.len();
        let mut out = Vec::with_capacity(beta);
        let mut msgs = 0u64;
        // Degenerate rings cannot yield a peer. The second clause counts
        // *distinct nodes*: a single member occupying many virtual
        // positions has n > 1 yet nothing to sample — without it the loop
        // spins through 128·(β+1) lookups before returning empty-handed.
        if n <= 1 || self.ids.len() <= 1 || beta == 0 {
            return (out, msgs);
        }
        let from = self
            .ring_id_of(observer)
            .unwrap_or_else(|| node_ring_id(observer, self.namespace));
        let target = beta.min(self.ids.len() - 1);
        let k = 32usize.min(n);
        let expect = (u64::MAX as f64) / n as f64;
        let mut attempts = 0;
        while out.len() < target && attempts < 128 * (beta + 1) {
            attempts += 1;
            let point = rng.next_u64();
            let Some((first, hops)) = self.lookup(from, point) else { continue };
            // Routing hops, plus one successor-list fetch — unless the
            // observer itself owns the point, in which case the window
            // read is local and free.
            msgs += hops as u64 + u64::from(first != observer);
            // Collect the k-node window starting at `first`'s ring
            // position. Owner-id recovery reads the reverse index
            // (O(log n)); this used to be an O(n) scan on every draw,
            // which made the sampling hot path grow linearly in n.
            let Some(&first_id) = self.ids.get(&first) else { continue };
            let mut window = Vec::with_capacity(k);
            let mut cursor = first_id;
            for i in 0..k {
                window.push((cursor, self.members[&cursor]));
                let Some(next) = self
                    .members
                    .range(cursor.wrapping_add(1)..)
                    .next()
                    .or_else(|| self.members.iter().next())
                    .map(|(&id, _)| id)
                else {
                    break; // membership emptied under us: nothing to walk
                };
                if i + 1 < k && next == first_id {
                    break; // wrapped the whole ring
                }
                cursor = next;
            }
            // Span covered by the window's arcs (predecessor of first -> last).
            let Some(pred) = self
                .members
                .range(..first_id)
                .next_back()
                .or_else(|| self.members.iter().next_back())
                .map(|(&id, _)| id)
            else {
                continue;
            };
            let Some(&(last_id, _)) = window.last() else { continue };
            let span = last_id.wrapping_sub(pred);
            // span == 0 means the window closed on its own predecessor (a
            // single-member or fully-wrapped arc): the density correction
            // would divide by zero — the window already covers the whole
            // populated ring, so the draw is exactly uniform; accept it.
            let p_accept = if window.len() >= n || span == 0 {
                1.0 // whole ring: exactly uniform already
            } else {
                ((window.len() as f64 * expect) / (2.0 * span as f64)).min(1.0)
            };
            if !rng.bernoulli(p_accept) {
                continue;
            }
            let pick = window[rng.next_below(window.len() as u64) as usize].1;
            if pick == observer || out.contains(&pick) {
                continue;
            }
            out.push(pick);
        }
        (out, msgs)
    }

    /// Estimate total system size from the local zone density (paper §3.2):
    /// observe the `k` successors of your own id; they span a fraction
    /// `span/2^64` of the ring, so `n ≈ k / frac`.
    pub fn estimate_size(&self, observer: usize, k: usize) -> f64 {
        let n = self.members.len();
        if n == 0 {
            return 0.0;
        }
        let k = k.min(n - 1).max(1);
        let my_id = self
            .ring_id_of(observer)
            .unwrap_or_else(|| node_ring_id(observer, self.namespace));
        // walk k successors clockwise
        let mut last = my_id;
        let mut count = 0;
        let mut iter_from = my_id.wrapping_add(1);
        while count < k {
            match self.members.range(iter_from..).next() {
                Some((&id, _)) => {
                    last = id;
                    iter_from = id.wrapping_add(1);
                    count += 1;
                }
                None => {
                    // wrap
                    match self.members.iter().next() {
                        Some((&id, _)) if id != my_id => {
                            last = id;
                            iter_from = id.wrapping_add(1);
                            count += 1;
                        }
                        _ => break,
                    }
                }
            }
            if count >= n {
                break;
            }
        }
        if count == 0 {
            return 1.0;
        }
        let span = last.wrapping_sub(my_id);
        if span == 0 {
            return n as f64;
        }
        let frac = span as f64 / u64::MAX as f64;
        count as f64 / frac
    }
}

/// Is `x` in the clockwise arc (from, to]?
fn in_arc(from: RingId, x: RingId, to: RingId) -> bool {
    if from < to {
        x > from && x <= to
    } else if from > to {
        x > from || x <= to
    } else {
        false
    }
}

/// Fully-distributed view provider: ring sampling + a step query function.
///
/// In a real deployment the query is an RPC to the sampled node; in the
/// engines/simulator it reads that node's published step. Control-message
/// accounting (`msgs`) captures the paper's communication-cost argument:
/// PSP costs O(β·log n) per decision vs O(n) global-state maintenance.
pub struct OverlaySampler<'a> {
    pub ring: &'a Ring,
    pub observer: usize,
}

impl<'a> OverlaySampler<'a> {
    /// Sample β peers and read their steps via `step_of`.
    /// Returns (sampled steps, control messages spent).
    pub fn sample_steps<F: Fn(usize) -> u64>(
        &self,
        beta: usize,
        rng: &mut Rng,
        step_of: F,
    ) -> (Vec<u64>, u64) {
        let (nodes, mut msgs) = self.ring.sample_nodes(self.observer, beta, rng);
        msgs += 2 * nodes.len() as u64; // query + reply per sampled peer
        (nodes.into_iter().map(step_of).collect(), msgs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    #[test]
    fn join_leave_membership() {
        let mut r = Ring::new(7);
        assert!(r.is_empty());
        r.join(0);
        r.join(1);
        r.join(2);
        assert_eq!(r.len(), 3);
        assert!(r.leave(1));
        assert!(!r.leave(1));
        assert_eq!(r.len(), 2);
        assert_eq!(r.ring_id_of(1), None);
        assert_eq!(r.ring_id_of(0), Some(node_ring_id(0, 7)));
    }

    #[test]
    fn reverse_index_tracks_membership_under_churn() {
        property("ring reverse index consistent", 60, |g| {
            let n = g.usize_in(1, 50);
            let mut r = Ring::with_nodes(n, 13);
            let mut rng = g.rng();
            for node in 0..n {
                if rng.bernoulli(0.4) {
                    r.leave(node);
                }
                if rng.bernoulli(0.2) {
                    r.join(node); // rejoin (no-op when present)
                }
            }
            // the two maps must be exact inverses of one another
            assert_eq!(r.len(), r.ids.len());
            for (&id, &node) in &r.members {
                assert_eq!(r.ring_id_of(node), Some(id));
            }
        });
    }

    #[test]
    fn self_lookup_costs_zero_hops() {
        let r = Ring::with_nodes(64, 5);
        let id0 = r.ring_id_of(0).unwrap();
        // Looking up a key the observer already owns is local: 0 hops.
        let (owner, hops) = r.lookup(id0, id0).unwrap();
        assert_eq!(owner, 0);
        assert_eq!(hops, 0);
        // A key owned by somebody else costs at least one hop.
        let other = r.ring_id_of(1).unwrap();
        let (owner, hops) = r.lookup(id0, other).unwrap();
        assert_eq!(owner, 1);
        assert!(hops >= 1);
    }

    #[test]
    fn successor_node_walks_clockwise() {
        let mut r = Ring::with_nodes(16, 11);
        for node in 0..16 {
            let succ = r.successor_node(node).unwrap();
            assert_ne!(succ, node);
            // the successor really is the next member clockwise
            let id = r.ring_id_of(node).unwrap();
            let (_, expect) = r.successor(id.wrapping_add(1)).unwrap();
            assert_eq!(succ, expect);
        }
        // successor pointers skip departed nodes
        let succ_of_3 = r.successor_node(3).unwrap();
        r.leave(succ_of_3);
        if let Some(new_succ) = r.successor_node(3) {
            assert_ne!(new_succ, succ_of_3);
        }
        // singleton ring has no usable successor
        let mut one = Ring::new(1);
        one.join(0);
        assert_eq!(one.successor_node(0), None);
        assert_eq!(one.successor_node(9), None);
    }

    #[test]
    fn evict_returns_vacated_position_once() {
        let mut r = Ring::with_nodes(8, 13);
        let id3 = r.ring_id_of(3).unwrap();
        assert_eq!(r.evict(3), Some(id3));
        assert_eq!(r.evict(3), None, "eviction is idempotent");
        assert_eq!(r.len(), 7);
        // The vacated position routes to the next live node — the
        // custodian the membership plane hands the dead node's rumors to.
        let (_, heir) = r.successor(id3.wrapping_add(1)).unwrap();
        assert_ne!(heir, 3);
        // Rejoining restores the identical id (pure function of index).
        assert_eq!(r.join(3), id3);
    }

    #[test]
    fn vnode_zero_id_matches_primary_hash() {
        // v=0 must be byte-identical to the historical hash: every
        // committed golden and membership trajectory depends on it.
        for ns in [1u64, 7, 42, 0xB10C] {
            for node in 0..64 {
                assert_eq!(node_ring_id_v(node, 0, ns), node_ring_id(node, ns));
            }
        }
        // higher vnodes land elsewhere
        assert_ne!(node_ring_id_v(3, 1, 7), node_ring_id_v(3, 0, 7));
        assert_ne!(node_ring_id_v(3, 2, 7), node_ring_id_v(3, 1, 7));
    }

    #[test]
    fn join_vnodes_occupies_and_vacates_all_positions() {
        let mut r = Ring::new(19);
        for node in 0..4 {
            r.join_vnodes(node, 8);
        }
        assert_eq!(r.nodes(), 4);
        assert_eq!(r.len(), 4 * 8);
        // primary id unchanged by vnode count
        assert_eq!(r.ring_id_of(2), Some(node_ring_id(2, 19)));
        // evict removes the primary and every virtual position at once
        assert_eq!(r.evict(2), Some(node_ring_id(2, 19)));
        assert_eq!(r.nodes(), 3);
        assert_eq!(r.len(), 3 * 8);
        assert_eq!(r.evict(2), None);
        // successor walks on a vnode ring never return the node itself
        for node in [0usize, 1, 3] {
            assert_ne!(r.successor_node(node), Some(node));
        }
    }

    #[test]
    fn successors_distinct_orders_all_other_nodes() {
        let mut r = Ring::new(23);
        for node in 0..6 {
            r.join_vnodes(node, 4);
        }
        for node in 0..6 {
            let all = r.successors_distinct(node, usize::MAX);
            assert_eq!(all.len(), 5, "node {node} should see every peer");
            assert!(!all.contains(&node));
            let mut d = all.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 5, "repeat entries in successor list");
            // a truncated request is a prefix of the full walk
            assert_eq!(r.successors_distinct(node, 2), all[..2].to_vec());
        }
        // single-vnode rings: first distinct successor == successor_node
        let plain = Ring::with_nodes(16, 11);
        for node in 0..16 {
            assert_eq!(
                plain.successors_distinct(node, 1).first().copied(),
                plain.successor_node(node)
            );
        }
        // singleton ring has no successors at all
        let mut one = Ring::new(3);
        one.join_vnodes(0, 16);
        assert!(one.successors_distinct(0, 4).is_empty());
        assert_eq!(one.successor_node(0), None);
    }

    #[test]
    fn successor_wraps() {
        let mut r = Ring::new(1);
        let id0 = r.join(0);
        let (sid, node) = r.successor(id0.wrapping_add(1)).unwrap();
        // single node: its own successor (wrapping)
        assert_eq!(node, 0);
        assert_eq!(sid, id0);
    }

    #[test]
    fn lookup_finds_owner_with_log_hops() {
        let r = Ring::with_nodes(1000, 42);
        let from = node_ring_id(0, 42);
        let mut rng = Rng::new(5);
        let mut total_hops = 0u32;
        for _ in 0..100 {
            let key = rng.next_u64();
            let (owner, hops) = r.lookup(from, key).unwrap();
            // owner really is the successor of key
            let (_, expect) = r.successor(key).unwrap();
            assert_eq!(owner, expect);
            total_hops += hops;
        }
        let avg = total_hops as f64 / 100.0;
        assert!(avg < 2.0 * (1000f64).log2(), "avg hops {avg}");
    }

    #[test]
    fn sample_nodes_distinct_and_excludes_observer() {
        let r = Ring::with_nodes(100, 3);
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let (s, msgs) = r.sample_nodes(5, 10, &mut rng);
            assert_eq!(s.len(), 10);
            assert!(!s.contains(&5));
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 10);
            assert!(msgs > 0);
        }
    }

    #[test]
    fn sample_on_degenerate_rings_returns_empty_without_drawing() {
        // n = 0 and n = 1 (plain + vnodes): nobody to sample, and the rng
        // must not be consumed — a single node occupying 8 virtual
        // positions used to spin 128·(β+1) window draws (and hit the
        // span-0 division) before returning empty-handed.
        let mut rng = Rng::new(77);
        let mut probe = rng.clone();
        let empty = Ring::new(7);
        assert_eq!(empty.sample_nodes(0, 4, &mut rng), (vec![], 0));
        let mut one = Ring::new(7);
        one.join(0);
        assert_eq!(one.sample_nodes(0, 4, &mut rng), (vec![], 0));
        let mut vone = Ring::new(7);
        vone.join_vnodes(0, 8);
        assert_eq!(vone.sample_nodes(0, 4, &mut rng), (vec![], 0));
        assert_eq!(rng.next_u64(), probe.next_u64(), "no rng draws spent");
    }

    #[test]
    fn sample_at_window_size_covers_whole_ring() {
        // n == k (the successor window wraps the full ring, k = min(32, n)):
        // the span correction degenerates to the whole-ring case; sampling
        // must stay exact — every peer reachable, none repeated, no panic.
        for n in [2usize, 3, 31, 32] {
            let r = Ring::with_nodes(n, 9);
            let mut rng = Rng::new(n as u64);
            let (s, _) = r.sample_nodes(0, n - 1, &mut rng);
            let mut d = s.clone();
            d.sort_unstable();
            assert_eq!(d, (1..n).collect::<Vec<_>>(), "n={n}");
        }
    }

    #[test]
    fn sample_on_vnode_ring_targets_distinct_nodes() {
        // β is capped by distinct members, not ring positions: 2 nodes ×
        // 8 vnodes = 16 positions but exactly one samplable peer.
        let mut r = Ring::new(31);
        r.join_vnodes(0, 8);
        r.join_vnodes(1, 8);
        let mut rng = Rng::new(5);
        let (s, msgs) = r.sample_nodes(0, 6, &mut rng);
        assert_eq!(s, vec![1]);
        assert!(msgs > 0);
    }

    #[test]
    fn sample_is_approximately_uniform() {
        // χ²-style sanity: over many 1-samples from 20 nodes, each node
        // should be drawn a reasonable number of times.
        let r = Ring::with_nodes(20, 9);
        let mut rng = Rng::new(13);
        let mut counts = vec![0u32; 20];
        let trials = 8000;
        for _ in 0..trials {
            let (s, _) = r.sample_nodes(0, 1, &mut rng);
            for n in s {
                counts[n] += 1;
            }
        }
        let expected = trials as f64 / 19.0; // observer excluded
        assert_eq!(counts[0], 0);
        for (i, &c) in counts.iter().enumerate().skip(1) {
            assert!(
                (c as f64) > expected * 0.55 && (c as f64) < expected * 1.6,
                "node {i}: {c} vs expected {expected}"
            );
        }
    }

    #[test]
    fn size_estimation_within_factor_two() {
        for &n in &[50usize, 200, 1000] {
            let r = Ring::with_nodes(n, 21);
            let est = r.estimate_size(0, 16);
            assert!(
                est > n as f64 / 2.5 && est < n as f64 * 2.5,
                "n={n} est={est}"
            );
        }
    }

    #[test]
    fn prop_sample_size_bounds() {
        property("overlay sample ≤ β and ≤ n-1", 60, |g| {
            let n = g.usize_in(1, 60);
            let beta = g.usize_in(0, 70);
            let r = Ring::with_nodes(n, 5);
            let mut rng = g.rng();
            let obs = g.usize_in(0, n - 1);
            let (s, _) = r.sample_nodes(obs, beta, &mut rng);
            assert!(s.len() <= beta);
            assert!(s.len() <= n.saturating_sub(1));
            assert!(!s.contains(&obs));
        });
    }

    #[test]
    fn prop_lookup_owner_matches_successor_under_churn() {
        property("lookup correct under churn", 40, |g| {
            let n = g.usize_in(2, 40);
            let mut r = Ring::with_nodes(n, 17);
            let mut rng = g.rng();
            // churn half the nodes
            for node in 0..n {
                if rng.bernoulli(0.3) {
                    r.leave(node);
                }
            }
            if r.is_empty() {
                return;
            }
            let key = rng.next_u64();
            let from = node_ring_id(0, 17);
            let (owner, _) = r.lookup(from, key).unwrap();
            let (_, expect) = r.successor(key).unwrap();
            assert_eq!(owner, expect);
        });
    }

    #[test]
    fn overlay_sampler_reads_steps() {
        let r = Ring::with_nodes(30, 2);
        let sampler = OverlaySampler { ring: &r, observer: 0 };
        let mut rng = Rng::new(3);
        let (steps, msgs) = sampler.sample_steps(8, &mut rng, |n| n as u64);
        assert_eq!(steps.len(), 8);
        assert!(msgs >= 16); // at least query+reply per peer
        assert!(steps.iter().all(|&s| s > 0 && s < 30)); // not observer(0)
    }
}
