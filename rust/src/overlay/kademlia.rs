//! Kademlia-style overlay (Maymounkov & Mazières 2002) — the paper's
//! other suggested substrate ("e.g., chord or kademlia", §3.2).
//!
//! XOR-metric id space with per-node k-buckets. Provides the same two
//! primitives the sampling layer needs:
//!
//! * `lookup(target)` — iterative closest-node routing, O(log n) hops;
//! * `sample_nodes(observer, β)` — uniform peer sampling by looking up
//!   uniformly-random ids and taking the closest-window correction
//!   (mirror of the chord ring's successor-window method, in XOR space);
//! * `estimate_size(observer)` — population estimate from the density of
//!   the observer's nearest neighbours: for uniform ids the expected
//!   XOR distance of the k-th nearest neighbour is `k·2^64/n`.
//!
//! Both overlays exist so the sampling correctness claims are not an
//! artifact of one topology; `overlay::tests` cross-checks uniformity on
//! both.

use crate::util::rng::Rng;

/// K-bucket width (replication factor k in the Kademlia paper).
pub const BUCKET_K: usize = 8;

/// A kademlia-style node table. Like [`super::Ring`], the authoritative
/// membership is kept flat (sorted ids) and routing emulates per-hop
/// bucket queries, counting the control messages a deployment would pay.
#[derive(Debug, Clone)]
pub struct Kademlia {
    /// Sorted (id, node) pairs.
    members: Vec<(u64, usize)>,
    namespace: u64,
}

impl Kademlia {
    pub fn new(namespace: u64) -> Kademlia {
        Kademlia { members: Vec::new(), namespace }
    }

    pub fn with_nodes(n: usize, namespace: u64) -> Kademlia {
        let mut k = Kademlia::new(namespace);
        for node in 0..n {
            k.join(node);
        }
        k
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn node_id(&self, node: usize) -> u64 {
        super::node_ring_id(node, self.namespace ^ KAD_SALT)
    }

    pub fn join(&mut self, node: usize) -> u64 {
        let id = self.node_id(node);
        match self.members.binary_search_by_key(&id, |&(i, _)| i) {
            Ok(_) => id, // collision: astronomically rare; id already present
            Err(pos) => {
                self.members.insert(pos, (id, node));
                id
            }
        }
    }

    pub fn leave(&mut self, node: usize) -> bool {
        let id = self.node_id(node);
        match self.members.binary_search_by_key(&id, |&(i, _)| i) {
            Ok(pos) if self.members[pos].1 == node => {
                self.members.remove(pos);
                true
            }
            _ => false,
        }
    }

    /// The node whose id is XOR-closest to `target`.
    pub fn closest(&self, target: u64) -> Option<(u64, usize)> {
        self.members
            .iter()
            .copied()
            .min_by_key(|&(id, _)| id ^ target)
    }

    /// `count` XOR-closest members to `target`, ascending by distance.
    pub fn closest_k(&self, target: u64, count: usize) -> Vec<(u64, usize)> {
        // Exploit sortedness: candidates near the insertion point first,
        // then verify by full distance ordering over a widened window.
        let mut all: Vec<(u64, usize)> = self.members.clone();
        all.sort_by_key(|&(id, _)| id ^ target);
        all.truncate(count);
        all
    }

    /// Iterative lookup emulation: each hop queries the current node's
    /// bucket for the closest known contacts and halves the distance.
    /// Returns (owner node, hops). As in [`super::Ring::lookup`], a
    /// self-lookup (the observer is already the closest node) is local
    /// and costs 0 hops; remote lookups cost ≥ 1.
    pub fn lookup(&self, from: usize, target: u64) -> Option<(usize, u32)> {
        if self.members.is_empty() {
            return None;
        }
        let (goal_id, goal_node) = self.closest(target)?;
        let mut cur = self.node_id(from);
        if cur == goal_id {
            return Some((goal_node, 0));
        }
        let mut hops = 0u32;
        while cur != goal_id && hops < 64 {
            // the current node knows the BUCKET_K closest contacts to the
            // target among members whose distance-to-target is less than
            // its own (bucket structure guarantees such a contact exists
            // and at least halves the distance)
            let dcur = cur ^ target;
            let next = self
                .members
                .iter()
                .copied()
                .filter(|&(id, _)| (id ^ target) < dcur)
                .min_by_key(|&(id, _)| id ^ target);
            match next {
                Some((id, _)) => {
                    // emulate halving: in a real kademlia the hop lands in
                    // the bucket covering the target's prefix
                    cur = id;
                    hops += 1;
                }
                None => break,
            }
        }
        Some((goal_node, hops.max(1)))
    }

    /// Uniform node sample via random-target lookups with a
    /// closest-window correction (the XOR-space analogue of the ring's
    /// successor-window sampling). Returns (nodes, control messages).
    pub fn sample_nodes(
        &self,
        observer: usize,
        beta: usize,
        rng: &mut Rng,
    ) -> (Vec<usize>, u64) {
        let n = self.members.len();
        let mut out = Vec::with_capacity(beta);
        let mut msgs = 0u64;
        if n <= 1 || beta == 0 {
            return (out, msgs);
        }
        let target_count = beta.min(n - 1);
        let window = BUCKET_K.min(n);
        let mut attempts = 0;
        while out.len() < target_count && attempts < 128 * (beta + 1) {
            attempts += 1;
            let t = rng.next_u64();
            let Some((_, hops)) = self.lookup(observer, t) else { continue };
            msgs += hops as u64 + 1;
            let w = self.closest_k(t, window);
            // pick uniformly within the window; the window's span in XOR
            // space is ~window·2^64/n regardless of where t landed, so
            // per-node selection probability is ~uniform.
            let pick = w[rng.next_below(w.len() as u64) as usize].1;
            if pick == observer || out.contains(&pick) {
                continue;
            }
            out.push(pick);
        }
        (out, msgs)
    }

    /// Population estimate from nearest-neighbour density (§3.2): the
    /// k-th nearest neighbour of a uniform id sits at expected XOR
    /// distance `k·2^64/(n+1)`, so `n ≈ k·2^64/d_k`.
    pub fn estimate_size(&self, observer: usize, k: usize) -> f64 {
        let n = self.members.len();
        if n <= 1 {
            return n as f64;
        }
        let k = k.min(n - 1).max(1);
        let my = self.node_id(observer);
        let mut neigh = self.closest_k(my, k + 1); // includes self
        neigh.retain(|&(_, node)| node != observer);
        neigh.truncate(k);
        let d_k = neigh.last().map(|&(id, _)| id ^ my).unwrap_or(u64::MAX);
        if d_k == 0 {
            return n as f64;
        }
        k as f64 * (u64::MAX as f64) / d_k as f64
    }
}

/// Salt so kademlia ids differ from ring ids in the same namespace.
const KAD_SALT: u64 = 0x4B41_444D_4C49_4121;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    #[test]
    fn join_leave_membership() {
        let mut k = Kademlia::new(1);
        k.join(0);
        k.join(1);
        assert_eq!(k.len(), 2);
        assert!(k.leave(0));
        assert!(!k.leave(0));
        assert_eq!(k.len(), 1);
    }

    #[test]
    fn closest_is_truly_closest() {
        let k = Kademlia::with_nodes(200, 5);
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let t = rng.next_u64();
            let (id, _) = k.closest(t).unwrap();
            for node in 0..200 {
                assert!(id ^ t <= k.node_id(node) ^ t);
            }
        }
    }

    #[test]
    fn lookup_converges_in_log_hops() {
        let k = Kademlia::with_nodes(1000, 7);
        let mut rng = Rng::new(3);
        let mut total = 0u32;
        for _ in 0..100 {
            let t = rng.next_u64();
            let (owner, hops) = k.lookup(0, t).unwrap();
            let (_, expect) = k.closest(t).unwrap();
            assert_eq!(owner, expect);
            total += hops;
        }
        let avg = total as f64 / 100.0;
        assert!(avg <= 2.0 * (1000f64).log2(), "avg hops {avg}");
    }

    #[test]
    fn sampling_approximately_uniform() {
        let k = Kademlia::with_nodes(20, 9);
        let mut rng = Rng::new(13);
        let mut counts = vec![0u32; 20];
        let trials = 8000;
        for _ in 0..trials {
            let (s, _) = k.sample_nodes(0, 1, &mut rng);
            for n in s {
                counts[n] += 1;
            }
        }
        assert_eq!(counts[0], 0, "observer must be excluded");
        let expected = trials as f64 / 19.0;
        for (i, &c) in counts.iter().enumerate().skip(1) {
            assert!(
                (c as f64) > expected * 0.5 && (c as f64) < expected * 1.7,
                "node {i}: {c} vs {expected}"
            );
        }
    }

    #[test]
    fn size_estimate_within_factor_three() {
        for &n in &[50usize, 500, 2000] {
            let k = Kademlia::with_nodes(n, 21);
            let est = k.estimate_size(0, BUCKET_K);
            assert!(
                est > n as f64 / 3.0 && est < n as f64 * 3.0,
                "n={n} est={est}"
            );
        }
    }

    #[test]
    fn prop_sample_bounds_and_distinct() {
        property("kademlia sample ≤ β, distinct, no observer", 50, |g| {
            let n = g.usize_in(1, 50);
            let beta = g.usize_in(0, 60);
            let k = Kademlia::with_nodes(n, 11);
            let mut rng = g.rng();
            let obs = g.usize_in(0, n - 1);
            let (s, _) = k.sample_nodes(obs, beta, &mut rng);
            assert!(s.len() <= beta.min(n.saturating_sub(1)));
            assert!(!s.contains(&obs));
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), s.len());
        });
    }

    #[test]
    fn self_lookup_costs_zero_hops() {
        let k = Kademlia::with_nodes(64, 5);
        let my = k.node_id(0);
        let (owner, hops) = k.lookup(0, my).unwrap();
        assert_eq!(owner, 0);
        assert_eq!(hops, 0);
        let other = k.node_id(1);
        let (owner, hops) = k.lookup(0, other).unwrap();
        assert_eq!(owner, 1);
        assert!(hops >= 1);
    }

    #[test]
    fn ids_differ_from_ring_ids() {
        let k = Kademlia::new(3);
        let ring_id = crate::overlay::node_ring_id(5, 3);
        assert_ne!(k.node_id(5), ring_id);
    }
}
