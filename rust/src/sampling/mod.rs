//! The **sampling primitive** — the paper's proposed system primitive.
//!
//! Two pieces of information are needed to estimate "what fraction of the
//! system has passed step s" (paper §3.1):
//!
//!  1. an estimate of the total number of nodes;
//!  2. an estimate of the distribution of the nodes' current steps.
//!
//! Both are answered by *sampling*, decoupling barrier control from model
//! consistency:
//!
//! * [`StepTracker`] — the oracle view: a central server's step table with
//!   O(1) global-min maintenance (the centralised PSP scenario where "PSP
//!   is as trivial as a counting process").
//! * [`StepDistribution`] — the estimator a node builds from a sample: the
//!   empirical CDF of observed steps plus the derived quantities used by
//!   barrier decisions and by the Section-6 analysis (lag CDF `F(r)`).
//! * [`OverlaySampler`] (in [`crate::overlay`]) — the fully-distributed
//!   view provider, drawing uniform node samples from a structured
//!   overlay without any global state.

use std::collections::VecDeque;

use crate::util::rng::Rng;

/// Central step table with incremental min/histogram maintenance.
///
/// Supports churn (join/leave) and O(β) sampling from the *active* set.
/// All engines and the simulator use this as the single source of truth
/// for node progress; distributed scenarios restrict themselves to the
/// sampled API.
///
/// The step histogram is a **dense sliding window** rather than a tree:
/// active steps always span a narrow band `[min, max]` (the barrier
/// bounds it for every method but ASP, and even ASP's spread grows
/// slowly), so a `VecDeque` of counts indexed from the window base gives
/// O(1) increments and O(1) `min_step`/`max_step` — the tree's per-step
/// node allocation and pointer chasing was a measurable slice of the
/// simulator's hot loop.
#[derive(Debug, Clone)]
pub struct StepTracker {
    /// Step of every node ever seen (dense by NodeId).
    steps: Vec<u64>,
    /// Whether the node is currently part of the system.
    active: Vec<bool>,
    /// Dense list of active node ids (for O(1) uniform sampling).
    active_ids: Vec<u32>,
    /// Position of each node id in `active_ids` (usize::MAX = not active).
    pos: Vec<usize>,
    /// `hist[i]` = active nodes at step `base + i`. Both ends are kept
    /// non-zero whenever any node is active, so the window bounds *are*
    /// the min/max steps.
    hist: VecDeque<u32>,
    /// Step of `hist[0]`.
    base: u64,
}

impl StepTracker {
    /// Create a tracker with `n` nodes, all active at step 0.
    pub fn new(n: usize) -> StepTracker {
        let mut hist = VecDeque::new();
        if n > 0 {
            hist.push_back(n as u32);
        }
        StepTracker {
            steps: vec![0; n],
            active: vec![true; n],
            active_ids: (0..n as u32).collect(),
            pos: (0..n).collect(),
            hist,
            base: 0,
        }
    }

    /// Number of active nodes.
    pub fn len(&self) -> usize {
        self.active_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active_ids.is_empty()
    }

    /// Total nodes ever registered (dense id space).
    pub fn capacity(&self) -> usize {
        self.steps.len()
    }

    pub fn step_of(&self, node: usize) -> u64 {
        self.steps[node]
    }

    pub fn is_active(&self, node: usize) -> bool {
        self.active[node]
    }

    /// The `k`-th active node id (in the tracker's internal dense order,
    /// which is stable between mutations). With a uniform `k` this is a
    /// uniform draw from the active set in O(1) — the simulator's churn
    /// victim pick uses it instead of scanning all nodes.
    pub fn active_id_at(&self, k: usize) -> usize {
        self.active_ids[k] as usize
    }

    /// Minimum step over active nodes (the BSP/SSP release frontier).
    pub fn min_step(&self) -> u64 {
        if self.hist.is_empty() {
            0
        } else {
            self.base
        }
    }

    /// Maximum step over active nodes.
    pub fn max_step(&self) -> u64 {
        if self.hist.is_empty() {
            0
        } else {
            self.base + self.hist.len() as u64 - 1
        }
    }

    /// Advance a node's step by one; returns the new global min if it
    /// changed (the simulator uses this to release blocked workers).
    pub fn advance(&mut self, node: usize) -> Option<u64> {
        assert!(self.active[node], "advance on inactive node {node}");
        let old = self.steps[node];
        let old_min = self.min_step();
        self.steps[node] = old + 1;
        // Increment before decrement: the new count anchors the window so
        // the front-trim in `dec_hist` cannot slide past it.
        self.inc_hist(old + 1);
        self.dec_hist(old);
        let new_min = self.min_step();
        (new_min != old_min).then_some(new_min)
    }

    /// Advance a node directly to `step` (batched step reports: a worker
    /// that accumulates updates locally may report a jump of several
    /// steps in one message). A no-op when `step` is not ahead of the
    /// node's current step. Returns the new global min if it changed.
    pub fn advance_to(&mut self, node: usize, step: u64) -> Option<u64> {
        assert!(self.active[node], "advance_to on inactive node {node}");
        let old = self.steps[node];
        if step <= old {
            return None;
        }
        let old_min = self.min_step();
        self.steps[node] = step;
        self.inc_hist(step);
        self.dec_hist(old);
        let new_min = self.min_step();
        (new_min != old_min).then_some(new_min)
    }

    /// Register a new node joining at the current minimum step (a fresh
    /// replica starts from the latest checkpointed frontier). Returns its id.
    pub fn join(&mut self) -> usize {
        let id = self.steps.len();
        let step = self.min_step();
        self.steps.push(step);
        self.active.push(true);
        self.pos.push(self.active_ids.len());
        self.active_ids.push(id as u32);
        self.inc_hist(step);
        id
    }

    /// Remove a node (churn). Returns the new global min if it changed —
    /// a departing straggler can release a BSP barrier.
    pub fn leave(&mut self, node: usize) -> Option<u64> {
        if !self.active[node] {
            return None;
        }
        let old_min = self.min_step();
        self.active[node] = false;
        let p = self.pos[node];
        let last = *self.active_ids.last().unwrap() as usize;
        self.active_ids.swap_remove(p);
        if p < self.active_ids.len() {
            self.pos[last] = p;
        }
        self.pos[node] = usize::MAX;
        self.dec_hist(self.steps[node]);
        let new_min = self.min_step();
        (!self.is_empty() && new_min != old_min).then_some(new_min)
    }

    fn inc_hist(&mut self, step: u64) {
        if self.hist.is_empty() {
            // No active nodes: re-anchor the window wherever needed.
            self.base = step;
            self.hist.push_back(1);
            return;
        }
        debug_assert!(step >= self.base, "hist window regressed");
        let idx = (step - self.base) as usize;
        while idx >= self.hist.len() {
            self.hist.push_back(0);
        }
        self.hist[idx] += 1;
    }

    fn dec_hist(&mut self, step: u64) {
        let idx = (step - self.base) as usize;
        let c = &mut self.hist[idx];
        debug_assert!(*c > 0, "hist underflow");
        *c -= 1;
        // Keep both window ends non-zero (min/max are the window bounds).
        // Amortised O(1): the front only ever moves forward with the
        // rising minimum, the back only retreats past steps abandoned by
        // a departing or advancing maximum.
        while self.hist.front() == Some(&0) {
            self.hist.pop_front();
            self.base += 1;
        }
        while self.hist.back() == Some(&0) {
            self.hist.pop_back();
        }
    }

    /// Steps of all active nodes (allocates; global-view engines only).
    pub fn all_steps(&self) -> Vec<u64> {
        self.active_ids.iter().map(|&i| self.steps[i as usize]).collect()
    }

    /// The sampling primitive against the oracle: draw β active nodes
    /// (excluding `observer` if active) and return the **minimum** step
    /// observed — sufficient statistic for every barrier in this crate.
    ///
    /// Allocation-free given the scratch buffer. Cost model: 2β control
    /// messages in the distributed setting (query + reply).
    pub fn sample_min(
        &self,
        observer: usize,
        beta: usize,
        rng: &mut Rng,
        scratch: &mut Vec<usize>,
    ) -> Option<u64> {
        let n = self.active_ids.len();
        if n == 0 || beta == 0 {
            return None;
        }
        // Exclude the observer by sampling from n-1 virtual slots and
        // remapping: slot i >= observer_pos maps to i+1.
        let obs_pos = if observer < self.pos.len() && self.active[observer] {
            self.pos[observer]
        } else {
            usize::MAX
        };
        let pool = if obs_pos != usize::MAX { n - 1 } else { n };
        if pool == 0 {
            return None;
        }
        rng.sample_into(pool, beta.min(pool), scratch);
        let mut min = u64::MAX;
        for &slot in scratch.iter() {
            let idx = if obs_pos != usize::MAX && slot >= obs_pos {
                slot + 1
            } else {
                slot
            };
            let node = self.active_ids[idx] as usize;
            min = min.min(self.steps[node]);
        }
        Some(min)
    }

    /// Full sampled view (steps, not just min) — used by the estimator
    /// and the quorum barrier path. Allocation-free like [`Self::sample_min`]:
    /// the caller provides the index scratch and the output buffer (which
    /// is cleared and filled with the sampled steps).
    pub fn sample_steps(
        &self,
        observer: usize,
        beta: usize,
        rng: &mut Rng,
        scratch: &mut Vec<usize>,
        out: &mut Vec<u64>,
    ) {
        out.clear();
        let n = self.active_ids.len();
        if n == 0 || beta == 0 {
            return;
        }
        let obs_pos = if observer < self.pos.len() && self.active[observer] {
            self.pos[observer]
        } else {
            usize::MAX
        };
        let pool = if obs_pos != usize::MAX { n - 1 } else { n };
        if pool == 0 {
            return;
        }
        rng.sample_into(pool, beta.min(pool), scratch);
        for &slot in scratch.iter() {
            let idx = if obs_pos != usize::MAX && slot >= obs_pos {
                slot + 1
            } else {
                slot
            };
            out.push(self.steps[self.active_ids[idx] as usize]);
        }
    }
}

/// Empirical step/lag distribution built from a sample — the estimator of
/// paper §3.2 ("investigate the distribution of these observed steps to
/// derive an estimate of the percentage of nodes which have passed a given
/// step").
#[derive(Debug, Clone)]
pub struct StepDistribution {
    sorted: Vec<u64>,
}

impl StepDistribution {
    pub fn from_sample(mut sample: Vec<u64>) -> StepDistribution {
        sample.sort_unstable();
        StepDistribution { sorted: sample }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Estimated fraction of the system with step ≥ `s`.
    pub fn frac_passed(&self, s: u64) -> f64 {
        if self.sorted.is_empty() {
            return 1.0; // no evidence: optimistic (ASP behaviour)
        }
        let idx = self.sorted.partition_point(|&x| x < s);
        (self.sorted.len() - idx) as f64 / self.sorted.len() as f64
    }

    /// Empirical lag CDF `F(r)` relative to `my_step`: fraction of sampled
    /// peers lagging at most `r` steps behind — the quantity the Section-6
    /// bounds are written in.
    pub fn lag_cdf(&self, my_step: u64, r: u64) -> f64 {
        if self.sorted.is_empty() {
            return 1.0;
        }
        let passing = self
            .sorted
            .iter()
            .filter(|&&s| my_step.saturating_sub(s) <= r)
            .count();
        passing as f64 / self.sorted.len() as f64
    }

    /// Threshold-style decision (paper §3.2): advance if at least
    /// `quorum` fraction of the sample has passed `my_step - staleness`.
    /// With quorum = 1.0 this is exactly pSSP; lower quorums give the
    /// "percentage barrier" generalisation discussed in §3.1.
    pub fn quorum_advance(&self, my_step: u64, staleness: u64, quorum: f64) -> bool {
        self.lag_cdf(my_step, staleness) >= quorum
    }
}

/// Estimate the total system size from observed id density in a hash ring
/// (paper §3.2: "the total number of nodes can be estimated by the density
/// of each zone"). Given the `k` nearest ids within a zone spanning
/// `zone_frac` of the ring, the MLE of the population is `k / zone_frac`.
pub fn estimate_system_size(ids_in_zone: usize, zone_frac: f64) -> f64 {
    assert!(zone_frac > 0.0 && zone_frac <= 1.0);
    ids_in_zone as f64 / zone_frac
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    #[test]
    fn tracker_basic_advance_and_min() {
        let mut t = StepTracker::new(3);
        assert_eq!(t.min_step(), 0);
        assert_eq!(t.advance(0), None); // min still 0 (nodes 1,2 at 0)
        assert_eq!(t.advance(1), None);
        assert_eq!(t.advance(2), Some(1)); // all at 1 now
        assert_eq!(t.min_step(), 1);
        assert_eq!(t.max_step(), 1);
    }

    #[test]
    fn tracker_advance_to_jumps_and_tracks_min() {
        let mut t = StepTracker::new(3);
        assert_eq!(t.advance_to(0, 5), None); // min still 0
        assert_eq!(t.step_of(0), 5);
        assert_eq!(t.max_step(), 5);
        // stale or equal reports are no-ops
        assert_eq!(t.advance_to(0, 5), None);
        assert_eq!(t.advance_to(0, 3), None);
        assert_eq!(t.step_of(0), 5);
        // the last laggard jumping raises the global min
        t.advance_to(1, 4);
        assert_eq!(t.advance_to(2, 2), Some(2));
        assert_eq!(t.min_step(), 2);
        // equivalent to repeated advance() for +1 reports
        let mut a = StepTracker::new(2);
        let mut b = StepTracker::new(2);
        a.advance(0);
        b.advance_to(0, 1);
        assert_eq!(a.all_steps(), b.all_steps());
        assert_eq!(a.min_step(), b.min_step());
    }

    #[test]
    fn tracker_join_starts_at_frontier() {
        let mut t = StepTracker::new(2);
        t.advance(0);
        t.advance(1);
        t.advance(0);
        let id = t.join();
        assert_eq!(t.step_of(id), 1); // joins at min
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn tracker_leave_releases_min() {
        let mut t = StepTracker::new(3);
        t.advance(0);
        t.advance(1);
        // node 2 is the straggler at step 0
        assert_eq!(t.min_step(), 0);
        assert_eq!(t.leave(2), Some(1));
        assert_eq!(t.min_step(), 1);
        assert_eq!(t.len(), 2);
        // leaving twice is a no-op
        assert_eq!(t.leave(2), None);
    }

    #[test]
    fn tracker_sample_excludes_observer() {
        let mut t = StepTracker::new(5);
        for _ in 0..7 {
            t.advance(0); // node 0 races ahead
        }
        let mut rng = Rng::new(1);
        let mut scratch = Vec::new();
        // Node 0 samples everyone else; their steps are all 0.
        for _ in 0..50 {
            let m = t.sample_min(0, 4, &mut rng, &mut scratch).unwrap();
            assert_eq!(m, 0);
        }
        // Another node sampling 4-of-4 peers must see node 0's step 7.
        let mut seen7 = false;
        let mut view = Vec::new();
        for _ in 0..50 {
            t.sample_steps(1, 4, &mut rng, &mut scratch, &mut view);
            assert_eq!(view.len(), 4);
            seen7 |= view.contains(&7);
        }
        assert!(seen7);
    }

    #[test]
    fn sample_steps_reuses_buffers() {
        let t = StepTracker::new(6);
        let mut rng = Rng::new(9);
        let mut scratch = Vec::new();
        let mut view = Vec::new();
        t.sample_steps(0, 3, &mut rng, &mut scratch, &mut view);
        assert_eq!(view.len(), 3);
        // β=0 and empty trackers clear the output.
        t.sample_steps(0, 0, &mut rng, &mut scratch, &mut view);
        assert!(view.is_empty());
    }

    #[test]
    fn advance_with_wide_gap_keeps_window_consistent() {
        // Regression: a laggard advancing from the window base while the
        // other node sits far ahead must not slide the base past the
        // laggard's new step.
        let mut t = StepTracker::new(2);
        t.advance_to(1, 5);
        assert_eq!(t.advance(0), Some(1));
        assert_eq!(t.min_step(), 1);
        assert_eq!(t.max_step(), 5);
        // And the single-node collapse: removing the laggard re-anchors.
        assert_eq!(t.leave(0), Some(5));
        assert_eq!(t.min_step(), 5);
        assert_eq!(t.max_step(), 5);
    }

    #[test]
    fn active_id_at_covers_exactly_the_active_set() {
        let mut t = StepTracker::new(5);
        t.leave(2);
        let mut seen: Vec<usize> = (0..t.len()).map(|k| t.active_id_at(k)).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 3, 4]);
    }

    #[test]
    fn tracker_sample_beta_zero_is_none() {
        let t = StepTracker::new(4);
        let mut rng = Rng::new(2);
        let mut s = Vec::new();
        assert_eq!(t.sample_min(0, 0, &mut rng, &mut s), None);
    }

    #[test]
    fn tracker_single_node_sample_is_none() {
        let t = StepTracker::new(1);
        let mut rng = Rng::new(3);
        let mut s = Vec::new();
        assert_eq!(t.sample_min(0, 5, &mut rng, &mut s), None);
    }

    #[test]
    fn prop_hist_matches_steps() {
        property("tracker histogram consistent", 100, |g| {
            let n = g.usize_in(1, 40);
            let ops = g.usize_in(0, 200);
            let mut t = StepTracker::new(n);
            let mut rng = g.rng();
            for _ in 0..ops {
                let node = rng.next_below(t.capacity() as u64) as usize;
                match rng.next_below(10) {
                    0 => {
                        t.leave(node);
                    }
                    1 => {
                        t.join();
                    }
                    _ => {
                        if t.is_active(node) {
                            t.advance(node);
                        }
                    }
                }
            }
            if !t.is_empty() {
                let steps = t.all_steps();
                assert_eq!(
                    t.min_step(),
                    *steps.iter().min().unwrap(),
                    "min mismatch"
                );
                assert_eq!(
                    t.max_step(),
                    *steps.iter().max().unwrap(),
                    "max mismatch"
                );
                assert_eq!(t.len(), steps.len());
            }
        });
    }

    #[test]
    fn prop_sample_min_ge_global_min() {
        property("sampled min ≥ global min", 100, |g| {
            let n = g.usize_in(2, 50);
            let beta = g.usize_in(1, n);
            let mut t = StepTracker::new(n);
            let mut rng = g.rng();
            for _ in 0..g.usize_in(0, 100) {
                let node = rng.next_below(n as u64) as usize;
                t.advance(node);
            }
            let mut scratch = Vec::new();
            if let Some(m) = t.sample_min(0, beta, &mut rng, &mut scratch) {
                assert!(m >= t.min_step());
            }
        });
    }

    #[test]
    fn distribution_frac_passed() {
        let d = StepDistribution::from_sample(vec![1, 2, 2, 3, 10]);
        assert_eq!(d.frac_passed(0), 1.0);
        assert_eq!(d.frac_passed(2), 0.8);
        assert_eq!(d.frac_passed(3), 0.4);
        assert_eq!(d.frac_passed(11), 0.0);
    }

    #[test]
    fn distribution_lag_cdf() {
        let d = StepDistribution::from_sample(vec![5, 7, 9]);
        assert_eq!(d.lag_cdf(9, 0), 1.0 / 3.0);
        assert_eq!(d.lag_cdf(9, 2), 2.0 / 3.0);
        assert_eq!(d.lag_cdf(9, 4), 1.0);
        // quorum: pSSP is quorum=1.0
        assert!(d.quorum_advance(9, 4, 1.0));
        assert!(!d.quorum_advance(9, 2, 1.0));
        assert!(d.quorum_advance(9, 2, 0.5));
    }

    #[test]
    fn empty_distribution_is_optimistic() {
        let d = StepDistribution::from_sample(vec![]);
        assert_eq!(d.frac_passed(100), 1.0);
        assert!(d.quorum_advance(100, 0, 1.0)); // β=0 == ASP
    }

    #[test]
    fn system_size_estimator() {
        // 10 ids observed in 1% of the ring => ~1000 nodes.
        assert_eq!(estimate_system_size(10, 0.01), 1000.0);
    }
}
