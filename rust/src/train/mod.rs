//! Training drivers over the PJRT artifacts — the end-to-end layer that
//! proves L1 (Pallas kernels) + L2 (JAX model) + L3 (this coordinator)
//! compose on a real workload with Python nowhere on the path.
//!
//! * [`TransformerTrainer`] — owns the `tf_<cfg>_{init,step,loss}`
//!   artifact triple: initialises parameters on-device from a seed,
//!   applies fused train steps (fwd + bwd through the Pallas attention
//!   kernel + SGD update in ONE executable), evaluates held-out loss.
//! * [`Corpus`] — deterministic synthetic byte-level corpus with enough
//!   structure to be learnable in a few hundred steps.
//! * [`train_lm`] — single-stream training loop (quickstart).
//! * [`psp_train_lm`] — the paper's technique on the LM workload: N
//!   logical workers with heterogeneous virtual speeds submit batches,
//!   paced by any [`Method`]; updates apply in virtual-time order, so
//!   barrier control decides *which* batches the model sees when —
//!   exactly the coupling the paper studies, with real gradients.

use anyhow::{anyhow, Result};

use crate::barrier::{Method, ViewRequirement};
use crate::runtime::{Runtime, Tensor};
use crate::sampling::StepTracker;
use crate::sim::EventScheduler;
use crate::util::rng::Rng;

/// Deterministic synthetic byte-level corpus.
///
/// Sentences are drawn from a small template pool with rotating number
/// words — repetitive enough that a tiny LM's loss falls well below the
/// uniform baseline within a few hundred steps, varied enough that it
/// must actually condition on context.
#[derive(Debug, Clone)]
pub struct Corpus {
    text: Vec<u8>,
    vocab: usize,
}

const TEMPLATES: [&str; 6] = [
    "the quick brown fox jumps over the lazy dog. ",
    "a stitch in time saves nine, they say. ",
    "all work and no play makes jack a dull boy. ",
    "pack my box with five dozen liquor jugs. ",
    "sphinx of black quartz, judge my vow. ",
    "how vexingly quick daft zebras jump! ",
];

impl Corpus {
    /// Build a corpus of roughly `target_bytes` bytes for a model with the
    /// given vocabulary size (tokens are bytes clamped into the vocab).
    pub fn synthetic(target_bytes: usize, vocab: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let mut text = Vec::with_capacity(target_bytes + 64);
        while text.len() < target_bytes {
            let t = TEMPLATES[rng.next_below(TEMPLATES.len() as u64) as usize];
            text.extend_from_slice(t.as_bytes());
        }
        Corpus { text, vocab }
    }

    pub fn len(&self) -> usize {
        self.text.len()
    }

    pub fn is_empty(&self) -> bool {
        self.text.is_empty()
    }

    /// Sample a `(batch, seq+1)` token batch (flattened, row-major).
    pub fn next_batch(&self, batch: usize, seq: usize, rng: &mut Rng) -> Vec<i32> {
        let span = seq + 1;
        let mut out = Vec::with_capacity(batch * span);
        for _ in 0..batch {
            let start =
                rng.next_below((self.text.len() - span) as u64) as usize;
            out.extend(
                self.text[start..start + span]
                    .iter()
                    .map(|&b| (b as usize % self.vocab) as i32),
            );
        }
        out
    }
}

/// Hyper-parameters read back from the artifact manifest meta.
#[derive(Debug, Clone)]
pub struct TfMeta {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub param_count: u64,
    pub n_params: usize,
}

/// Driver for one transformer artifact set on a [`Runtime`].
pub struct TransformerTrainer {
    rt: Runtime,
    pub meta: TfMeta,
    params: Vec<Tensor>,
    step_name: String,
    loss_name: String,
}

impl TransformerTrainer {
    /// Load artifacts for `cfg` ("tiny", "small", ...) and initialise
    /// parameters on-device from `seed` via the `tf_<cfg>_init` artifact.
    pub fn new(rt: Runtime, cfg: &str, seed: i32) -> Result<TransformerTrainer> {
        let init_name = format!("tf_{cfg}_init");
        let step_name = format!("tf_{cfg}_step");
        let loss_name = format!("tf_{cfg}_loss");
        let spec = rt.manifest().find(&step_name)?.clone();
        let m = spec
            .meta
            .get("config")
            .ok_or_else(|| anyhow!("artifact meta missing config"))?;
        let meta = TfMeta {
            name: cfg.to_string(),
            vocab: m.req("vocab")?.as_usize().unwrap(),
            seq: m.req("seq")?.as_usize().unwrap(),
            batch: m.req("batch")?.as_usize().unwrap(),
            param_count: m.req("param_count")?.as_i64().unwrap() as u64,
            n_params: spec.inputs.len() - 2,
        };
        let params = rt.execute(&init_name, &[Tensor::I32(vec![seed])])?;
        assert_eq!(params.len(), meta.n_params);
        Ok(TransformerTrainer { rt, meta, params, step_name, loss_name })
    }

    /// One fused SGD step on a `(batch, seq+1)` token batch. Returns the
    /// loss *before* the update.
    pub fn train_step(&mut self, tokens: &[i32], lr: f32) -> Result<f32> {
        let expect = self.meta.batch * (self.meta.seq + 1);
        if tokens.len() != expect {
            return Err(anyhow!(
                "batch is {} tokens, artifact wants {expect}",
                tokens.len()
            ));
        }
        let mut inputs = self.params.clone();
        inputs.push(Tensor::I32(tokens.to_vec()));
        inputs.push(Tensor::F32(vec![lr]));
        let mut out = self.rt.execute(&self.step_name, &inputs)?;
        let loss = out.pop().expect("loss output").into_f32()?[0];
        self.params = out;
        Ok(loss)
    }

    /// Held-out loss on a batch (no update).
    pub fn eval_loss(&self, tokens: &[i32]) -> Result<f32> {
        let mut inputs = self.params.clone();
        inputs.push(Tensor::I32(tokens.to_vec()));
        let out = self.rt.execute(&self.loss_name, &inputs)?;
        Ok(out[0].as_f32()?[0])
    }

    /// Uniform-prediction baseline: ln(vocab).
    pub fn uniform_loss(&self) -> f32 {
        (self.meta.vocab as f32).ln()
    }
}

/// A recorded training run.
#[derive(Debug, Clone)]
pub struct TrainLog {
    /// (global step, loss-before-step).
    pub losses: Vec<(u64, f32)>,
    pub wall_secs: f64,
    pub steps_per_sec: f64,
    /// Per-worker final step counts (multi-worker runs).
    pub worker_steps: Vec<u64>,
}

impl TrainLog {
    pub fn first_loss(&self) -> f32 {
        self.losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }

    pub fn last_loss(&self) -> f32 {
        self.losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }

    /// Mean loss over the last k recorded steps.
    pub fn tail_mean(&self, k: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return f32::NAN;
        }
        let k = k.min(n);
        self.losses[n - k..].iter().map(|&(_, l)| l).sum::<f32>() / k as f32
    }
}

/// Single-stream LM training (quickstart path).
pub fn train_lm(
    trainer: &mut TransformerTrainer,
    corpus: &Corpus,
    steps: u64,
    lr: f32,
    seed: u64,
) -> Result<TrainLog> {
    let start = std::time::Instant::now();
    let mut rng = Rng::new(seed);
    let mut losses = Vec::with_capacity(steps as usize);
    for step in 0..steps {
        let batch = corpus.next_batch(trainer.meta.batch, trainer.meta.seq, &mut rng);
        let loss = trainer.train_step(&batch, lr)?;
        losses.push((step, loss));
    }
    let wall = start.elapsed().as_secs_f64();
    Ok(TrainLog {
        steps_per_sec: steps as f64 / wall.max(1e-9),
        losses,
        wall_secs: wall,
        worker_steps: vec![steps],
    })
}

/// PSP-paced data-parallel LM training.
///
/// `n_workers` logical workers with heterogeneous virtual speeds each
/// stream their own batches; a worker may start its next step only when
/// the chosen barrier `method` admits it (evaluated against the oracle
/// step table, the centralised scenario of §5). Updates are applied in
/// virtual-time order through the shared fused-step executable. Straggler
/// workers can be injected with `slow` (fraction, slowdown).
///
/// `accum` is the LM-layer analogue of the sharded engine's `push_batch`:
/// each logical step applies `accum` consecutive micro-batches (recording
/// their mean loss), so one barrier decision paces a larger batched
/// update. `accum = 1` is the paper's per-step protocol.
pub fn psp_train_lm(
    trainer: &mut TransformerTrainer,
    corpus: &Corpus,
    method: Method,
    n_workers: usize,
    total_steps: u64,
    lr: f32,
    seed: u64,
    slow: Option<(f64, f64)>,
    accum: usize,
) -> Result<TrainLog> {
    let start = std::time::Instant::now();
    let mut rng = Rng::new(seed);
    let barrier = method.build();
    let staleness = barrier.staleness();
    let mut tracker = StepTracker::new(n_workers);
    let mut scratch = Vec::new();
    // (virtual finish time, worker) min-queue
    let mut queue = crate::sim::EventQueue::new();
    let speeds: Vec<f64> = (0..n_workers)
        .map(|i| {
            let mut s = rng.uniform(0.7, 1.3);
            if let Some((frac, slowdown)) = slow {
                if (i as f64) < frac * n_workers as f64 {
                    s *= slowdown;
                }
            }
            s
        })
        .collect();
    for (i, &s) in speeds.iter().enumerate() {
        queue.push(rng.exponential(s), crate::sim::EventKind::ComputeDone { node: i });
    }
    let mut losses = Vec::new();
    let mut applied = 0u64;
    while applied < total_steps {
        let Some(ev) = queue.pop() else { break };
        let crate::sim::EventKind::ComputeDone { node } = ev.kind else {
            continue;
        };
        let my_step = tracker.step_of(node);
        let pass = match barrier.view() {
            ViewRequirement::None => true,
            ViewRequirement::Global => tracker.min_step() + staleness >= my_step,
            ViewRequirement::Sample(beta) => {
                match tracker.sample_min(node, beta, &mut rng, &mut scratch) {
                    None => true,
                    Some(min) => min + staleness >= my_step,
                }
            }
        };
        if !pass {
            // re-check after a short virtual back-off
            queue.push(
                ev.time + rng.uniform(0.05, 0.15),
                crate::sim::EventKind::ComputeDone { node },
            );
            continue;
        }
        // the worker's batch(es) go through the real fused step
        let accum = accum.max(1);
        let mut loss_acc = 0.0f32;
        for _ in 0..accum {
            let batch =
                corpus.next_batch(trainer.meta.batch, trainer.meta.seq, &mut rng);
            loss_acc += trainer.train_step(&batch, lr)?;
        }
        losses.push((applied, loss_acc / accum as f32));
        applied += 1;
        tracker.advance(node);
        queue.push(
            ev.time + rng.exponential(speeds[node]),
            crate::sim::EventKind::ComputeDone { node },
        );
    }
    let wall = start.elapsed().as_secs_f64();
    Ok(TrainLog {
        steps_per_sec: applied as f64 / wall.max(1e-9),
        losses,
        wall_secs: wall,
        worker_steps: (0..n_workers).map(|i| tracker.step_of(i)).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_batches_in_vocab() {
        let c = Corpus::synthetic(4096, 256, 1);
        assert!(c.len() >= 4096);
        let mut rng = Rng::new(2);
        let b = c.next_batch(4, 32, &mut rng);
        assert_eq!(b.len(), 4 * 33);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn corpus_deterministic() {
        let a = Corpus::synthetic(2048, 128, 7);
        let b = Corpus::synthetic(2048, 128, 7);
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        assert_eq!(a.next_batch(2, 16, &mut r1), b.next_batch(2, 16, &mut r2));
    }

    #[test]
    fn corpus_small_vocab_clamps() {
        let c = Corpus::synthetic(1024, 61, 9);
        let mut rng = Rng::new(4);
        let b = c.next_batch(2, 8, &mut rng);
        assert!(b.iter().all(|&t| (0..61).contains(&t)));
    }

    #[test]
    fn train_log_stats() {
        let log = TrainLog {
            losses: vec![(0, 4.0), (1, 3.0), (2, 2.0), (3, 1.0)],
            wall_secs: 1.0,
            steps_per_sec: 4.0,
            worker_steps: vec![4],
        };
        assert_eq!(log.first_loss(), 4.0);
        assert_eq!(log.last_loss(), 1.0);
        assert_eq!(log.tail_mean(2), 1.5);
    }

    // PJRT-backed trainer tests live in rust/tests/e2e_transformer.rs
    // (they need the artifacts and take seconds, not micros).
}
