//! The Actor framework's computation engines (paper §4).
//!
//! Three engines share one worker-facing API — `schedule / pull / push /
//! barrier` — and differ in where the *model* and the *nodes' states*
//! live (paper §4.1 design combinations):
//!
//! | engine | model | states | barriers supported |
//! |---|---|---|---|
//! | [`mapreduce`]   | central | central | BSP (supersteps) |
//! | [`paramserver`] | sharded central | central | BSP, SSP, ASP, pBSP, pSSP |
//! | [`p2p`]         | replicated | distributed | ASP, pBSP, pSSP |
//!
//! The parameter-server engine is the paper's *centralised PSP* scenario
//! (the server samples its own step table — "as trivial as a counting
//! process"), scaled out: the model vector is partitioned across
//! `n_shards` shard actors and workers scatter batched per-shard pushes,
//! while barrier state stays in one coordinator actor — sampling-based
//! barriers compose unchanged with a distributed server because they
//! never needed the model actor's state in the first place. The p2p
//! engine is the *fully distributed* scenario: every
//! worker holds a model replica and runs its own barrier decision over a
//! sample drawn from the structured overlay, with **no global state
//! anywhere** — the composition the paper argues only ASP and PSP can
//! support (global-view barriers are rejected at construction). Its
//! model plane disseminates deltas over the same overlay via the
//! [`gossip`] plane (sequence-numbered rumors, per-link batching, TTL'd
//! shortcuts + a successor chain) in O(n·fanout) messages per step; the
//! legacy O(n²) full-mesh broadcast survives as an explicit mode for
//! equivalence testing and baselines.
//!
//! These engines run real OS threads via [`crate::actor`] and compute real
//! gradients — either the pure-Rust linear model or the PJRT-backed AOT
//! artifact ([`crate::runtime`]); the gradient source is a plugged-in
//! closure ([`GradFn`]) so examples can choose.

pub mod delta;
pub mod gossip;
pub mod mapreduce;
pub mod membership;
pub mod node;
pub mod p2p;
pub mod paramserver;
pub mod transport;

use std::sync::Arc;

/// A run that could not complete, carrying whatever the engine salvaged.
///
/// Engines return this instead of aborting the process when the failure
/// is a *data-plane* fact the caller may want to inspect — e.g. the
/// parameter server losing a shard's last live candidate: the partial
/// report still holds the counters up to the abort and the model with
/// the surviving blocks filled in.
#[derive(Debug)]
pub struct EngineError {
    /// Human-readable cause, loud enough to paste into an incident note.
    pub reason: String,
    /// Everything the engine could still account for at the abort.
    pub partial: EngineReport,
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine aborted: {}", self.reason)
    }
}

impl std::error::Error for EngineError {}

/// A worker's gradient oracle: `(model snapshot, step seed) -> gradient`.
///
/// Implementations: [`crate::model::linear`] minibatch gradients (pure
/// Rust) or [`crate::runtime::LinearStepFn`] (PJRT executing the Pallas
/// kernel artifact).
pub type GradFn = Arc<dyn Fn(&[f32], u64) -> Vec<f32> + Send + Sync>;

/// Statistics every engine reports.
#[derive(Debug, Clone, Default)]
pub struct EngineReport {
    /// Final per-worker step counts.
    pub steps: Vec<u64>,
    /// Update (model-plane) messages. For the gossip p2p plane this
    /// counts **physical** messages — rumors for the same destination
    /// share one message per flush tick.
    pub update_msgs: u64,
    /// Control (barrier/sampling-plane) messages: sampling queries and
    /// replies plus overlay routing hops — including the routing the
    /// gossip plane spends picking shortcut targets.
    pub control_msgs: u64,
    /// Wall-clock seconds.
    pub wall_secs: f64,
    /// Final model (engine-dependent: server copy or worker-0 replica).
    pub model: Vec<f32>,
    /// All worker replicas (p2p engine only; empty elsewhere).
    pub replicas: Vec<Vec<f32>>,
    // -- dissemination stats (gossip p2p plane; zero elsewhere) --
    /// Rumors applied exactly once across all workers.
    pub applied_rumors: u64,
    /// Duplicate rumor arrivals dropped by per-origin sequence dedup.
    pub dup_rumors: u64,
    /// Rumor copies queued (bandwidth proxy; ≥ update_msgs since many
    /// copies can share one physical message).
    pub rumor_copies: u64,
    /// Late model-plane messages dropped at shutdown after the drain
    /// timeout expired (loudly logged; 0 on a healthy run). Kept as the
    /// per-worker `max(missing, discarded)` headline; the two components
    /// are reported separately below so repair losses (rumors never
    /// delivered) and discard losses (queued messages thrown away) stay
    /// distinguishable.
    pub dropped_deltas: u64,
    /// Rumors still owed (announced but never applied) when a worker's
    /// drain safety-net fired, summed over workers. Non-zero means the
    /// repair plane failed to reclaim something.
    pub missing_rumors: u64,
    /// Queued messages discarded unprocessed when the drain safety-net
    /// fired, summed over workers.
    pub discarded_msgs: u64,
    /// Shutdown-drain loop iterations, summed over workers. A healthy
    /// drain pays a handful; a worker camped on `drain_timeout` pays
    /// ~timeout / MIN_DRAIN_POLL — bounded either way, which is the
    /// no-busy-wait guarantee `tests/membership_crash.rs` asserts.
    pub drain_polls: u64,
    // -- crash-fault membership plane (zero when membership is off) --
    /// Death confirmations observed, summed over workers (each survivor
    /// confirms independently, so one crash at n workers reports n-1).
    pub confirmed_dead: u64,
    /// Repair-plane physical messages: custody re-announcements plus
    /// full-store re-sends after a successor loss.
    pub repair_msgs: u64,
    /// Rumors applied from repair messages that normal dissemination had
    /// not yet delivered — the deltas a crash would have lost.
    pub repaired_rumors: u64,
    /// Workers that left the run early (graceful leave or crash-stop),
    /// in worker-id order. Their replicas stop at the departure step.
    pub departed: Vec<usize>,
    // -- shard replication plane (paramserver; zero when replication off) --
    /// Pulls served from a block the answering shard actor was not the
    /// original home of — i.e. reads a replica (usually a promoted one)
    /// answered instead of the shard's first primary. Counted separately
    /// from `update_msgs`/`control_msgs` so the chaos gate can assert a
    /// post-kill pull really was replica-served.
    pub replica_pulls: u64,
    /// Bytes bulk-copied by `Install` handoffs when a confirmed-dead
    /// shard actor's blocks were re-homed (promotion re-seeding the
    /// successor list). Setup-time replica seeding is free; only
    /// failure-driven transfers count.
    pub handoff_bytes: u64,
    // -- unified barrier counters (every engine, via BarrierPolicy) --
    /// Barrier crossings that blocked at least once before passing,
    /// summed over workers. Same semantics in every engine and in
    /// [`crate::sim::SimResult::barrier_waits`].
    pub barrier_waits: u64,
    /// Failed admission evaluations (poll attempts that did not pass),
    /// summed over workers.
    pub stall_ticks: u64,
    /// Per-worker *effective* staleness bound after online adaptation —
    /// equal to the configured θ everywhere when adaptation is off
    /// (`u64::MAX` for ASP). Indexed by worker id.
    pub eff_staleness: Vec<u64>,
    /// Per-worker effective sample size β (0 for global/no-view methods).
    pub eff_sample: Vec<u64>,
    // -- compression plane (delta payloads; see [`delta`]) --
    /// Payload mode every origin encoded with (`"dense"` when
    /// compression is off).
    pub compress_mode: &'static str,
    /// Delta-payload bytes originated across all workers (wire form,
    /// before framing) — the numerator of the bytes/step headline the
    /// `ext_compress` ablation races.
    pub payload_bytes: u64,
    /// L1 mass the error-feedback accumulators re-injected (0 in dense
    /// mode — nothing is ever dropped).
    pub fed_back_mass: f64,
}

/// One worker's barrier-policy outcome, in the shape the engines fold
/// into [`EngineReport`]: lifetime counters plus the final effective
/// θ/β. Built from the worker's [`crate::barrier::BarrierPolicy`] right
/// before its thread returns.
#[derive(Debug, Clone, Copy, Default)]
pub struct BarrierOut {
    pub waits: u64,
    pub ticks: u64,
    pub eff_staleness: u64,
    pub eff_sample: u64,
}

impl BarrierOut {
    pub fn of(policy: &crate::barrier::BarrierPolicy) -> BarrierOut {
        BarrierOut {
            waits: policy.stats().barrier_waits,
            ticks: policy.stats().stall_ticks,
            eff_staleness: policy.staleness(),
            eff_sample: policy.sample_size() as u64,
        }
    }
}
