//! The Actor framework's computation engines (paper §4).
//!
//! Three engines share one worker-facing API — `schedule / pull / push /
//! barrier` — and differ in where the *model* and the *nodes' states*
//! live (paper §4.1 design combinations):
//!
//! | engine | model | states | barriers supported |
//! |---|---|---|---|
//! | [`mapreduce`]   | central | central | BSP (supersteps) |
//! | [`paramserver`] | sharded central | central | BSP, SSP, ASP, pBSP, pSSP |
//! | [`p2p`]         | replicated | distributed | ASP, pBSP, pSSP |
//!
//! The parameter-server engine is the paper's *centralised PSP* scenario
//! (the server samples its own step table — "as trivial as a counting
//! process"), scaled out: the model vector is partitioned across
//! `n_shards` shard actors and workers scatter batched per-shard pushes,
//! while barrier state stays in one coordinator actor — sampling-based
//! barriers compose unchanged with a distributed server because they
//! never needed the model actor's state in the first place. The p2p
//! engine is the *fully distributed* scenario: every
//! worker holds a model replica and runs its own barrier decision over a
//! sample drawn from the structured overlay, with **no global state
//! anywhere** — the composition the paper argues only ASP and PSP can
//! support (global-view barriers are rejected at construction).
//!
//! These engines run real OS threads via [`crate::actor`] and compute real
//! gradients — either the pure-Rust linear model or the PJRT-backed AOT
//! artifact ([`crate::runtime`]); the gradient source is a plugged-in
//! closure ([`GradFn`]) so examples can choose.

pub mod mapreduce;
pub mod p2p;
pub mod paramserver;

use std::sync::Arc;

/// A worker's gradient oracle: `(model snapshot, step seed) -> gradient`.
///
/// Implementations: [`crate::model::linear`] minibatch gradients (pure
/// Rust) or [`crate::runtime::LinearStepFn`] (PJRT executing the Pallas
/// kernel artifact).
pub type GradFn = Arc<dyn Fn(&[f32], u64) -> Vec<f32> + Send + Sync>;

/// Statistics every engine reports.
#[derive(Debug, Clone, Default)]
pub struct EngineReport {
    /// Final per-worker step counts.
    pub steps: Vec<u64>,
    /// Update (model-plane) messages.
    pub update_msgs: u64,
    /// Control (barrier/sampling-plane) messages.
    pub control_msgs: u64,
    /// Wall-clock seconds.
    pub wall_secs: f64,
    /// Final model (engine-dependent: server copy or worker-0 replica).
    pub model: Vec<f32>,
}
