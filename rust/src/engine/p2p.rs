//! Peer-to-peer engine — distributed model, distributed states (paper
//! §4.1 cases 2/4): **no global state anywhere**.
//!
//! Every worker holds a model replica and runs its own barrier decision
//! over a β-sample drawn from the structured overlay ([`crate::overlay`]).
//! Only ASP and the PSP family compose with this engine — global-view
//! methods (BSP/SSP) are rejected at construction, which *is* the paper's
//! systems argument: sampling turns barrier control into something each
//! node can execute independently.
//!
//! Mechanics:
//! * model plane: each step a worker computes a gradient against its
//!   replica, applies it locally, and **pushes the delta to every peer**
//!   (update messages counted);
//! * control plane: workers publish their step in a shared atomic table —
//!   the moral equivalent of answering `StepQuery` RPCs instantly — and a
//!   blocked worker re-samples the overlay each poll. Control messages
//!   are accounted as 2 per sampled peer plus overlay routing hops, which
//!   is what the real RPCs would cost.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::actor::System;
use crate::barrier::{Method, ViewRequirement};
use crate::engine::{EngineReport, GradFn};
use crate::overlay::Ring;
use crate::util::rng::Rng;

/// Messages between peer workers (model plane).
pub enum PeerMsg {
    /// A model delta from a peer: apply `w += delta`.
    Delta { delta: Vec<f32> },
    /// Finish up: no more deltas will arrive from `from`.
    Done { from: u32 },
}

/// Engine configuration.
#[derive(Clone)]
pub struct P2pConfig {
    pub n_workers: usize,
    pub steps_per_worker: u64,
    /// Must be ASP or a PSP method (no global view available).
    pub method: Method,
    pub lr: f32,
    pub dim: usize,
    pub seed: u64,
    pub poll: Duration,
}

impl Default for P2pConfig {
    fn default() -> Self {
        P2pConfig {
            n_workers: 8,
            steps_per_worker: 15,
            method: Method::Pssp { sample: 3, staleness: 2 },
            lr: 0.05,
            dim: 32,
            seed: 2,
            poll: Duration::from_micros(200),
        }
    }
}

/// Run the p2p engine. Panics if the method needs a global view.
pub fn run(cfg: &P2pConfig, init_w: Vec<f32>, grad_fn: GradFn) -> EngineReport {
    let barrier = cfg.method.build();
    assert!(
        !matches!(barrier.view(), ViewRequirement::Global),
        "p2p engine cannot host global-view barrier {} — use the \
         parameter-server engine (paper §4.1: only ASP/PSP work in case 4)",
        barrier.name()
    );
    let staleness = barrier.staleness();
    let start = Instant::now();
    let sys = System::new();
    let n = cfg.n_workers;

    // Published step table (the control plane each node exposes).
    let steps: Arc<Vec<AtomicU64>> =
        Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    // The structured overlay used for sampling.
    let ring = Arc::new(Ring::with_nodes(n, cfg.seed));

    // Build the mesh of addresses first (two-phase: spawn, then wire).
    let mut mailboxes = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for i in 0..n {
        let (tx, rx) = std::sync::mpsc::channel::<PeerMsg>();
        // Raw channel here: actor::Address requires a running body; we
        // need all endpoints before any worker starts.
        mailboxes.push(rx);
        addrs.push(tx);
        let _ = i;
    }
    let addrs = Arc::new(addrs);

    let workers: Vec<_> = mailboxes
        .into_iter()
        .enumerate()
        .map(|(i, rx)| {
            let grad_fn = grad_fn.clone();
            let steps = Arc::clone(&steps);
            let ring = Arc::clone(&ring);
            let addrs = Arc::clone(&addrs);
            let mut w = init_w.clone();
            let cfg = cfg.clone();
            let view = cfg.method.build().view();
            sys.spawn::<(), _, _>(&format!("p2p-{i}"), move |_mb| {
                let mut rng = Rng::new(cfg.seed ^ (i as u64).wrapping_mul(0xABCD_EF01));
                let mut control_msgs = 0u64;
                let mut update_msgs = 0u64;
                let mut done_peers = 0usize;
                let drain = |w: &mut Vec<f32>, done_peers: &mut usize| {
                    while let Ok(msg) = rx.try_recv() {
                        match msg {
                            PeerMsg::Delta { delta } => {
                                for (wi, di) in w.iter_mut().zip(&delta) {
                                    *wi += di;
                                }
                            }
                            PeerMsg::Done { .. } => *done_peers += 1,
                        }
                    }
                };
                for step in 0..cfg.steps_per_worker {
                    drain(&mut w, &mut done_peers);
                    // compute locally, apply locally
                    let g = grad_fn(&w, rng.next_u64());
                    let delta: Vec<f32> = g.iter().map(|x| -cfg.lr * x).collect();
                    for (wi, di) in w.iter_mut().zip(&delta) {
                        *wi += di;
                    }
                    // push the delta to all peers (model plane)
                    for (j, addr) in addrs.iter().enumerate() {
                        if j != i {
                            update_msgs += 1;
                            let _ = addr.send(PeerMsg::Delta { delta: delta.clone() });
                        }
                    }
                    steps[i].store(step + 1, Ordering::Release);
                    if step + 1 == cfg.steps_per_worker {
                        break;
                    }
                    // fully-distributed barrier: sample the overlay
                    loop {
                        let pass = match view {
                            ViewRequirement::None => true,
                            ViewRequirement::Sample(beta) => {
                                let (peers, hops) = ring.sample_nodes(i, beta, &mut rng);
                                control_msgs += hops + 2 * peers.len() as u64;
                                peers.iter().all(|&p| {
                                    let sp = steps[p].load(Ordering::Acquire);
                                    (step + 1).saturating_sub(sp) <= staleness
                                })
                            }
                            ViewRequirement::Global => unreachable!(),
                        };
                        if pass {
                            break;
                        }
                        drain(&mut w, &mut done_peers);
                        std::thread::sleep(cfg.poll);
                    }
                }
                // signal completion, then drain until all peers are done so
                // late deltas are not lost
                for (j, addr) in addrs.iter().enumerate() {
                    if j != i {
                        let _ = addr.send(PeerMsg::Done { from: i as u32 });
                    }
                }
                let deadline = Instant::now() + Duration::from_secs(5);
                while done_peers < addrs.len() - 1 && Instant::now() < deadline {
                    match rx.recv_timeout(Duration::from_millis(5)) {
                        Ok(PeerMsg::Delta { delta }) => {
                            for (wi, di) in w.iter_mut().zip(&delta) {
                                *wi += di;
                            }
                        }
                        Ok(PeerMsg::Done { .. }) => done_peers += 1,
                        Err(_) => {}
                    }
                }
                (w, control_msgs, update_msgs)
            })
        })
        .collect();

    let mut control_msgs = 0;
    let mut update_msgs = 0;
    let results: Vec<Vec<f32>> = workers
        .into_iter()
        .map(|wk| {
            let (addr, handle) = wk.into_parts();
            drop(addr);
            let (w, c, u) = handle.join().expect("p2p worker panicked");
            control_msgs += c;
            update_msgs += u;
            w
        })
        .collect();

    EngineReport {
        steps: steps.iter().map(|s| s.load(Ordering::Acquire)).collect(),
        update_msgs,
        control_msgs,
        wall_secs: start.elapsed().as_secs_f64(),
        model: results.into_iter().next().unwrap_or_default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::linear::{Dataset, LinearModel};
    use crate::util::stats::l2_dist;
    use std::sync::Mutex;

    fn linear_grad_fn(dim: usize, seed: u64) -> (GradFn, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let data = Dataset::synthetic(512, dim, 0.05, &mut rng);
        let w_true = data.w_true.clone();
        let model = Mutex::new(LinearModel::new(dim));
        let f: GradFn = Arc::new(move |w, s| {
            model.lock().unwrap().minibatch_grad(&data, w, s, 32).to_vec()
        });
        (f, w_true)
    }

    #[test]
    fn pssp_converges_fully_distributed() {
        let cfg = P2pConfig {
            n_workers: 6,
            steps_per_worker: 12,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 24,
            lr: 0.02,
            seed: 11,
            ..P2pConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 13);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        assert!(r.steps.iter().all(|&s| s == 12));
        let init = l2_dist(&vec![0.0; 24], &w_true);
        let err = l2_dist(&r.model, &w_true);
        assert!(err < init, "p2p did not reduce error: {init} -> {err}");
        assert!(r.control_msgs > 0, "no sampling traffic recorded");
        // every worker pushed every delta to every peer
        assert_eq!(r.update_msgs, 6 * 12 * 5);
    }

    #[test]
    fn asp_works_with_zero_control_traffic() {
        let cfg = P2pConfig {
            n_workers: 4,
            steps_per_worker: 8,
            method: Method::Asp,
            dim: 16,
            seed: 17,
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(16, 19);
        let r = run(&cfg, vec![0.0; 16], grad);
        assert_eq!(r.control_msgs, 0);
        assert_eq!(r.update_msgs, 4 * 8 * 3);
    }

    #[test]
    #[should_panic(expected = "p2p engine cannot host global-view barrier")]
    fn bsp_rejected() {
        let cfg = P2pConfig { method: Method::Bsp, ..P2pConfig::default() };
        let (grad, _) = linear_grad_fn(cfg.dim, 1);
        run(&cfg, vec![0.0; cfg.dim], grad);
    }

    #[test]
    fn pbsp_zero_sample_is_asp() {
        let cfg = P2pConfig {
            n_workers: 4,
            steps_per_worker: 5,
            method: Method::Pbsp { sample: 0 },
            dim: 8,
            seed: 23,
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(8, 29);
        let r = run(&cfg, vec![0.0; 8], grad);
        assert_eq!(r.control_msgs, 0);
    }
}
