//! Peer-to-peer engine — distributed model, distributed states (paper
//! §4.1 cases 2/4): **no global state anywhere**.
//!
//! Every worker holds a model replica and runs its own barrier decision
//! over a β-sample drawn from the structured overlay ([`crate::overlay`]).
//! Only ASP and the PSP family compose with this engine — global-view
//! methods (BSP/SSP) are rejected at construction, which *is* the paper's
//! systems argument: sampling turns barrier control into something each
//! node can execute independently.
//!
//! Mechanics:
//! * model plane ([`Dissemination`]): by default deltas travel the
//!   **gossip plane** ([`crate::engine::gossip`]) — the origin compacts
//!   `flush_every` steps into one sequence-numbered rumor, forwards it to
//!   its ring successor plus `fanout` overlay-sampled shortcuts, and every
//!   node relays fresh rumors once, batching all rumors per link into one
//!   physical message per flush tick. Updates reach all peers in
//!   O(log n) rounds at O(n·fanout) messages per step, applied additively
//!   exactly once (per-origin sequence dedup). `Dissemination::FullMesh`
//!   keeps the legacy O(n²) broadcast for equivalence tests and baselines.
//! * control plane: workers publish their step in a shared atomic table —
//!   the moral equivalent of answering `StepQuery` RPCs instantly — and a
//!   blocked worker re-samples the overlay each poll. Control messages
//!   are accounted as 2 per sampled peer plus overlay routing hops
//!   (self-lookups are local and cost 0), plus the routing the gossip
//!   plane spends picking shortcut targets — what the real RPCs would
//!   cost.
//! * membership plane ([`crate::engine::membership`]): alongside the step
//!   table every worker publishes a heartbeat counter, bumped once per
//!   loop tick — the SWIM-style liveness signal piggybacked on the flush
//!   cadence. Each worker runs its own suspect/confirm timers over the
//!   table and keeps a **local overlay view**: confirming a death evicts
//!   the node from that view (sampling and chain routing skip it) and
//!   triggers the two repair roles — the dead node's ring successor
//!   re-announces its exact rumor count and re-injects its rumors from
//!   the custody store ([`PeerMsg::Repair`], the `Done` the origin never
//!   sent), and any worker whose chain successor died re-sends its full
//!   store to the next live successor, restoring the relay invariant
//!   across the gap. Workers also depart mid-run via [`P2pConfig::churn`]:
//!   gracefully (flush + store handoff + [`PeerMsg::Leave`]) or by
//!   crash-stop (silence).
//! * shutdown: every worker announces `Done` and each peer tracks the
//!   expected senders explicitly. In gossip mode `Done` carries each
//!   origin's exact rumor count, so the drain's exit condition is
//!   **deterministic** — every announced rumor applied — not a timing
//!   heuristic. A crash-stop origin never sends `Done`; the membership
//!   plane excuses it once confirmed dead and substitutes the custodian's
//!   count, so survivors still terminate promptly instead of camping on
//!   `drain_timeout`. The timeout remains as a hang safety net — and then
//!   fails *loudly*: a warning naming the missing peers plus separate
//!   missing-rumor / discarded-message counts in [`EngineReport`], so
//!   repair losses and discard losses stay distinguishable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::actor::System;
use crate::barrier::{AdaptiveConfig, BarrierPolicy, Method, ViewRequirement};
use crate::engine::delta::{CompressConfig, DeltaEncoder, DeltaPayload};
use crate::engine::gossip::{GossipConfig, GossipNode, Rumor};
use crate::engine::membership::{self, FailureDetector, MembershipConfig};
use crate::engine::{BarrierOut, EngineReport, GradFn};
use crate::log_warn;
use crate::overlay::Ring;
use crate::util::rng::Rng;

/// Floor for the drain's blocking wait. Once the deadline is nearer than
/// this, `recv_timeout(left)` would degenerate toward `recv_timeout(0)` —
/// an immediate return, turning the final stretch before the timeout
/// branch into a hot spin. Clamping trades at most one millisecond of
/// deadline overshoot for a paced wait.
pub(crate) const MIN_DRAIN_POLL: Duration = Duration::from_millis(1);

/// Messages between peer workers (model + membership planes).
#[derive(Debug, Clone)]
pub enum PeerMsg {
    /// Full-mesh mode: a model delta from a peer, apply `w += delta`.
    /// Dense or compressed, in whatever form the origin's
    /// [`DeltaEncoder`] produced.
    Delta { delta: DeltaPayload },
    /// Gossip mode: one physical message — every rumor queued for this
    /// link since the sender's last flush (or a repair-plane store
    /// re-send; receivers dedup, so the two are interchangeable).
    Gossip { rumors: Vec<Rumor> },
    /// Finish up: no more *originations* will arrive from `from`, which
    /// emitted exactly `rumors` of them (gossip relays may still follow;
    /// the count is what lets the drain terminate deterministically).
    Done { from: u32, rumors: u32 },
    /// Graceful mid-run departure: like `Done`, but the sender left the
    /// system — receivers also evict it from their overlay views so
    /// sampling and chain routing stop touching it. The leaver hands its
    /// rumor store to its successor itself before announcing.
    Leave { from: u32, rumors: u32 },
    /// Custody repair: the sender — ring successor of the confirmed-dead
    /// `origin` — re-announces the origin's exact announced-rumor count
    /// and re-injects the rumors from its store. Stands in for the `Done`
    /// the origin never sent; doubles as a death notice.
    Repair { origin: u32, rumors: u32, store: Vec<Rumor> },
}

/// How the model plane moves deltas.
#[derive(Debug, Clone)]
pub enum Dissemination {
    /// Every worker pushes every delta to every peer: n·(n-1) messages
    /// per step. Kept as the equivalence/baseline mode.
    FullMesh,
    /// Overlay-routed gossip: O(n·fanout) physical messages per step.
    Gossip(GossipConfig),
}

/// A scripted mid-run departure (crash-fault scenario knob).
#[derive(Debug, Clone)]
pub struct Departure {
    /// Which worker leaves.
    pub worker: usize,
    /// It departs at the top of this step (having completed `at_step`
    /// steps and flushed their rumors).
    pub at_step: u64,
    /// Graceful (flush + store handoff + `Leave` announcement) or
    /// crash-stop (thread simply stops; no handoff, no `Done`).
    pub graceful: bool,
}

/// Engine configuration.
#[derive(Clone)]
pub struct P2pConfig {
    pub n_workers: usize,
    pub steps_per_worker: u64,
    /// Must be ASP or a PSP method (no global view available).
    pub method: Method,
    pub lr: f32,
    pub dim: usize,
    pub seed: u64,
    pub poll: Duration,
    /// Model-plane transport (default: gossip, fanout 2, flush 1, ttl 6).
    pub dissemination: Dissemination,
    /// How long the shutdown drain waits for missing `Done` senders or
    /// missing rumors before giving up loudly. Never reached on a
    /// healthy run — and, with the membership plane on, not on a
    /// crash-faulted run either: confirmed-dead origins are excused and
    /// repaired instead of timed out. Purely a hang safety net.
    pub drain_timeout: Duration,
    /// Crash-fault membership plane (failure detection + rumor repair).
    /// `None` disables detection entirely — a crash-stop peer then stalls
    /// every survivor until `drain_timeout`, the pre-membership failure
    /// mode. On by default.
    pub membership: Option<MembershipConfig>,
    /// Scripted mid-run departures (at most one per worker is honoured).
    pub churn: Vec<Departure>,
    /// Online barrier adaptation (DSSP-style). `None` = static knobs;
    /// the policy then replays the legacy admission decisions exactly.
    /// Each worker adapts its own θ/β locally — no consensus round,
    /// which is the point: it composes with "no global state anywhere".
    pub adaptive: Option<AdaptiveConfig>,
    /// Delta-payload compression ([`crate::engine::delta`]). The
    /// default (`dense`) is bit-identical to the uncompressed engine;
    /// lossy modes ship smaller payloads and carry the dropped mass in
    /// each origin's error-feedback residual.
    pub compress: CompressConfig,
}

impl Default for P2pConfig {
    fn default() -> Self {
        P2pConfig {
            n_workers: 8,
            steps_per_worker: 15,
            method: Method::Pssp { sample: 3, staleness: 2 },
            lr: 0.05,
            dim: 32,
            seed: 2,
            poll: Duration::from_micros(200),
            dissemination: Dissemination::Gossip(GossipConfig::default()),
            drain_timeout: Duration::from_secs(30),
            membership: Some(MembershipConfig::default()),
            churn: Vec::new(),
            adaptive: None,
            compress: CompressConfig::default(),
        }
    }
}

/// What one worker hands back at join time.
struct WorkerOut {
    w: Vec<f32>,
    control_msgs: u64,
    update_msgs: u64,
    applied_rumors: u64,
    dup_rumors: u64,
    rumor_copies: u64,
    dropped_deltas: u64,
    missing_rumors: u64,
    discarded_msgs: u64,
    confirmed_dead: u64,
    repair_msgs: u64,
    repaired_rumors: u64,
    drain_polls: u64,
    departed: bool,
    barrier: BarrierOut,
    payload_bytes: u64,
    fed_back_mass: f64,
}

#[inline]
fn add_delta(w: &mut [f32], delta: &[f32]) {
    for (wi, di) in w.iter_mut().zip(delta) {
        *wi += di;
    }
}

/// Run the p2p engine. Panics if the method needs a global view.
pub fn run(cfg: &P2pConfig, init_w: Vec<f32>, grad_fn: GradFn) -> EngineReport {
    let barrier = cfg.method.build();
    assert!(
        !matches!(barrier.view(), ViewRequirement::Global),
        "p2p engine cannot host global-view barrier {} — use the \
         parameter-server engine (paper §4.1: only ASP/PSP work in case 4)",
        barrier.name()
    );
    let start = Instant::now();
    let sys = System::new();
    let n = cfg.n_workers;
    for d in &cfg.churn {
        // A typo'd departure must fail loudly, not silently run a
        // churn-free scenario the caller believes was crash-tested.
        assert!(
            d.worker < n,
            "departure names worker {} but the engine has only {n} workers",
            d.worker
        );
        assert!(
            d.at_step < cfg.steps_per_worker,
            "departure of worker {} at step {} can never fire: workers run \
             only {} step(s)",
            d.worker,
            d.at_step,
            cfg.steps_per_worker
        );
    }

    // Published step table (the control plane each node exposes) and the
    // heartbeat table (the membership plane's liveness signal).
    let steps: Arc<Vec<AtomicU64>> =
        Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    let beats: Arc<Vec<AtomicU64>> =
        Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    // The structured overlay used for sampling AND gossip routing. Each
    // worker clones its own evolving view from this launch ring.
    let ring = Arc::new(Ring::with_nodes(n, cfg.seed));

    // Build the mesh of addresses first (two-phase: spawn, then wire).
    let mut mailboxes = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = std::sync::mpsc::channel::<PeerMsg>();
        // Raw channel here: actor::Address requires a running body; we
        // need all endpoints before any worker starts.
        mailboxes.push(rx);
        addrs.push(tx);
    }
    let addrs = Arc::new(addrs);

    let workers: Vec<_> = mailboxes
        .into_iter()
        .enumerate()
        .map(|(i, rx)| {
            let grad_fn = grad_fn.clone();
            let steps = Arc::clone(&steps);
            let beats = Arc::clone(&beats);
            let ring = Arc::clone(&ring);
            let addrs = Arc::clone(&addrs);
            let mut w = init_w.clone();
            let cfg = cfg.clone();
            sys.spawn::<(), _, _>(&format!("p2p-{i}"), move |_mb| {
                // The single admission authority for this worker. With
                // `adaptive: None` its decisions are value-identical to
                // the legacy inline per-peer lag check (and it makes the
                // quorum fraction actually bind for pQuorum, which the
                // old inline ∀-window silently ignored).
                let mut policy =
                    BarrierPolicy::with_adaptive(cfg.method, cfg.adaptive);
                // Three independent streams so gradient seeds stay a pure
                // function of (engine seed, worker, step) no matter how
                // many barrier polls or gossip relays interleave.
                let base = cfg.seed ^ (i as u64).wrapping_mul(0xABCD_EF01);
                let mut grad_rng = Rng::new(base);
                let mut ctrl_rng = Rng::new(base ^ 0x0C0_17B0_0C0_17B0);
                let mut gossip_rng = Rng::new(base ^ 0x6055_1900_6055_1900);

                let gossip_cfg = match &cfg.dissemination {
                    Dissemination::Gossip(g) => Some(g.clone()),
                    Dissemination::FullMesh => None,
                };
                // Churn-capable runs retain the rumor store: graceful
                // leavers hand it to their successor, and survivors
                // re-send it across chain gaps / reclaim dead origins'
                // rumors from it. This is the crash-tolerance memory
                // trade: with membership on (the default) every worker
                // pins O(total rumors) of run history, because without
                // acks nobody can prove a rumor will never be needed for
                // repair — set `membership: None` (and no scripted
                // churn) to restore PR 3's store-free fast path.
                let keep_store = gossip_cfg.is_some()
                    && (cfg.membership.is_some() || !cfg.churn.is_empty());
                let mut gnode = gossip_cfg.as_ref().map(|_| {
                    if keep_store {
                        GossipNode::with_handoff_store(i, n)
                    } else {
                        GossipNode::new(i, n)
                    }
                });
                // Origin-side delta compaction buffer (gossip mode).
                let mut pending = vec![0.0f32; cfg.dim];
                let mut pending_steps = 0u64;
                // Every origination funnels through this encoder: dense
                // mode passes the buffer through untouched; lossy modes
                // sparsify/quantize and keep the dropped mass as the
                // error-feedback residual for the next origination.
                let mut encoder = DeltaEncoder::new(cfg.compress, cfg.dim);

                // This worker's evolving overlay view: the launch ring
                // minus evicted (departed or confirmed-dead) nodes.
                let mut view: Ring = (*ring).clone();
                let t0 = Instant::now();
                let mut detector = cfg
                    .membership
                    .as_ref()
                    .map(|mc| FailureDetector::new(i, n, 0, mc.clone()));
                // Observation passes are throttled to a fraction of the
                // suspect threshold — beats are written every tick, but
                // scanning n counters every 200µs poll would be waste.
                let detect_every = cfg
                    .membership
                    .as_ref()
                    .map(|mc| (mc.suspect_after / 4).clamp(1, 50_000))
                    .unwrap_or(u64::MAX);
                let mut next_detect = 0u64;

                let mut control_msgs = 0u64;
                let mut update_msgs = 0u64;
                let mut repair_msgs = 0u64;
                let mut repaired_rumors = 0u64;
                let mut confirmed_dead = 0u64;
                let mut done = vec![false; n];
                done[i] = true;
                // Per-origin rumor counts announced by Done/Leave/Repair;
                // the drain exits when every announced rumor is applied.
                let mut expected = vec![0u32; n];
                // Origins we confirmed dead ourselves and whose custody
                // announcement we are still owed — the drain must not
                // exit before the custodian's count arrives (we cannot
                // know how many rumors we are missing until it does).
                let mut repair_pending = vec![false; n];

                // Evict `$dead` from this worker's overlay view and carry
                // out the repair duties the eviction assigns. Custody is
                // suppressed (`$may_take_custody = false`) when the death
                // notice came from an existing custodian or the node left
                // gracefully (it announced its own count).
                macro_rules! evict {
                    ($dead:expr, $may_take_custody:expr) => {
                        let may_take_custody: bool = $may_take_custody;
                        let evicted = membership::evict_from_view(&mut view, i, $dead);
                        if evicted.is_none() {
                            // Already out of the view (e.g. re-confirmed
                            // after a resurrection raced a Leave): nothing
                            // to repair, so nothing to hold the drain for.
                            repair_pending[$dead] = false;
                        }
                        if let Some(out) = evicted {
                            if may_take_custody && out.custodian {
                                if let Some(node) = gnode.as_ref() {
                                    // Custody repair: the dead origin's
                                    // flushes hit us first, so our count
                                    // is exactly what it ever announced.
                                    let origin = $dead as u32;
                                    let count = node.applied_count(origin);
                                    expected[$dead] = expected[$dead].max(count);
                                    repair_pending[$dead] = false;
                                    let store = node.rumors_of(origin);
                                    // Every peer gets the announcement —
                                    // including Done-but-still-draining
                                    // ones, whose own exit waits on this
                                    // count. Sends into already-exited
                                    // mailboxes fail harmlessly.
                                    for (j, addr) in addrs.iter().enumerate() {
                                        if j != i && j != $dead {
                                            let sent = addr.send(PeerMsg::Repair {
                                                origin,
                                                rumors: count,
                                                store: store.clone(),
                                            });
                                            if sent.is_ok() {
                                                repair_msgs += 1;
                                            }
                                        }
                                    }
                                }
                            }
                            if let (Some(node), Some(succ)) =
                                (gnode.as_ref(), out.lost_successor)
                            {
                                // Successor repair: everything we ever
                                // applied goes to the node now clockwise
                                // of the gap; it dedups and relays the
                                // fresh remainder, restoring the chain's
                                // relay invariant.
                                let store = node.handoff_rumors();
                                if !store.is_empty()
                                    && addrs[succ]
                                        .send(PeerMsg::Gossip { rumors: store })
                                        .is_ok()
                                {
                                    repair_msgs += 1;
                                    update_msgs += 1;
                                }
                            }
                        }
                    };
                }

                // One membership tick: publish our own liveness, and (at
                // the throttled cadence) run the suspect/confirm timers
                // over everyone else's.
                macro_rules! membership_tick {
                    () => {
                        beats[i].fetch_add(1, Ordering::Relaxed);
                        if let Some(det) = detector.as_mut() {
                            let now = t0.elapsed().as_micros() as u64;
                            if now >= next_detect {
                                next_detect = now + detect_every;
                                let obs = det.observe(
                                    now,
                                    |j| beats[j].load(Ordering::Acquire),
                                    |j| done[j],
                                );
                                for d in obs.dead {
                                    confirmed_dead += 1;
                                    // Until a custodian announces the dead
                                    // origin's count we do not know what
                                    // we are owed — hold the drain open.
                                    repair_pending[d] = gnode.is_some() && !done[d];
                                    evict!(d, true);
                                }
                                for r in obs.resurrected {
                                    // False positive: restore the ring
                                    // position, and if the revived peer is
                                    // our successor again it missed every
                                    // chain flush we routed around it —
                                    // re-send the store.
                                    view.join(r);
                                    if view.successor_node(i) == Some(r) {
                                        if let Some(node) = gnode.as_ref() {
                                            let store = node.handoff_rumors();
                                            if !store.is_empty()
                                                && addrs[r]
                                                    .send(PeerMsg::Gossip { rumors: store })
                                                    .is_ok()
                                            {
                                                repair_msgs += 1;
                                                update_msgs += 1;
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    };
                }

                // One flush tick: relay the fresh-rumor buffer — one
                // physical message per destination (successor + sampled
                // partners), no matter how many rumors ride along.
                // Destinations come from the *local* view, so confirmed-
                // dead and departed nodes stop receiving chain traffic.
                // A send into a crashed peer's dropped mailbox fails; the
                // payload is not lost — it stays in our store and rides
                // the successor-repair re-send once the death confirms.
                macro_rules! flush_gossip {
                    () => {
                        if let (Some(node), Some(gc)) =
                            (gnode.as_mut(), gossip_cfg.as_ref())
                        {
                            for (dest, rumors) in
                                node.flush(gc, &view, &mut gossip_rng)
                            {
                                update_msgs += 1;
                                let _ = addrs[dest].send(PeerMsg::Gossip { rumors });
                            }
                        }
                    };
                }
                // Handle one inbound message (shared by step loop, waits
                // and the shutdown drain).
                macro_rules! process {
                    ($msg:expr) => {
                        match $msg {
                            PeerMsg::Delta { delta } => delta.apply_into(&mut w),
                            PeerMsg::Gossip { rumors } => {
                                let node = gnode.as_mut().expect(
                                    "gossip message on a full-mesh plane",
                                );
                                node.receive(rumors, |r| r.delta.apply_into(&mut w));
                            }
                            PeerMsg::Done { from, rumors } => {
                                let from = from as usize;
                                done[from] = true;
                                expected[from] = rumors;
                                repair_pending[from] = false;
                                if let Some(det) = detector.as_mut() {
                                    let now = t0.elapsed().as_micros() as u64;
                                    if det.alive(from, now) {
                                        // Our confirmation was a false
                                        // positive — the peer finished
                                        // normally. Restore its position,
                                        // and (as on the observe-path
                                        // resurrection) re-seed its chain
                                        // edge: it missed every flush we
                                        // routed around it, and its own
                                        // drain still needs those rumors.
                                        view.join(from);
                                        if view.successor_node(i) == Some(from) {
                                            if let Some(node) = gnode.as_ref() {
                                                let store = node.handoff_rumors();
                                                if !store.is_empty()
                                                    && addrs[from]
                                                        .send(PeerMsg::Gossip { rumors: store })
                                                        .is_ok()
                                                {
                                                    repair_msgs += 1;
                                                    update_msgs += 1;
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                            PeerMsg::Leave { from, rumors } => {
                                let from = from as usize;
                                done[from] = true;
                                expected[from] = rumors;
                                repair_pending[from] = false;
                                // The leaver handed its store to its
                                // successor itself; we only repair our own
                                // chain edge if we owned it.
                                evict!(from, false);
                            }
                            PeerMsg::Repair { origin, rumors, store } => {
                                let o = origin as usize;
                                expected[o] = expected[o].max(rumors);
                                repair_pending[o] = false;
                                // A custody announcement doubles as a
                                // death notice: evict without waiting for
                                // our own timers (no second custody take —
                                // the sender already claimed it).
                                if let Some(det) = detector.as_mut() {
                                    if det.declare_dead(o) {
                                        evict!(o, false);
                                    }
                                }
                                if let Some(node) = gnode.as_mut() {
                                    node.receive(store, |r| {
                                        repaired_rumors += 1;
                                        r.delta.apply_into(&mut w);
                                    });
                                }
                            }
                        }
                    };
                }

                let my_departure = cfg.churn.iter().find(|d| d.worker == i).cloned();
                let mut departed = false;

                for step in 0..cfg.steps_per_worker {
                    let step_t0 = Instant::now();
                    if let Some(dep) = &my_departure {
                        if step >= dep.at_step {
                            departed = true;
                            if dep.graceful {
                                // Graceful leave: compact and announce any
                                // buffered deltas, flush, hand the full
                                // store to the successor, say goodbye.
                                while let Ok(msg) = rx.try_recv() {
                                    process!(msg);
                                }
                                if let (Some(node), Some(gc)) =
                                    (gnode.as_mut(), gossip_cfg.as_ref())
                                {
                                    if pending_steps > 0 {
                                        let payload = encoder.encode(std::mem::replace(
                                            &mut pending,
                                            vec![0.0; cfg.dim],
                                        ));
                                        pending_steps = 0;
                                        node.originate(payload, gc);
                                    }
                                }
                                flush_gossip!();
                                let own = gnode
                                    .as_ref()
                                    .map(|nd| nd.originated())
                                    .unwrap_or(0);
                                if let Some(node) = gnode.as_ref() {
                                    if let Some(succ) = view.successor_node(i) {
                                        let store = node.handoff_rumors();
                                        if !store.is_empty() {
                                            update_msgs += 1;
                                            let _ = addrs[succ]
                                                .send(PeerMsg::Gossip { rumors: store });
                                        }
                                    }
                                }
                                for (j, addr) in addrs.iter().enumerate() {
                                    if j != i {
                                        let _ = addr.send(PeerMsg::Leave {
                                            from: i as u32,
                                            rumors: own,
                                        });
                                    }
                                }
                            }
                            // Crash-stop: no flush, no handoff, no Done —
                            // dropping the mailbox is the silence the
                            // survivors must detect and repair around.
                            break;
                        }
                    }
                    // Drain before detecting: a confirmation must never be
                    // based on older knowledge than the mailbox holds — a
                    // custodian that confirmed with the dead origin's
                    // final flush still queued would broadcast an
                    // undercounted Repair.
                    while let Ok(msg) = rx.try_recv() {
                        process!(msg);
                    }
                    membership_tick!();
                    // compute locally, apply locally
                    let g = grad_fn(&w, grad_rng.next_u64());
                    let delta: Vec<f32> = g.iter().map(|x| -cfg.lr * x).collect();
                    add_delta(&mut w, &delta);
                    match &cfg.dissemination {
                        Dissemination::FullMesh => {
                            // push the delta to all peers (model plane); a
                            // send fails only into a departed peer's
                            // dropped mailbox, and a departed peer applies
                            // no further updates anyway. One encode per
                            // step; every peer gets the same payload (the
                            // local replica keeps the exact delta).
                            let payload = encoder.encode(delta);
                            for (j, addr) in addrs.iter().enumerate() {
                                if j != i {
                                    update_msgs += 1;
                                    let _ = addr
                                        .send(PeerMsg::Delta { delta: payload.clone() });
                                }
                            }
                        }
                        Dissemination::Gossip(gc) => {
                            add_delta(&mut pending, &delta);
                            pending_steps += 1;
                            let last = step + 1 == cfg.steps_per_worker;
                            if pending_steps >= gc.flush_every || last {
                                let payload = encoder.encode(std::mem::replace(
                                    &mut pending,
                                    vec![0.0; cfg.dim],
                                ));
                                pending_steps = 0;
                                gnode.as_mut().unwrap().originate(payload, gc);
                            }
                            // relays + originations leave every step
                            flush_gossip!();
                        }
                    }
                    steps[i].store(step + 1, Ordering::Release);
                    if step + 1 == cfg.steps_per_worker {
                        break;
                    }
                    // fully-distributed barrier: sample the overlay view
                    // (evicted nodes are invisible, so a dead straggler
                    // stops poisoning samples the moment it is confirmed)
                    let entered = Instant::now();
                    loop {
                        // Re-read the view each attempt: under adaptation
                        // β can change between polls of the same crossing.
                        let (pass, lag) = match policy.view() {
                            ViewRequirement::None => (true, None),
                            ViewRequirement::Sample(beta) => {
                                let (peers, hops) =
                                    view.sample_nodes(i, beta, &mut ctrl_rng);
                                control_msgs += hops + 2 * peers.len() as u64;
                                let sampled: Vec<u64> = peers
                                    .iter()
                                    .map(|&p| steps[p].load(Ordering::Acquire))
                                    .collect();
                                let lag = sampled
                                    .iter()
                                    .min()
                                    .map(|&m| (step + 1).saturating_sub(m));
                                (policy.admit_view(step + 1, &sampled), lag)
                            }
                            ViewRequirement::Global => unreachable!(),
                        };
                        policy.record_decision(pass, lag);
                        if pass {
                            break;
                        }
                        while let Ok(msg) = rx.try_recv() {
                            process!(msg);
                        }
                        // keep relaying while blocked so peers' deltas
                        // are not parked in our outbox
                        flush_gossip!();
                        membership_tick!();
                        std::thread::sleep(cfg.poll);
                    }
                    policy.record_crossing(
                        entered.elapsed().as_secs_f64(),
                        entered.duration_since(step_t0).as_secs_f64(),
                    );
                }

                let mut dropped_deltas = 0u64;
                let mut missing_total = 0u64;
                let mut discarded_total = 0u64;
                let mut drain_polls = 0u64;
                if !departed {
                    // Signal completion (no more originations from us)
                    // with our exact origination count, then drain until
                    // every origin is accounted for — by its Done/Leave,
                    // or by a confirmed death plus the custodian's
                    // count — and every announced rumor is applied.
                    let own_rumors =
                        gnode.as_ref().map(|nd| nd.originated()).unwrap_or(0);
                    expected[i] = own_rumors;
                    for (j, addr) in addrs.iter().enumerate() {
                        if j != i {
                            let _ = addr.send(PeerMsg::Done {
                                from: i as u32,
                                rumors: own_rumors,
                            });
                        }
                    }
                    let deadline = Instant::now() + cfg.drain_timeout;
                    // Shorter waits when the detector is on: the drain is
                    // where crash confirmation usually lands, so it must
                    // wake often enough to run the timers.
                    let drain_wait = if detector.is_some() {
                        Duration::from_millis(20)
                    } else {
                        Duration::from_millis(100)
                    };
                    // Ingest the whole backlog before relaying, then pace
                    // the next tick at the poll interval: batching stays
                    // dense and relay traffic settles into synchronous-
                    // like rounds instead of one flush per message.
                    macro_rules! ingest_backlog_and_relay {
                        ($first:expr) => {{
                            process!($first);
                            while let Ok(m) = rx.try_recv() {
                                process!(m);
                            }
                            flush_gossip!();
                            std::thread::sleep(cfg.poll);
                        }};
                    }
                    // Exact exit condition — no quiet-window guesswork:
                    // * full mesh: every peer Done, departed, or confirmed
                    //   dead (per-sender FIFO: a peer's Done follows all
                    //   its deltas);
                    // * gossip: the same, AND every announced rumor
                    //   applied, AND no confirmed death still awaiting its
                    //   custodian's count. Liveness is structural: a live
                    //   peer exits only after relaying everything it
                    //   applied, and chain gaps left by the dead are
                    //   re-sent around by their ring neighbours.
                    macro_rules! drain_complete {
                        () => {{
                            (0..n).all(|j| {
                                done[j]
                                    || detector
                                        .as_ref()
                                        .is_some_and(|d| d.is_dead(j))
                            }) && repair_pending.iter().all(|&p| !p)
                                && match &gnode {
                                    None => true,
                                    Some(node) => (0..n).all(|j| {
                                        node.applied_count(j as u32) >= expected[j]
                                    }),
                                }
                        }};
                    }
                    loop {
                        // Iteration count surfaced in EngineReport: the
                        // no-busy-wait assertion in tests/membership_crash
                        // bounds it by drain_timeout / MIN_DRAIN_POLL.
                        drain_polls += 1;
                        // Same order as the step loop: ingest the whole
                        // backlog (and relay it) before the detector may
                        // confirm anything, so custody counts always
                        // include every flush the dead origin ever
                        // delivered here.
                        while let Ok(m) = rx.try_recv() {
                            process!(m);
                        }
                        flush_gossip!();
                        membership_tick!();
                        if drain_complete!() {
                            let excused = (0..n).any(|j| !done[j]);
                            if excused && detector.is_some() {
                                // About to exit on a death excuse: run one
                                // ungated observation first — a heartbeat
                                // since the last throttled pass disproves
                                // the confirmation, and the drain must
                                // keep waiting for the real Done.
                                next_detect = 0;
                                membership_tick!();
                                if drain_complete!() {
                                    break;
                                }
                            } else {
                                break;
                            }
                        }
                        let left = deadline.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            // Loud failure: name the silent peers / missing
                            // rumors and count exactly what this timeout
                            // discards, keeping the two loss kinds apart
                            // (repair failures vs queue discards).
                            let missing_done: Vec<usize> = (0..n)
                                .filter(|&j| {
                                    !done[j]
                                        && !detector
                                            .as_ref()
                                            .is_some_and(|d| d.is_dead(j))
                                })
                                .collect();
                            let missing_rumors: u64 = match &gnode {
                                None => 0,
                                Some(node) => (0..n)
                                    .map(|j| {
                                        u64::from(expected[j]).saturating_sub(
                                            u64::from(node.applied_count(j as u32)),
                                        )
                                    })
                                    .sum(),
                            };
                            let mut discarded = 0u64;
                            while let Ok(msg) = rx.try_recv() {
                                match msg {
                                    PeerMsg::Delta { .. } => discarded += 1,
                                    PeerMsg::Gossip { rumors }
                                    | PeerMsg::Repair { store: rumors, .. } => {
                                        discarded += rumors.len() as u64
                                    }
                                    PeerMsg::Done { from, rumors }
                                    | PeerMsg::Leave { from, rumors } => {
                                        done[from as usize] = true;
                                        expected[from as usize] = rumors;
                                    }
                                }
                            }
                            missing_total = missing_rumors;
                            discarded_total = discarded;
                            dropped_deltas = missing_rumors.max(discarded);
                            log_warn!(
                                "p2p-{i}: drain timed out after {:?} (no Done from \
                                 {missing_done:?}; {missing_rumors} expected rumor(s) \
                                 never arrived; {discarded} queued message(s) \
                                 discarded) — the reported replica is missing updates",
                                cfg.drain_timeout
                            );
                            break;
                        }
                        // Clamp below by MIN_DRAIN_POLL: as the deadline
                        // approaches, `left` saturates toward zero and an
                        // unclamped recv_timeout(≈0) spins hot until the
                        // timeout branch fires.
                        if let Ok(msg) =
                            rx.recv_timeout(left.min(drain_wait).max(MIN_DRAIN_POLL))
                        {
                            ingest_backlog_and_relay!(msg);
                        }
                    }
                }

                let (applied_rumors, dup_rumors, rumor_copies, route_msgs) =
                    match &gnode {
                        Some(nd) => (
                            nd.applied_rumors,
                            nd.dup_rumors,
                            nd.rumor_copies,
                            nd.route_msgs,
                        ),
                        None => (0, 0, 0, 0),
                    };
                WorkerOut {
                    w,
                    control_msgs: control_msgs + route_msgs,
                    update_msgs,
                    applied_rumors,
                    dup_rumors,
                    rumor_copies,
                    dropped_deltas,
                    missing_rumors: missing_total,
                    discarded_msgs: discarded_total,
                    confirmed_dead,
                    repair_msgs,
                    repaired_rumors,
                    drain_polls,
                    departed,
                    barrier: BarrierOut::of(&policy),
                    payload_bytes: encoder.payload_bytes,
                    fed_back_mass: encoder.fed_back_mass,
                }
            })
        })
        .collect();

    let mut report = EngineReport::default();
    report.compress_mode = cfg.compress.mode_str();
    let mut replicas: Vec<Vec<f32>> = Vec::with_capacity(n);
    for (i, wk) in workers.into_iter().enumerate() {
        let (addr, handle) = wk.into_parts();
        drop(addr);
        let out = handle.join().expect("p2p worker panicked");
        report.control_msgs += out.control_msgs;
        report.update_msgs += out.update_msgs;
        report.applied_rumors += out.applied_rumors;
        report.dup_rumors += out.dup_rumors;
        report.rumor_copies += out.rumor_copies;
        report.dropped_deltas += out.dropped_deltas;
        report.missing_rumors += out.missing_rumors;
        report.discarded_msgs += out.discarded_msgs;
        report.confirmed_dead += out.confirmed_dead;
        report.repair_msgs += out.repair_msgs;
        report.repaired_rumors += out.repaired_rumors;
        report.drain_polls += out.drain_polls;
        report.payload_bytes += out.payload_bytes;
        report.fed_back_mass += out.fed_back_mass;
        report.barrier_waits += out.barrier.waits;
        report.stall_ticks += out.barrier.ticks;
        report.eff_staleness.push(out.barrier.eff_staleness);
        report.eff_sample.push(out.barrier.eff_sample);
        if out.departed {
            report.departed.push(i);
        }
        replicas.push(out.w);
    }

    report.steps = steps.iter().map(|s| s.load(Ordering::Acquire)).collect();
    report.wall_secs = start.elapsed().as_secs_f64();
    // The headline model comes from a worker that saw the run through.
    let first_live = (0..n).find(|j| !report.departed.contains(j)).unwrap_or(0);
    report.model = replicas.get(first_live).cloned().unwrap_or_default();
    report.replicas = replicas;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::linear::{Dataset, LinearModel};
    use crate::util::stats::l2_dist;
    use std::sync::Mutex;

    fn linear_grad_fn(dim: usize, seed: u64) -> (GradFn, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let data = Dataset::synthetic(512, dim, 0.05, &mut rng);
        let w_true = data.w_true.clone();
        let model = Mutex::new(LinearModel::new(dim));
        let f: GradFn = Arc::new(move |w, s| {
            model.lock().unwrap().minibatch_grad(&data, w, s, 32).to_vec()
        });
        (f, w_true)
    }

    #[test]
    fn pssp_converges_fully_distributed_over_gossip() {
        let cfg = P2pConfig {
            n_workers: 6,
            steps_per_worker: 12,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 24,
            lr: 0.02,
            seed: 11,
            ..P2pConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 13);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        assert!(r.steps.iter().all(|&s| s == 12));
        let init = l2_dist(&vec![0.0; 24], &w_true);
        let err = l2_dist(&r.model, &w_true);
        assert!(err < init, "p2p did not reduce error: {init} -> {err}");
        assert!(r.control_msgs > 0, "no sampling traffic recorded");
        // the gossip plane must beat the full mesh on physical messages
        // even at n=6 (mesh would be 6·12·5 = 360)
        assert!(r.update_msgs > 0);
        assert_eq!(r.dropped_deltas, 0, "no deltas may be dropped");
        assert_eq!(r.missing_rumors, 0);
        assert_eq!(r.discarded_msgs, 0);
        assert_eq!(r.replicas.len(), 6);
        // no churn: the membership plane confirms nothing and repairs
        // nothing, it only watches
        assert_eq!(r.confirmed_dead, 0);
        assert_eq!(r.repair_msgs, 0);
        assert!(r.departed.is_empty());
    }

    #[test]
    fn full_mesh_mode_counts_n_squared_pushes() {
        let cfg = P2pConfig {
            n_workers: 6,
            steps_per_worker: 12,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 24,
            lr: 0.02,
            seed: 11,
            dissemination: Dissemination::FullMesh,
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(cfg.dim, 13);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        // every worker pushed every delta to every peer
        assert_eq!(r.update_msgs, 6 * 12 * 5);
        assert_eq!(r.applied_rumors, 0);
        assert_eq!(r.dropped_deltas, 0);
    }

    #[test]
    fn asp_full_mesh_has_zero_control_traffic() {
        let cfg = P2pConfig {
            n_workers: 4,
            steps_per_worker: 8,
            method: Method::Asp,
            dim: 16,
            seed: 17,
            dissemination: Dissemination::FullMesh,
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(16, 19);
        let r = run(&cfg, vec![0.0; 16], grad);
        assert_eq!(r.control_msgs, 0);
        assert_eq!(r.update_msgs, 4 * 8 * 3);
    }

    #[test]
    fn asp_gossip_spends_routing_not_barrier_traffic() {
        let cfg = P2pConfig {
            n_workers: 6,
            steps_per_worker: 8,
            method: Method::Asp,
            dim: 16,
            seed: 17,
            dissemination: Dissemination::Gossip(GossipConfig {
                fanout: 2,
                flush_every: 1,
                ttl: 4,
            }),
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(16, 19);
        let r = run(&cfg, vec![0.0; 16], grad);
        // ASP never samples for barriers, but gossip target selection
        // routes over the overlay — that traffic is control-plane cost.
        assert!(r.control_msgs > 0);
        assert!(r.rumor_copies >= r.applied_rumors);
    }

    #[test]
    #[should_panic(expected = "p2p engine cannot host global-view barrier")]
    fn bsp_rejected() {
        let cfg = P2pConfig { method: Method::Bsp, ..P2pConfig::default() };
        let (grad, _) = linear_grad_fn(cfg.dim, 1);
        run(&cfg, vec![0.0; cfg.dim], grad);
    }

    #[test]
    fn pbsp_zero_sample_is_asp() {
        let cfg = P2pConfig {
            n_workers: 4,
            steps_per_worker: 5,
            method: Method::Pbsp { sample: 0 },
            dim: 8,
            seed: 23,
            dissemination: Dissemination::FullMesh,
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(8, 29);
        let r = run(&cfg, vec![0.0; 8], grad);
        assert_eq!(r.control_msgs, 0);
    }

    #[test]
    fn flush_interval_compacts_originations() {
        let cfg = P2pConfig {
            n_workers: 5,
            steps_per_worker: 8,
            method: Method::Asp,
            dim: 8,
            seed: 31,
            dissemination: Dissemination::Gossip(GossipConfig {
                fanout: 1,
                flush_every: 4,
                ttl: 8,
            }),
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(8, 37);
        let r = run(&cfg, vec![0.0; 8], grad);
        // 8 steps at flush 4 → 2 rumors per origin; each of the other 4
        // workers applies each exactly once when dissemination completes.
        assert_eq!(r.dropped_deltas, 0);
        assert_eq!(r.applied_rumors, 5 * 2 * 4);
        assert_eq!(r.steps, vec![8; 5]);
    }

    #[test]
    fn graceful_leave_mid_run_drains_without_timeout() {
        // Worker 2 leaves gracefully at step 3 of 10: it hands its store
        // to its successor and announces Leave, so survivors finish and
        // drain with zero drops — and nobody waits on drain_timeout.
        let cfg = P2pConfig {
            n_workers: 5,
            steps_per_worker: 10,
            method: Method::Pssp { sample: 2, staleness: 3 },
            dim: 12,
            lr: 0.02,
            seed: 41,
            churn: vec![Departure { worker: 2, at_step: 3, graceful: true }],
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(cfg.dim, 43);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        assert_eq!(r.departed, vec![2]);
        assert_eq!(r.steps[2], 3);
        for j in [0usize, 1, 3, 4] {
            assert_eq!(r.steps[j], 10, "survivor {j} did not finish");
        }
        assert_eq!(r.dropped_deltas, 0);
        assert_eq!(r.missing_rumors, 0);
        // graceful: announced via Leave, nothing for the detector to do
        assert_eq!(r.confirmed_dead, 0);
        assert!(
            r.wall_secs < cfg.drain_timeout.as_secs_f64() / 3.0,
            "drain stalled: {}s",
            r.wall_secs
        );
    }

    #[test]
    fn topk_compression_cuts_payload_bytes_and_still_converges() {
        let base = P2pConfig {
            n_workers: 6,
            steps_per_worker: 12,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 24,
            lr: 0.02,
            seed: 11,
            ..P2pConfig::default()
        };
        let topk = P2pConfig {
            compress: CompressConfig::parse("topk", 3, "i8").unwrap(),
            ..base.clone()
        };
        let (grad, w_true) = linear_grad_fn(24, 13);
        let d = run(&base, vec![0.0; 24], grad.clone());
        let c = run(&topk, vec![0.0; 24], grad);
        assert_eq!(d.compress_mode, "dense");
        assert_eq!(c.compress_mode, "topk");
        // Dense never touches the residual; top-k must have fed back.
        assert_eq!(d.fed_back_mass, 0.0);
        assert!(c.fed_back_mass > 0.0);
        // k=3 of 24 coords: 33-byte payloads vs 101-byte dense.
        assert!(d.payload_bytes > 0);
        assert!(
            2 * c.payload_bytes < d.payload_bytes,
            "top-k did not compress: {} vs dense {}",
            c.payload_bytes,
            d.payload_bytes
        );
        // Error feedback keeps the compressed run training.
        let init = l2_dist(&vec![0.0; 24], &w_true);
        let err = l2_dist(&c.model, &w_true);
        assert!(err < init, "compressed p2p diverged: {init} -> {err}");
        assert_eq!(c.dropped_deltas, 0);
    }

    #[test]
    fn membership_disabled_without_churn_changes_nothing() {
        let mk = |membership| P2pConfig {
            n_workers: 5,
            steps_per_worker: 6,
            method: Method::Asp,
            dim: 8,
            lr: 0.5,
            seed: 53,
            membership,
            ..P2pConfig::default()
        };
        // Dyadic, model-independent gradients: replicas are exactly the
        // delta sum, so both runs must agree bitwise.
        let grad: GradFn = Arc::new(|_w, seed| {
            (0..8).map(|j| (((seed ^ j as u64) % 9) as f32 - 4.0) * 0.25).collect()
        });
        let with = run(&mk(Some(MembershipConfig::default())), vec![0.0; 8], grad.clone());
        let without = run(&mk(None), vec![0.0; 8], grad);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for (a, b) in with.replicas.iter().zip(&without.replicas) {
            assert_eq!(bits(a), bits(b));
        }
        assert_eq!(with.applied_rumors, without.applied_rumors);
        assert_eq!(with.confirmed_dead, 0);
        assert_eq!(with.repair_msgs, 0);
        assert_eq!(with.repaired_rumors, 0);
    }
}
