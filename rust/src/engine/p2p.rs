//! Peer-to-peer engine — distributed model, distributed states (paper
//! §4.1 cases 2/4): **no global state anywhere**.
//!
//! Every worker holds a model replica and runs its own barrier decision
//! over a β-sample drawn from the structured overlay ([`crate::overlay`]).
//! Only ASP and the PSP family compose with this engine — global-view
//! methods (BSP/SSP) are rejected at construction, which *is* the paper's
//! systems argument: sampling turns barrier control into something each
//! node can execute independently.
//!
//! Mechanics:
//! * model plane ([`Dissemination`]): by default deltas travel the
//!   **gossip plane** ([`crate::engine::gossip`]) — the origin compacts
//!   `flush_every` steps into one sequence-numbered rumor, forwards it to
//!   its ring successor plus `fanout` overlay-sampled shortcuts, and every
//!   node relays fresh rumors once, batching all rumors per link into one
//!   physical message per flush tick. Updates reach all peers in
//!   O(log n) rounds at O(n·fanout) messages per step, applied additively
//!   exactly once (per-origin sequence dedup). `Dissemination::FullMesh`
//!   keeps the legacy O(n²) broadcast for equivalence tests and baselines.
//! * control plane: workers publish their step in a shared atomic table —
//!   the moral equivalent of answering `StepQuery` RPCs instantly — and a
//!   blocked worker re-samples the overlay each poll. Control messages
//!   are accounted as 2 per sampled peer plus overlay routing hops
//!   (self-lookups are local and cost 0), plus the routing the gossip
//!   plane spends picking shortcut targets — what the real RPCs would
//!   cost.
//! * shutdown: every worker announces `Done` and each peer tracks the
//!   expected senders explicitly. The drain only gives up after
//!   `drain_timeout` — and then *loudly*: a warning naming the missing
//!   peers plus a dropped-delta count in [`EngineReport`], instead of the
//!   old silent 5-second discard. In gossip mode `Done` carries each
//!   origin's exact rumor count, so the drain's exit condition is
//!   **deterministic** — every announced rumor applied — not a timing
//!   heuristic; a worker therefore never exits while it is still owed
//!   deltas, and a failed send can only ever carry duplicates (the
//!   structural-completeness argument is exercised by
//!   `tests/gossip_dissemination.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::actor::System;
use crate::barrier::{Method, ViewRequirement};
use crate::engine::gossip::{GossipConfig, GossipNode, Rumor};
use crate::engine::{EngineReport, GradFn};
use crate::log_warn;
use crate::overlay::Ring;
use crate::util::rng::Rng;

/// Messages between peer workers (model plane).
pub enum PeerMsg {
    /// Full-mesh mode: a model delta from a peer, apply `w += delta`.
    Delta { delta: Vec<f32> },
    /// Gossip mode: one physical message — every rumor queued for this
    /// link since the sender's last flush.
    Gossip { rumors: Vec<Rumor> },
    /// Finish up: no more *originations* will arrive from `from`, which
    /// emitted exactly `rumors` of them (gossip relays may still follow;
    /// the count is what lets the drain terminate deterministically).
    Done { from: u32, rumors: u32 },
}

/// How the model plane moves deltas.
#[derive(Debug, Clone)]
pub enum Dissemination {
    /// Every worker pushes every delta to every peer: n·(n-1) messages
    /// per step. Kept as the equivalence/baseline mode.
    FullMesh,
    /// Overlay-routed gossip: O(n·fanout) physical messages per step.
    Gossip(GossipConfig),
}

/// Engine configuration.
#[derive(Clone)]
pub struct P2pConfig {
    pub n_workers: usize,
    pub steps_per_worker: u64,
    /// Must be ASP or a PSP method (no global view available).
    pub method: Method,
    pub lr: f32,
    pub dim: usize,
    pub seed: u64,
    pub poll: Duration,
    /// Model-plane transport (default: gossip, fanout 2, flush 1, ttl 6).
    pub dissemination: Dissemination,
    /// How long the shutdown drain waits for missing `Done` senders or
    /// missing rumors before giving up loudly. Never reached on a
    /// healthy run: the drain's exit condition is exact (every expected
    /// rumor applied), so this is purely a hang safety net.
    pub drain_timeout: Duration,
}

impl Default for P2pConfig {
    fn default() -> Self {
        P2pConfig {
            n_workers: 8,
            steps_per_worker: 15,
            method: Method::Pssp { sample: 3, staleness: 2 },
            lr: 0.05,
            dim: 32,
            seed: 2,
            poll: Duration::from_micros(200),
            dissemination: Dissemination::Gossip(GossipConfig::default()),
            drain_timeout: Duration::from_secs(30),
        }
    }
}

/// What one worker hands back at join time.
struct WorkerOut {
    w: Vec<f32>,
    control_msgs: u64,
    update_msgs: u64,
    applied_rumors: u64,
    dup_rumors: u64,
    rumor_copies: u64,
    dropped_deltas: u64,
}

#[inline]
fn add_delta(w: &mut [f32], delta: &[f32]) {
    for (wi, di) in w.iter_mut().zip(delta) {
        *wi += di;
    }
}

/// Run the p2p engine. Panics if the method needs a global view.
pub fn run(cfg: &P2pConfig, init_w: Vec<f32>, grad_fn: GradFn) -> EngineReport {
    let barrier = cfg.method.build();
    assert!(
        !matches!(barrier.view(), ViewRequirement::Global),
        "p2p engine cannot host global-view barrier {} — use the \
         parameter-server engine (paper §4.1: only ASP/PSP work in case 4)",
        barrier.name()
    );
    let staleness = barrier.staleness();
    let start = Instant::now();
    let sys = System::new();
    let n = cfg.n_workers;

    // Published step table (the control plane each node exposes).
    let steps: Arc<Vec<AtomicU64>> =
        Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    // The structured overlay used for sampling AND gossip routing.
    let ring = Arc::new(Ring::with_nodes(n, cfg.seed));

    // Build the mesh of addresses first (two-phase: spawn, then wire).
    let mut mailboxes = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = std::sync::mpsc::channel::<PeerMsg>();
        // Raw channel here: actor::Address requires a running body; we
        // need all endpoints before any worker starts.
        mailboxes.push(rx);
        addrs.push(tx);
    }
    let addrs = Arc::new(addrs);

    let workers: Vec<_> = mailboxes
        .into_iter()
        .enumerate()
        .map(|(i, rx)| {
            let grad_fn = grad_fn.clone();
            let steps = Arc::clone(&steps);
            let ring = Arc::clone(&ring);
            let addrs = Arc::clone(&addrs);
            let mut w = init_w.clone();
            let cfg = cfg.clone();
            let view = cfg.method.build().view();
            sys.spawn::<(), _, _>(&format!("p2p-{i}"), move |_mb| {
                // Three independent streams so gradient seeds stay a pure
                // function of (engine seed, worker, step) no matter how
                // many barrier polls or gossip relays interleave.
                let base = cfg.seed ^ (i as u64).wrapping_mul(0xABCD_EF01);
                let mut grad_rng = Rng::new(base);
                let mut ctrl_rng = Rng::new(base ^ 0x0C0_17B0_0C0_17B0);
                let mut gossip_rng = Rng::new(base ^ 0x6055_1900_6055_1900);

                let gossip_cfg = match &cfg.dissemination {
                    Dissemination::Gossip(g) => Some(g.clone()),
                    Dissemination::FullMesh => None,
                };
                let mut gnode = gossip_cfg.as_ref().map(|_| GossipNode::new(i, n));
                // Origin-side delta compaction buffer (gossip mode).
                let mut pending = vec![0.0f32; cfg.dim];
                let mut pending_steps = 0u64;

                let mut control_msgs = 0u64;
                let mut update_msgs = 0u64;
                let mut done = vec![false; n];
                done[i] = true;
                // Per-origin rumor counts announced by Done messages; the
                // drain exits when every announced rumor is applied.
                let mut expected = vec![0u32; n];

                // One flush tick: relay the fresh-rumor buffer — one
                // physical message per destination (successor + sampled
                // partners), no matter how many rumors ride along. A send
                // can only fail when the peer already exited — and a peer
                // only exits once it has applied *every* expected rumor,
                // so a failed send carries nothing but duplicates and is
                // safe to ignore.
                macro_rules! flush_gossip {
                    () => {
                        if let (Some(node), Some(gc)) =
                            (gnode.as_mut(), gossip_cfg.as_ref())
                        {
                            for (dest, rumors) in
                                node.flush(gc, &ring, &mut gossip_rng)
                            {
                                update_msgs += 1;
                                let _ = addrs[dest].send(PeerMsg::Gossip { rumors });
                            }
                        }
                    };
                }
                // Handle one inbound message (shared by step loop, waits
                // and the shutdown drain).
                macro_rules! process {
                    ($msg:expr) => {
                        match $msg {
                            PeerMsg::Delta { delta } => add_delta(&mut w, &delta),
                            PeerMsg::Gossip { rumors } => {
                                let node = gnode.as_mut().expect(
                                    "gossip message on a full-mesh plane",
                                );
                                node.receive(rumors, |r| add_delta(&mut w, &r.delta));
                            }
                            PeerMsg::Done { from, rumors } => {
                                done[from as usize] = true;
                                expected[from as usize] = rumors;
                            }
                        }
                    };
                }

                for step in 0..cfg.steps_per_worker {
                    while let Ok(msg) = rx.try_recv() {
                        process!(msg);
                    }
                    // compute locally, apply locally
                    let g = grad_fn(&w, grad_rng.next_u64());
                    let delta: Vec<f32> = g.iter().map(|x| -cfg.lr * x).collect();
                    add_delta(&mut w, &delta);
                    match &cfg.dissemination {
                        Dissemination::FullMesh => {
                            // push the delta to all peers (model plane);
                            // peers outlive every push — they cannot exit
                            // before processing our Done, which trails all
                            // of these sends in per-sender FIFO order
                            for (j, addr) in addrs.iter().enumerate() {
                                if j != i {
                                    update_msgs += 1;
                                    let _ = addr
                                        .send(PeerMsg::Delta { delta: delta.clone() });
                                }
                            }
                        }
                        Dissemination::Gossip(gc) => {
                            add_delta(&mut pending, &delta);
                            pending_steps += 1;
                            let last = step + 1 == cfg.steps_per_worker;
                            if pending_steps >= gc.flush_every || last {
                                let payload: Arc<[f32]> =
                                    std::mem::replace(&mut pending, vec![0.0; cfg.dim])
                                        .into();
                                pending_steps = 0;
                                gnode.as_mut().unwrap().originate(payload, gc);
                            }
                            // relays + originations leave every step
                            flush_gossip!();
                        }
                    }
                    steps[i].store(step + 1, Ordering::Release);
                    if step + 1 == cfg.steps_per_worker {
                        break;
                    }
                    // fully-distributed barrier: sample the overlay
                    loop {
                        let pass = match view {
                            ViewRequirement::None => true,
                            ViewRequirement::Sample(beta) => {
                                let (peers, hops) =
                                    ring.sample_nodes(i, beta, &mut ctrl_rng);
                                control_msgs += hops + 2 * peers.len() as u64;
                                peers.iter().all(|&p| {
                                    let sp = steps[p].load(Ordering::Acquire);
                                    (step + 1).saturating_sub(sp) <= staleness
                                })
                            }
                            ViewRequirement::Global => unreachable!(),
                        };
                        if pass {
                            break;
                        }
                        while let Ok(msg) = rx.try_recv() {
                            process!(msg);
                        }
                        // keep relaying while blocked so peers' deltas
                        // are not parked in our outbox
                        flush_gossip!();
                        std::thread::sleep(cfg.poll);
                    }
                }

                // Signal completion (no more originations from us) with
                // our exact origination count, then drain until every
                // expected Done sender reported in and — in gossip mode —
                // every announced rumor has been applied.
                let own_rumors = gnode.as_ref().map(|nd| nd.originated()).unwrap_or(0);
                expected[i] = own_rumors;
                for (j, addr) in addrs.iter().enumerate() {
                    if j != i {
                        let _ = addr.send(PeerMsg::Done {
                            from: i as u32,
                            rumors: own_rumors,
                        });
                    }
                }
                let deadline = Instant::now() + cfg.drain_timeout;
                // Ingest the whole backlog before relaying, then pace the
                // next tick at the poll interval: batching stays dense
                // (many rumors per physical message) and relay traffic
                // settles into synchronous-like rounds instead of one
                // flush per arriving message.
                macro_rules! ingest_backlog_and_relay {
                    ($first:expr) => {{
                        process!($first);
                        while let Ok(m) = rx.try_recv() {
                            process!(m);
                        }
                        flush_gossip!();
                        std::thread::sleep(cfg.poll);
                    }};
                }
                let mut dropped_deltas = 0u64;
                loop {
                    // Exact exit condition — no quiet-window guesswork:
                    // * full mesh: all Dones in ⇒ drained (per-sender
                    //   FIFO: a peer's Done follows all its deltas);
                    // * gossip: all Dones in AND every announced rumor
                    //   applied. Liveness is structural: a peer exits
                    //   only after it has applied and relayed everything,
                    //   so every rumor still owed to us is either in our
                    //   mailbox or behind a live relayer.
                    let all_done = done.iter().all(|&d| d);
                    let complete = all_done
                        && match &gnode {
                            None => true,
                            Some(node) => (0..n).all(|j| {
                                node.applied_count(j as u32) >= expected[j]
                            }),
                        };
                    if complete {
                        break;
                    }
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        // Loud failure: name the silent peers / missing
                        // rumors and count exactly what this timeout
                        // discards (a hang here means a peer died).
                        let missing_done: Vec<usize> = done
                            .iter()
                            .enumerate()
                            .filter(|(_, &d)| !d)
                            .map(|(j, _)| j)
                            .collect();
                        let missing_rumors: u64 = match &gnode {
                            None => 0,
                            Some(node) => (0..n)
                                .map(|j| {
                                    u64::from(expected[j]).saturating_sub(
                                        u64::from(node.applied_count(j as u32)),
                                    )
                                })
                                .sum(),
                        };
                        let mut discarded = 0u64;
                        while let Ok(msg) = rx.try_recv() {
                            match msg {
                                PeerMsg::Delta { .. } => discarded += 1,
                                PeerMsg::Gossip { rumors } => {
                                    discarded += rumors.len() as u64
                                }
                                PeerMsg::Done { from, rumors } => {
                                    done[from as usize] = true;
                                    expected[from as usize] = rumors;
                                }
                            }
                        }
                        dropped_deltas = missing_rumors.max(discarded);
                        log_warn!(
                            "p2p-{i}: drain timed out after {:?} (no Done from \
                             {missing_done:?}; {missing_rumors} expected rumor(s) \
                             never arrived; {discarded} queued message(s) \
                             discarded) — the reported replica is missing updates",
                            cfg.drain_timeout
                        );
                        break;
                    }
                    if let Ok(msg) =
                        rx.recv_timeout(left.min(Duration::from_millis(100)))
                    {
                        ingest_backlog_and_relay!(msg);
                    }
                }

                let (applied_rumors, dup_rumors, rumor_copies, route_msgs) =
                    match &gnode {
                        Some(nd) => (
                            nd.applied_rumors,
                            nd.dup_rumors,
                            nd.rumor_copies,
                            nd.route_msgs,
                        ),
                        None => (0, 0, 0, 0),
                    };
                WorkerOut {
                    w,
                    control_msgs: control_msgs + route_msgs,
                    update_msgs,
                    applied_rumors,
                    dup_rumors,
                    rumor_copies,
                    dropped_deltas,
                }
            })
        })
        .collect();

    let mut report = EngineReport::default();
    let mut replicas: Vec<Vec<f32>> = Vec::with_capacity(n);
    for wk in workers {
        let (addr, handle) = wk.into_parts();
        drop(addr);
        let out = handle.join().expect("p2p worker panicked");
        report.control_msgs += out.control_msgs;
        report.update_msgs += out.update_msgs;
        report.applied_rumors += out.applied_rumors;
        report.dup_rumors += out.dup_rumors;
        report.rumor_copies += out.rumor_copies;
        report.dropped_deltas += out.dropped_deltas;
        replicas.push(out.w);
    }

    report.steps = steps.iter().map(|s| s.load(Ordering::Acquire)).collect();
    report.wall_secs = start.elapsed().as_secs_f64();
    report.model = replicas.first().cloned().unwrap_or_default();
    report.replicas = replicas;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::linear::{Dataset, LinearModel};
    use crate::util::stats::l2_dist;
    use std::sync::Mutex;

    fn linear_grad_fn(dim: usize, seed: u64) -> (GradFn, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let data = Dataset::synthetic(512, dim, 0.05, &mut rng);
        let w_true = data.w_true.clone();
        let model = Mutex::new(LinearModel::new(dim));
        let f: GradFn = Arc::new(move |w, s| {
            model.lock().unwrap().minibatch_grad(&data, w, s, 32).to_vec()
        });
        (f, w_true)
    }

    #[test]
    fn pssp_converges_fully_distributed_over_gossip() {
        let cfg = P2pConfig {
            n_workers: 6,
            steps_per_worker: 12,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 24,
            lr: 0.02,
            seed: 11,
            ..P2pConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 13);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        assert!(r.steps.iter().all(|&s| s == 12));
        let init = l2_dist(&vec![0.0; 24], &w_true);
        let err = l2_dist(&r.model, &w_true);
        assert!(err < init, "p2p did not reduce error: {init} -> {err}");
        assert!(r.control_msgs > 0, "no sampling traffic recorded");
        // the gossip plane must beat the full mesh on physical messages
        // even at n=6 (mesh would be 6·12·5 = 360)
        assert!(r.update_msgs > 0);
        assert_eq!(r.dropped_deltas, 0, "no deltas may be dropped");
        assert_eq!(r.replicas.len(), 6);
    }

    #[test]
    fn full_mesh_mode_counts_n_squared_pushes() {
        let cfg = P2pConfig {
            n_workers: 6,
            steps_per_worker: 12,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 24,
            lr: 0.02,
            seed: 11,
            dissemination: Dissemination::FullMesh,
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(cfg.dim, 13);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        // every worker pushed every delta to every peer
        assert_eq!(r.update_msgs, 6 * 12 * 5);
        assert_eq!(r.applied_rumors, 0);
        assert_eq!(r.dropped_deltas, 0);
    }

    #[test]
    fn asp_full_mesh_has_zero_control_traffic() {
        let cfg = P2pConfig {
            n_workers: 4,
            steps_per_worker: 8,
            method: Method::Asp,
            dim: 16,
            seed: 17,
            dissemination: Dissemination::FullMesh,
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(16, 19);
        let r = run(&cfg, vec![0.0; 16], grad);
        assert_eq!(r.control_msgs, 0);
        assert_eq!(r.update_msgs, 4 * 8 * 3);
    }

    #[test]
    fn asp_gossip_spends_routing_not_barrier_traffic() {
        let cfg = P2pConfig {
            n_workers: 6,
            steps_per_worker: 8,
            method: Method::Asp,
            dim: 16,
            seed: 17,
            dissemination: Dissemination::Gossip(GossipConfig {
                fanout: 2,
                flush_every: 1,
                ttl: 4,
            }),
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(16, 19);
        let r = run(&cfg, vec![0.0; 16], grad);
        // ASP never samples for barriers, but gossip target selection
        // routes over the overlay — that traffic is control-plane cost.
        assert!(r.control_msgs > 0);
        assert!(r.rumor_copies >= r.applied_rumors);
    }

    #[test]
    #[should_panic(expected = "p2p engine cannot host global-view barrier")]
    fn bsp_rejected() {
        let cfg = P2pConfig { method: Method::Bsp, ..P2pConfig::default() };
        let (grad, _) = linear_grad_fn(cfg.dim, 1);
        run(&cfg, vec![0.0; cfg.dim], grad);
    }

    #[test]
    fn pbsp_zero_sample_is_asp() {
        let cfg = P2pConfig {
            n_workers: 4,
            steps_per_worker: 5,
            method: Method::Pbsp { sample: 0 },
            dim: 8,
            seed: 23,
            dissemination: Dissemination::FullMesh,
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(8, 29);
        let r = run(&cfg, vec![0.0; 8], grad);
        assert_eq!(r.control_msgs, 0);
    }

    #[test]
    fn flush_interval_compacts_originations() {
        let cfg = P2pConfig {
            n_workers: 5,
            steps_per_worker: 8,
            method: Method::Asp,
            dim: 8,
            seed: 31,
            dissemination: Dissemination::Gossip(GossipConfig {
                fanout: 1,
                flush_every: 4,
                ttl: 8,
            }),
            ..P2pConfig::default()
        };
        let (grad, _) = linear_grad_fn(8, 37);
        let r = run(&cfg, vec![0.0; 8], grad);
        // 8 steps at flush 4 → 2 rumors per origin; each of the other 4
        // workers applies each exactly once when dissemination completes.
        assert_eq!(r.dropped_deltas, 0);
        assert_eq!(r.applied_rumors, 5 * 2 * 4);
        assert_eq!(r.steps, vec![8; 5]);
    }
}
