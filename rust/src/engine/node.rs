//! Deployed single-node runtime (`actor node` / `actor join`).
//!
//! The p2p engine simulates a fully distributed PSP cluster inside one
//! process: every worker is a thread, and the coordinator-free barrier
//! reads peer step counts out of shared-nothing *messages*. This module
//! is the same design with the process boundary made real: **one worker
//! per OS process**, all state exchanged as [`Frame`]s over a pluggable
//! [`Transport`] — in-process channels for equivalence tests, TCP for a
//! real localhost (or LAN) cluster.
//!
//! What exists here and not in the sim engines:
//!
//! * a **step table** fed by `Step` broadcast frames — in the sim the
//!   sampling plane could query a peer thread directly; a deployed node
//!   can only know what peers have told it, so every step advance is
//!   announced (and re-announced while blocked, since TCP reconnects
//!   may drop the first copy);
//! * a **bootstrap handshake** ([`seed_bootstrap`] / [`join_bootstrap`]):
//!   the seed process accepts `n-1` joiners, assigns ids in connect
//!   order, and ships each one the full workload ([`Welcome`]) plus the
//!   roster (`Peers`) — the cluster is configured in exactly one place;
//! * a **monitor** ([`Monitor`]): a tiny HTTP endpoint serving ring
//!   topology and live [`EngineReport`] counters as JSON, which the CI
//!   cluster-smoke job scrapes to assert zero dropped deltas.
//!
//! Known limitation (documented, deliberate): the deployed runtime has
//! no custody-repair/membership plane yet — a crashed *process* is not
//! repaired the way the sim's membership plane repairs a crashed
//! worker thread (ROADMAP "deployment plane" item tracks the gap). The
//! protocol already carries `Repair` frames, so a node *receiving* one
//! handles it correctly.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::barrier::Method;
use crate::engine::gossip::{GossipConfig, GossipNode};
use crate::engine::p2p::{PeerMsg, MIN_DRAIN_POLL};
use crate::engine::transport::{read_frame, write_frame, Frame, Transport, Welcome};
use crate::engine::{EngineReport, GradFn};
use crate::log_warn;
use crate::overlay::Ring;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// Re-announce cadence for the step broadcast while a node is parked
/// at a barrier: peers that reconnected mid-run may have missed the
/// original announcement, and a silent node would park them forever.
const STEP_REANNOUNCE: Duration = Duration::from_millis(50);

/// One deployed node's slice of the cluster workload.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// This node's id (seed is 0; joiners get 1.. in connect order).
    pub id: usize,
    /// Cluster size.
    pub n: usize,
    /// Steps this node computes.
    pub steps: u64,
    /// Model dimension.
    pub dim: usize,
    /// Learning rate.
    pub lr: f32,
    /// Cluster-wide base seed (per-node RNGs fork off it).
    pub seed: u64,
    /// Barrier method. Probabilistic methods sample the overlay ring
    /// exactly like the p2p engine; `bsp`/`ssp` read the full step
    /// table (available here because every node broadcasts `Step`).
    pub method: Method,
    /// Gossip dissemination knobs.
    pub gossip: GossipConfig,
    /// Shutdown-drain safety net, after which unreceived rumors are
    /// counted as dropped and reported loudly.
    pub drain_timeout: Duration,
}

/// Cluster-wide workload as the seed node knows it — everything a
/// joiner needs arrives in the [`Welcome`] built from this.
#[derive(Debug, Clone)]
pub struct Workload {
    pub n: usize,
    pub steps: u64,
    pub dim: usize,
    pub lr: f32,
    pub seed: u64,
    pub method: Method,
    pub gossip: GossipConfig,
    pub drain_timeout: Duration,
}

impl Workload {
    /// The `Welcome` frame assigning `id` to a joiner.
    pub fn welcome(&self, id: u32) -> Welcome {
        Welcome {
            id,
            n: self.n as u32,
            seed: self.seed,
            steps: self.steps,
            dim: self.dim as u32,
            lr: self.lr,
            method: format!("{}", self.method),
            fanout: self.gossip.fanout as u32,
            flush: self.gossip.flush_every,
            ttl: self.gossip.ttl,
        }
    }

    /// The node config for one member of this workload.
    pub fn node_config(&self, id: usize) -> NodeConfig {
        NodeConfig {
            id,
            n: self.n,
            steps: self.steps,
            dim: self.dim,
            lr: self.lr,
            seed: self.seed,
            method: self.method,
            gossip: self.gossip.clone(),
            drain_timeout: self.drain_timeout,
        }
    }

    /// Rebuild a workload from a received `Welcome` (joiner side).
    /// `None` when the method string does not parse — a version-skewed
    /// seed, which the joiner must refuse rather than guess around.
    pub fn from_welcome(w: &Welcome, drain_timeout: Duration) -> Option<Workload> {
        Some(Workload {
            n: w.n as usize,
            steps: w.steps,
            dim: w.dim as usize,
            lr: w.lr,
            seed: w.seed,
            method: Method::parse(&w.method)?,
            gossip: GossipConfig {
                fanout: w.fanout as usize,
                flush_every: w.flush,
                ttl: w.ttl,
            },
            drain_timeout,
        })
    }
}

// ---------------------------------------------------------------------------
// Bootstrap handshake
// ---------------------------------------------------------------------------

/// Seed side: accept `n-1` joiners on `listener`, read each one's
/// `Join { addr }`, assign ids `1..n` in connect order, then send every
/// joiner its `Welcome` plus the full roster. Returns the roster
/// (`(id, listen addr)`, seed included as id 0). The listener is
/// *borrowed* — hand the same socket to [`TcpTransport::with_listener`]
/// afterwards so there is no rebind race.
///
/// [`TcpTransport::with_listener`]: crate::engine::transport::TcpTransport::with_listener
pub fn seed_bootstrap(
    listener: &TcpListener,
    wl: &Workload,
    seed_addr: &str,
) -> io::Result<Vec<(usize, String)>> {
    let mut joiners: Vec<(TcpStream, String)> = Vec::new();
    while joiners.len() < wl.n - 1 {
        let (mut conn, from) = listener.accept()?;
        conn.set_read_timeout(Some(Duration::from_secs(30)))?;
        match read_frame(&mut conn) {
            Ok(Frame::Join { addr }) => {
                eprintln!("node: joiner {} will be id {} (listens on {addr})", from, joiners.len() + 1);
                joiners.push((conn, addr));
            }
            Ok(other) => {
                log_warn!("node: bootstrap expected Join from {from}, got {other:?}; dropping");
            }
            Err(e) => {
                log_warn!("node: bootstrap read from {from} failed: {e}; dropping");
            }
        }
    }
    let mut roster: Vec<(usize, String)> = vec![(0, seed_addr.to_string())];
    for (i, (_, addr)) in joiners.iter().enumerate() {
        roster.push((i + 1, addr.clone()));
    }
    let peers = Frame::Peers {
        peers: roster.iter().map(|(id, a)| (*id as u32, a.clone())).collect(),
    };
    for (i, (mut conn, _)) in joiners.into_iter().enumerate() {
        write_frame(&mut conn, &Frame::Welcome(wl.welcome((i + 1) as u32)))?;
        write_frame(&mut conn, &peers)?;
        // The bootstrap connection's job is done; the run uses fresh
        // writer-owned connections in both directions.
    }
    Ok(roster)
}

/// Joiner side: connect to the seed (with retry/backoff until
/// `timeout` — the seed may not be up yet), announce our listen
/// address, and collect the `Welcome` + roster.
pub fn join_bootstrap(
    seed_addr: &str,
    my_addr: &str,
    timeout: Duration,
) -> io::Result<(Welcome, Vec<(usize, String)>)> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(10);
    let mut conn = loop {
        match TcpStream::connect(seed_addr) {
            Ok(c) => break c,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    };
    // The seed replies only once the whole cluster has dialed in; give
    // slow sibling processes a generous window.
    conn.set_read_timeout(Some(Duration::from_secs(120)))?;
    write_frame(&mut conn, &Frame::Join { addr: my_addr.to_string() })?;
    let welcome = match read_frame(&mut conn)? {
        Frame::Welcome(w) => w,
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bootstrap expected Welcome, got {other:?}"),
            ))
        }
    };
    let peers = match read_frame(&mut conn)? {
        Frame::Peers { peers } => {
            peers.into_iter().map(|(id, a)| (id as usize, a)).collect()
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bootstrap expected Peers, got {other:?}"),
            ))
        }
    };
    Ok((welcome, peers))
}

// ---------------------------------------------------------------------------
// Monitor endpoint
// ---------------------------------------------------------------------------

/// Minimal HTTP endpoint serving one JSON document — ring topology and
/// live engine counters. Any `GET` gets the current snapshot; the CI
/// cluster-smoke job curls it and asserts `dropped_deltas == 0`.
pub struct Monitor {
    addr: SocketAddr,
    state: Arc<Mutex<String>>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Monitor {
    /// Bind and start serving. Port 0 picks a free port; the real
    /// address is [`addr`](Self::addr).
    pub fn serve(listen: &str) -> io::Result<Monitor> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(Mutex::new(
            obj(vec![("status", Json::Str("starting".to_string()))]).to_string(),
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(mut conn) = conn else { continue };
                    let body = state.lock().unwrap().clone();
                    // Consume (and ignore) the request head — every
                    // path serves the same document.
                    let _ = conn.set_read_timeout(Some(Duration::from_millis(500)));
                    let mut scratch = [0u8; 1024];
                    let _ = conn.read(&mut scratch);
                    let resp = format!(
                        "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                        body.len(),
                        body
                    );
                    let _ = conn.write_all(resp.as_bytes());
                }
            })
        };
        Ok(Monitor { addr, state, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port-0 binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Swap the served document.
    pub fn set(&self, doc: &Json) {
        *self.state.lock().unwrap() = doc.to_string();
    }
}

impl Drop for Monitor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop so it can observe the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The monitor document for one node: identity, ring order, step table
/// and the report counters the smoke gate asserts on.
pub fn status_json(
    status: &str,
    cfg: &NodeConfig,
    ring: &Ring,
    report: &EngineReport,
    applied_of: &[u32],
) -> Json {
    let mut order: Vec<(u64, usize)> = (0..cfg.n)
        .filter_map(|i| ring.ring_id_of(i).map(|rid| (rid, i)))
        .collect();
    order.sort_unstable();
    obj(vec![
        ("status", Json::Str(status.to_string())),
        ("id", Json::Num(cfg.id as f64)),
        ("n", Json::Num(cfg.n as f64)),
        ("ring", Json::Arr(order.iter().map(|&(_, i)| Json::Num(i as f64)).collect())),
        ("steps", Json::Arr(report.steps.iter().map(|&s| Json::Num(s as f64)).collect())),
        (
            "applied_of",
            Json::Arr(applied_of.iter().map(|&c| Json::Num(c as f64)).collect()),
        ),
        (
            "report",
            obj(vec![
                ("update_msgs", Json::Num(report.update_msgs as f64)),
                ("control_msgs", Json::Num(report.control_msgs as f64)),
                ("applied_rumors", Json::Num(report.applied_rumors as f64)),
                ("dup_rumors", Json::Num(report.dup_rumors as f64)),
                ("rumor_copies", Json::Num(report.rumor_copies as f64)),
                ("dropped_deltas", Json::Num(report.dropped_deltas as f64)),
                ("missing_rumors", Json::Num(report.missing_rumors as f64)),
                ("discarded_msgs", Json::Num(report.discarded_msgs as f64)),
                ("drain_polls", Json::Num(report.drain_polls as f64)),
                ("wall_secs", Json::Num(report.wall_secs)),
            ]),
        ),
    ])
}

// ---------------------------------------------------------------------------
// Node runtime
// ---------------------------------------------------------------------------

/// What a finished node hands back: the standard engine report plus the
/// per-origin applied-rumor counts — the signature the equivalence
/// tests diff across transports (channel vs TCP must match exactly).
#[derive(Debug, Clone)]
pub struct NodeOutcome {
    pub report: EngineReport,
    /// `applied_of[o]` = distinct rumors of origin `o` this node
    /// applied (own originations included).
    pub applied_of: Vec<u32>,
}

/// Mutable node state, factored out so the frame handler and the main
/// loop borrow disjoint fields without closure gymnastics.
struct NodeState {
    me: usize,
    n: usize,
    gossip: GossipNode,
    ring: Ring,
    w: Vec<f32>,
    /// Last known completed-step count per peer (fed by `Step` frames).
    steps_done: Vec<u64>,
    /// Max beat seen per peer — distinguishes fresh announcements from
    /// reconnect resends in debug logs; merging is max on both fields.
    beats: Vec<u64>,
    /// `Some(count)` once origin announced its final origination count
    /// (via `Done`, `Leave`, or a custodian `Repair`).
    expected: Vec<Option<u32>>,
    update_msgs: u64,
    control_msgs: u64,
    discarded_msgs: u64,
}

fn axpy(w: &mut [f32], delta: &[f32]) {
    debug_assert_eq!(w.len(), delta.len(), "delta dimension mismatch");
    for (wi, di) in w.iter_mut().zip(delta) {
        *wi += di;
    }
}

impl NodeState {
    fn handle(&mut self, frame: Frame) {
        match frame {
            Frame::Peer(PeerMsg::Gossip { rumors }) => {
                let w = &mut self.w;
                self.gossip.receive(rumors, |r| axpy(w, &r.delta));
            }
            Frame::Peer(PeerMsg::Delta { delta }) => axpy(&mut self.w, &delta),
            Frame::Peer(PeerMsg::Done { from, rumors }) => {
                self.expected[from as usize] = Some(rumors);
            }
            Frame::Peer(PeerMsg::Leave { from, rumors }) => {
                self.expected[from as usize] = Some(rumors);
                self.ring.evict(from as usize);
            }
            Frame::Peer(PeerMsg::Repair { origin, rumors, store }) => {
                // A custodian re-announcing for a dead origin: stands in
                // for the Done the origin never sent.
                self.expected[origin as usize].get_or_insert(rumors);
                let w = &mut self.w;
                self.gossip.receive(store, |r| axpy(w, &r.delta));
            }
            Frame::Step { from, step, beat } => {
                let i = from as usize;
                if i < self.n {
                    self.steps_done[i] = self.steps_done[i].max(step);
                    self.beats[i] = self.beats[i].max(beat);
                } else {
                    self.discarded_msgs += 1;
                }
            }
            other @ (Frame::Join { .. } | Frame::Welcome(_) | Frame::Peers { .. }) => {
                log_warn!("node {}: bootstrap frame after bootstrap: {other:?}", self.me);
                self.discarded_msgs += 1;
            }
        }
    }

    /// Flush queued gossip batches onto the wire.
    fn flush_gossip<T: Transport>(&mut self, cfg: &GossipConfig, rng: &mut Rng, transport: &T) {
        for (dst, rumors) in self.gossip.flush(cfg, &self.ring, rng) {
            if transport.send(dst, Frame::Peer(PeerMsg::Gossip { rumors })) {
                self.update_msgs += 1;
            }
        }
    }

    /// A peer's step count as the barrier sees it: a peer that already
    /// announced its final origination count can never block anyone.
    fn view(&self, j: usize) -> u64 {
        if self.expected[j].is_some() {
            u64::MAX
        } else {
            self.steps_done[j]
        }
    }

    /// Can this node start computing step `my_step`? Returns the pass
    /// verdict and the overlay routing messages the sample cost.
    fn barrier_pass(&mut self, my_step: u64, method: &Method, rng: &mut Rng) -> (bool, u64) {
        let min_all = || (0..self.n).filter(|&j| j != self.me).map(|j| self.view(j)).min();
        match method {
            Method::Asp => (true, 0),
            Method::Bsp => (min_all().map_or(true, |m| m >= my_step), 0),
            Method::Ssp { staleness } => {
                (min_all().map_or(true, |m| my_step.saturating_sub(m) <= *staleness), 0)
            }
            Method::Pbsp { sample } => {
                let (peers, msgs) = self.ring.sample_nodes(self.me, *sample, rng);
                let pass = peers.iter().map(|&j| self.view(j)).min().map_or(true, |m| m >= my_step);
                (pass, msgs)
            }
            Method::Pssp { sample, staleness } => {
                let (peers, msgs) = self.ring.sample_nodes(self.me, *sample, rng);
                let pass = peers
                    .iter()
                    .map(|&j| self.view(j))
                    .min()
                    .map_or(true, |m| my_step.saturating_sub(m) <= *staleness);
                (pass, msgs)
            }
            Method::Pquorum { sample, staleness, quorum_pct } => {
                let (peers, msgs) = self.ring.sample_nodes(self.me, *sample, rng);
                if peers.is_empty() {
                    return (true, msgs);
                }
                let within = peers
                    .iter()
                    .filter(|&&j| my_step.saturating_sub(self.view(j)) <= *staleness)
                    .count();
                let pass = within * 100 >= peers.len() * *quorum_pct as usize;
                (pass, msgs)
            }
        }
    }
}

/// Run one deployed node to completion: compute `cfg.steps` SGD steps
/// under the configured barrier, disseminating deltas over the gossip
/// plane carried by `transport`, then drain until every announced rumor
/// of every origin has been applied (or `drain_timeout` fires — losses
/// are loud, never silent).
pub fn run_node<T: Transport>(
    cfg: &NodeConfig,
    transport: &mut T,
    grad_fn: GradFn,
    monitor: Option<&Monitor>,
) -> NodeOutcome {
    assert_eq!(cfg.id, transport.me(), "config/transport id mismatch");
    assert_eq!(cfg.n, transport.n(), "config/transport size mismatch");
    assert!(cfg.n >= 1 && cfg.id < cfg.n);
    let t0 = Instant::now();
    let me = cfg.id;
    let n = cfg.n;
    // Same fork recipe as the sim engines' per-worker RNGs: cluster
    // seed spread by the golden ratio, xor'd with the node id.
    let wseed = cfg.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ me as u64;
    let mut rng = Rng::new(wseed);
    let mut st = NodeState {
        me,
        n,
        gossip: GossipNode::new(me, n),
        ring: Ring::with_nodes(n, cfg.seed),
        w: vec![0.0; cfg.dim],
        steps_done: vec![0; n],
        beats: vec![0; n],
        expected: vec![None; n],
        update_msgs: 0,
        control_msgs: 0,
        discarded_msgs: 0,
    };
    let gcfg = cfg.gossip.clone();
    let flush_every = gcfg.flush_every.max(1);
    let mut pending = vec![0.0f32; cfg.dim];
    let mut step: u64 = 0;
    let mut beat: u64 = 0;

    let broadcast_step =
        |st: &mut NodeState, transport: &T, step: u64, beat: u64| {
            for peer in 0..n {
                if peer != me && transport.send(peer, Frame::Step { from: me as u32, step, beat }) {
                    st.control_msgs += 1;
                }
            }
        };

    beat += 1;
    broadcast_step(&mut st, transport, 0, beat);
    let mut last_announce = Instant::now();

    while step < cfg.steps {
        while let Some(f) = transport.try_recv() {
            st.handle(f);
        }
        let (pass, sample_msgs) = st.barrier_pass(step, &cfg.method, &mut rng);
        st.control_msgs += sample_msgs;
        if !pass {
            if let Some(f) = transport.recv_timeout(Duration::from_millis(2)) {
                st.handle(f);
            }
            // Relay anything a received batch queued even while parked,
            // or the cluster can deadlock waiting on our shortcuts.
            st.flush_gossip(&gcfg, &mut rng, transport);
            if last_announce.elapsed() >= STEP_REANNOUNCE {
                beat += 1;
                broadcast_step(&mut st, transport, step, beat);
                last_announce = Instant::now();
            }
            continue;
        }

        let g = grad_fn(&st.w, wseed.wrapping_add(step));
        for d in 0..cfg.dim {
            let delta = -cfg.lr * g[d];
            st.w[d] += delta;
            pending[d] += delta;
        }
        step += 1;
        st.steps_done[me] = step;

        if step % flush_every == 0 || step == cfg.steps {
            let delta = std::mem::replace(&mut pending, vec![0.0; cfg.dim]);
            st.gossip.originate(delta.into(), &gcfg);
            st.flush_gossip(&gcfg, &mut rng, transport);
        }
        beat += 1;
        broadcast_step(&mut st, transport, step, beat);
        last_announce = Instant::now();

        if let Some(m) = monitor {
            if step % 16 == 0 || step == cfg.steps {
                let snap = interim_report(&st, t0, 0);
                let applied: Vec<u32> =
                    (0..n).map(|o| st.gossip.applied_count(o as u32)).collect();
                m.set(&status_json("running", cfg, &st.ring, &snap, &applied));
            }
        }
    }

    // Announce our exact origination count so every peer's drain can
    // terminate deterministically, then drain ourselves.
    let announced = st.gossip.originated();
    st.expected[me] = Some(announced);
    for peer in 0..n {
        if peer != me
            && transport.send(peer, Frame::Peer(PeerMsg::Done { from: me as u32, rumors: announced }))
        {
            st.control_msgs += 1;
        }
    }

    let deadline = Instant::now() + cfg.drain_timeout;
    let mut drain_polls: u64 = 0;
    let mut timed_out = false;
    loop {
        let drained = (0..n).all(|o| match st.expected[o] {
            Some(c) => st.gossip.applied_count(o as u32) >= c,
            None => false,
        });
        if drained {
            break;
        }
        let now = Instant::now();
        if now >= deadline {
            timed_out = true;
            break;
        }
        // Same clamp as the p2p engine: near the deadline recv_timeout
        // would degenerate to a hot spin without a floor.
        let wait = (deadline - now).max(MIN_DRAIN_POLL);
        drain_polls += 1;
        if let Some(f) = transport.recv_timeout(wait) {
            st.handle(f);
            while let Some(f) = transport.try_recv() {
                st.handle(f);
            }
            st.flush_gossip(&gcfg, &mut rng, transport);
        }
    }

    let mut missing_rumors: u64 = 0;
    let mut discarded: u64 = st.discarded_msgs;
    if timed_out {
        for o in 0..n {
            match st.expected[o] {
                Some(c) => {
                    missing_rumors += u64::from(c.saturating_sub(st.gossip.applied_count(o as u32)))
                }
                None => log_warn!(
                    "node {me}: drain timed out with no Done from {o}; its rumor count is unknown"
                ),
            }
        }
        while transport.try_recv().is_some() {
            discarded += 1;
        }
        log_warn!(
            "node {me}: drain safety-net fired after {:?} — {missing_rumors} rumors missing, {discarded} messages discarded",
            cfg.drain_timeout
        );
    }

    let mut report = interim_report(&st, t0, drain_polls);
    report.missing_rumors = missing_rumors;
    report.discarded_msgs = discarded;
    report.dropped_deltas = missing_rumors.max(discarded);
    let applied_of: Vec<u32> = (0..n).map(|o| st.gossip.applied_count(o as u32)).collect();
    if let Some(m) = monitor {
        m.set(&status_json("done", cfg, &st.ring, &report, &applied_of));
    }
    NodeOutcome { report, applied_of }
}

/// The report as far as `st` can tell; loss fields are filled by the
/// caller once the drain verdict is known.
fn interim_report(st: &NodeState, t0: Instant, drain_polls: u64) -> EngineReport {
    EngineReport {
        steps: st.steps_done.clone(),
        update_msgs: st.update_msgs,
        control_msgs: st.control_msgs + st.gossip.route_msgs,
        wall_secs: t0.elapsed().as_secs_f64(),
        model: st.w.clone(),
        applied_rumors: st.gossip.applied_rumors,
        dup_rumors: st.gossip.dup_rumors,
        rumor_copies: st.gossip.rumor_copies,
        drain_polls,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::transport::ChannelTransport;
    use std::sync::Arc;

    fn test_workload(n: usize, steps: u64, method: Method) -> Workload {
        Workload {
            n,
            steps,
            dim: 8,
            lr: 0.1,
            seed: 42,
            method,
            gossip: GossipConfig { fanout: 2, flush_every: 1, ttl: 4 },
            drain_timeout: Duration::from_secs(10),
        }
    }

    fn seed_only_grad() -> GradFn {
        Arc::new(|w: &[f32], seed: u64| {
            let mut rng = Rng::new(seed);
            (0..w.len()).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
        })
    }

    fn run_cluster(wl: &Workload) -> Vec<NodeOutcome> {
        let transports = ChannelTransport::cluster(wl.n);
        let mut handles = Vec::new();
        for (id, mut tr) in transports.into_iter().enumerate() {
            let cfg = wl.node_config(id);
            let grad = seed_only_grad();
            handles.push(std::thread::spawn(move || {
                run_node(&cfg, &mut tr, grad, None)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("node thread")).collect()
    }

    #[test]
    fn channel_cluster_drains_with_zero_losses_under_pssp() {
        let wl = test_workload(4, 12, Method::Pssp { sample: 2, staleness: 2 });
        let outs = run_cluster(&wl);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.report.dropped_deltas, 0, "node {i} dropped deltas");
            assert_eq!(o.report.missing_rumors, 0, "node {i} missing rumors");
            // Every node applied every origin's full origination run.
            assert_eq!(o.applied_of, outs[0].applied_of, "node {i} applied_of diverges");
            assert_eq!(o.applied_of.iter().map(|&c| c as u64).sum::<u64>(), 4 * 12);
        }
    }

    #[test]
    fn channel_cluster_converges_under_bsp_lockstep() {
        // bsp over the broadcast step table: no node may ever lead by
        // more than one step, and all finish all steps.
        let wl = test_workload(3, 8, Method::Bsp);
        let outs = run_cluster(&wl);
        for o in &outs {
            assert_eq!(o.report.dropped_deltas, 0);
            assert_eq!(o.applied_of.iter().map(|&c| c as u64).sum::<u64>(), 3 * 8);
        }
    }

    #[test]
    fn flush_cadence_batches_originations() {
        // flush_every=3 over 7 steps -> originations at steps 3, 6, 7.
        let wl = Workload {
            gossip: GossipConfig { fanout: 1, flush_every: 3, ttl: 4 },
            ..test_workload(2, 7, Method::Asp)
        };
        let outs = run_cluster(&wl);
        for o in &outs {
            assert_eq!(o.applied_of, vec![3, 3]);
            assert_eq!(o.report.dropped_deltas, 0);
        }
    }

    #[test]
    fn welcome_round_trips_the_workload() {
        let wl = test_workload(5, 20, Method::Pquorum { sample: 3, staleness: 1, quorum_pct: 80 });
        let w = wl.welcome(3);
        assert_eq!(w.id, 3);
        assert_eq!(w.method, "pquorum:3:1:80");
        let back = Workload::from_welcome(&w, wl.drain_timeout).expect("parses");
        assert_eq!(back.n, wl.n);
        assert_eq!(back.steps, wl.steps);
        assert_eq!(back.dim, wl.dim);
        assert_eq!(back.method, wl.method);
        assert_eq!(back.gossip.fanout, wl.gossip.fanout);
        assert!(Workload::from_welcome(
            &Welcome { method: "warp-speed".into(), ..w },
            wl.drain_timeout
        )
        .is_none());
    }

    #[test]
    fn monitor_serves_the_current_snapshot_over_http() {
        let m = Monitor::serve("127.0.0.1:0").expect("bind monitor");
        m.set(&obj(vec![
            ("status", Json::Str("done".to_string())),
            ("dropped_deltas", Json::Num(0.0)),
        ]));
        let mut conn = TcpStream::connect(m.addr()).expect("connect");
        conn.write_all(b"GET /status HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let _ = conn.read_to_string(&mut resp);
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "bad response: {resp}");
        assert!(resp.contains("\"dropped_deltas\":0") || resp.contains("\"dropped_deltas\": 0"),
            "body missing counter: {resp}");
    }

    #[test]
    fn bootstrap_handshake_assigns_ids_and_ships_the_roster() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind seed");
        let seed_addr = listener.local_addr().unwrap().to_string();
        let wl = test_workload(3, 4, Method::Asp);
        let seed_thread = {
            let wl = wl.clone();
            let seed_addr = seed_addr.clone();
            std::thread::spawn(move || seed_bootstrap(&listener, &wl, &seed_addr).expect("seed"))
        };
        let mut joiners = Vec::new();
        for j in 0..2 {
            let seed_addr = seed_addr.clone();
            joiners.push(std::thread::spawn(move || {
                let my_addr = format!("127.0.0.1:{}", 9000 + j);
                join_bootstrap(&seed_addr, &my_addr, Duration::from_secs(10)).expect("join")
            }));
        }
        let roster = seed_thread.join().expect("seed thread");
        assert_eq!(roster.len(), 3);
        assert_eq!(roster[0], (0, seed_addr.clone()));
        let mut ids = Vec::new();
        for j in joiners {
            let (welcome, peers) = j.join().expect("join thread");
            assert_eq!(welcome.n, 3);
            assert_eq!(welcome.method, "asp");
            assert_eq!(peers.len(), 3);
            assert_eq!(peers[0].1, seed_addr);
            ids.push(welcome.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
    }
}
