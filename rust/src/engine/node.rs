//! Deployed single-node runtime (`actor node` / `actor join`).
//!
//! The p2p engine simulates a fully distributed PSP cluster inside one
//! process: every worker is a thread, and the coordinator-free barrier
//! reads peer step counts out of shared-nothing *messages*. This module
//! is the same design with the process boundary made real: **one worker
//! per OS process**, all state exchanged as [`Frame`]s over a pluggable
//! [`Transport`] — in-process channels for equivalence tests, TCP for a
//! real localhost (or LAN) cluster.
//!
//! What exists here and not in the sim engines:
//!
//! * a **step table** fed by `Step` broadcast frames — in the sim the
//!   sampling plane could query a peer thread directly; a deployed node
//!   can only know what peers have told it, so every step advance is
//!   announced (and re-announced while blocked, since TCP reconnects
//!   may drop the first copy);
//! * a **bootstrap handshake** ([`seed_bootstrap`] / [`join_bootstrap`]):
//!   the seed process accepts `n-1` joiners, assigns ids in connect
//!   order, and ships each one the full workload ([`Welcome`]) plus the
//!   roster (`Peers`) — the cluster is configured in exactly one place;
//! * a **monitor** ([`Monitor`]): a tiny HTTP endpoint serving ring
//!   topology and live [`EngineReport`] counters as JSON, which the CI
//!   cluster-smoke job scrapes to assert zero dropped deltas.
//!
//! * the **crash-fault membership plane over the wire**: the same
//!   SWIM-style [`FailureDetector`] the in-process engine runs, fed by
//!   the `Step` beat table (every announcement is a heartbeat). When a
//!   peer's beats go silent past `suspect_after + confirm_after`, the
//!   survivor confirms it dead, broadcasts a `Confirm` frame so the
//!   whole cluster converges on one verdict, evicts the corpse from its
//!   ring view (sampling and the drain stop waiting on it), tears down
//!   the peer's writer via [`Transport::evict_peer`], and — if it is
//!   the dead node's ring successor — acts as *custodian*: re-announces
//!   the origin's rumor count and re-injects its rumors from the
//!   custody store, standing in for the `Done` the dead process never
//!   sent. A `kill -9` therefore costs the survivors roughly
//!   suspect+confirm of wall clock, not `drain_timeout`.
//!
//! Multi-crash caveat (same as the in-process plane): custody assumes
//! the dead origin's ring successor holds every rumor the origin
//! flushed, which per-peer FIFO guarantees for a single crash; if the
//! custodian dies in the same window, counts can under-report and the
//! drain falls back to the timeout safety net — loud, never silent.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::barrier::{AdaptiveConfig, BarrierPolicy, Method, ViewRequirement};
use crate::engine::delta::{CompressConfig, DeltaEncoder};
use crate::engine::gossip::{GossipConfig, GossipNode};
use crate::engine::membership::{evict_from_view, FailureDetector, MembershipConfig, PeerState};
use crate::engine::p2p::{PeerMsg, MIN_DRAIN_POLL};
use crate::engine::transport::{read_frame, write_frame, Frame, Transport, Welcome};
use crate::engine::{EngineReport, GradFn};
use crate::log_warn;
use crate::overlay::Ring;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// Re-announce cadence for the step broadcast while a node is parked
/// at a barrier: peers that reconnected mid-run may have missed the
/// original announcement, and a silent node would park them forever.
const STEP_REANNOUNCE: Duration = Duration::from_millis(50);

/// One deployed node's slice of the cluster workload.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// This node's id (seed is 0; joiners get 1.. in connect order).
    pub id: usize,
    /// Cluster size.
    pub n: usize,
    /// Steps this node computes.
    pub steps: u64,
    /// Model dimension.
    pub dim: usize,
    /// Learning rate.
    pub lr: f32,
    /// Cluster-wide base seed (per-node RNGs fork off it).
    pub seed: u64,
    /// Barrier method. Probabilistic methods sample the overlay ring
    /// exactly like the p2p engine; `bsp`/`ssp` read the full step
    /// table (available here because every node broadcasts `Step`).
    pub method: Method,
    /// Gossip dissemination knobs.
    pub gossip: GossipConfig,
    /// Delta-payload compression for this node's originations. Rides
    /// the `Welcome` frame so every member encodes identically;
    /// `Dense` keeps the legacy uncompressed path bit-for-bit.
    pub compress: CompressConfig,
    /// Shutdown-drain safety net, after which unreceived rumors are
    /// counted as dropped and reported loudly.
    pub drain_timeout: Duration,
    /// Crash-fault detection thresholds (µs of beat silence); `None`
    /// disables the membership plane — a dead peer then stalls the
    /// drain to `drain_timeout` exactly as before. The thresholds must
    /// comfortably exceed one gradient step: a node computing does not
    /// beat mid-step.
    pub membership: Option<MembershipConfig>,
    /// Synthetic per-step compute padding. Deployment demos and the
    /// chaos CI job use it to pin a run's duration to `steps × pad`
    /// regardless of hardware, so a mid-run SIGKILL is actually
    /// mid-run. Zero (the default) means full speed.
    pub step_pad: Duration,
    /// Crash-stop after completing this many steps: return without
    /// `Done` or drain, exactly the silence survivors must detect and
    /// repair. Test/experiment hook; a real deployment crashes by
    /// dying.
    pub crash_at: Option<u64>,
    /// Online barrier adaptation (DSSP-style). Deliberately **not** part
    /// of [`Workload`]/`Welcome`: adaptation is a per-node-local policy
    /// (each node retunes its own θ/β from its own wait history), so a
    /// joiner opts in with its own flag and the wire format is
    /// untouched. `None` = static knobs, legacy decisions exactly.
    pub adaptive: Option<AdaptiveConfig>,
}

/// Cluster-wide workload as the seed node knows it — everything a
/// joiner needs arrives in the [`Welcome`] built from this.
#[derive(Debug, Clone)]
pub struct Workload {
    pub n: usize,
    pub steps: u64,
    pub dim: usize,
    pub lr: f32,
    pub seed: u64,
    pub method: Method,
    pub gossip: GossipConfig,
    /// Delta-payload compression; rides the `Welcome` frame (mode tag +
    /// top-k) so the whole cluster encodes originations the same way.
    pub compress: CompressConfig,
    pub drain_timeout: Duration,
    /// Crash-fault detection thresholds; rides the `Welcome` frame so
    /// seed and joiners agree on detection timing from one place.
    pub membership: Option<MembershipConfig>,
}

impl Workload {
    /// The `Welcome` frame assigning `id` to a joiner. Membership
    /// timing travels as µs pairs; `0/0` encodes "membership off"
    /// (zero silence-tolerance would confirm everyone dead instantly,
    /// so the zero value is free to mean *disabled*).
    pub fn welcome(&self, id: u32) -> Welcome {
        Welcome {
            id,
            n: self.n as u32,
            seed: self.seed,
            steps: self.steps,
            dim: self.dim as u32,
            lr: self.lr,
            method: format!("{}", self.method),
            fanout: self.gossip.fanout as u32,
            flush: self.gossip.flush_every,
            ttl: self.gossip.ttl,
            suspect_us: self.membership.as_ref().map_or(0, |m| m.suspect_after),
            confirm_us: self.membership.as_ref().map_or(0, |m| m.confirm_after),
            compress: self.compress.mode_tag(),
            top_k: self.compress.top_k as u32,
        }
    }

    /// The node config for one member of this workload.
    pub fn node_config(&self, id: usize) -> NodeConfig {
        NodeConfig {
            id,
            n: self.n,
            steps: self.steps,
            dim: self.dim,
            lr: self.lr,
            seed: self.seed,
            method: self.method,
            gossip: self.gossip.clone(),
            compress: self.compress,
            drain_timeout: self.drain_timeout,
            membership: self.membership.clone(),
            step_pad: Duration::ZERO,
            crash_at: None,
            adaptive: None,
        }
    }

    /// Rebuild a workload from a received `Welcome` (joiner side).
    /// `None` when the method string or the compression tag does not
    /// parse — a version-skewed seed, which the joiner must refuse
    /// rather than guess around.
    pub fn from_welcome(w: &Welcome, drain_timeout: Duration) -> Option<Workload> {
        Some(Workload {
            n: w.n as usize,
            steps: w.steps,
            dim: w.dim as usize,
            lr: w.lr,
            seed: w.seed,
            method: Method::parse(&w.method)?,
            gossip: GossipConfig {
                fanout: w.fanout as usize,
                flush_every: w.flush,
                ttl: w.ttl,
            },
            compress: CompressConfig::from_tag(w.compress, w.top_k as usize)?,
            drain_timeout,
            membership: if w.suspect_us == 0 || w.confirm_us == 0 {
                None
            } else {
                Some(MembershipConfig {
                    suspect_after: w.suspect_us,
                    confirm_after: w.confirm_us,
                })
            },
        })
    }
}

// ---------------------------------------------------------------------------
// Bootstrap handshake
// ---------------------------------------------------------------------------

/// Seed side: accept `n-1` joiners on `listener`, read each one's
/// `Join { addr }`, assign ids `1..n` in connect order, then send every
/// joiner its `Welcome` plus the full roster. Returns the roster
/// (`(id, listen addr)`, seed included as id 0). The listener is
/// *borrowed* — hand the same socket to [`TcpTransport::with_listener`]
/// afterwards so there is no rebind race.
///
/// [`TcpTransport::with_listener`]: crate::engine::transport::TcpTransport::with_listener
pub fn seed_bootstrap(
    listener: &TcpListener,
    wl: &Workload,
    seed_addr: &str,
) -> io::Result<Vec<(usize, String)>> {
    let mut joiners: Vec<(TcpStream, String)> = Vec::new();
    while joiners.len() < wl.n - 1 {
        let (mut conn, from) = listener.accept()?;
        conn.set_read_timeout(Some(Duration::from_secs(30)))?;
        match read_frame(&mut conn) {
            Ok(Frame::Join { addr }) => {
                eprintln!("node: joiner {} will be id {} (listens on {addr})", from, joiners.len() + 1);
                joiners.push((conn, addr));
            }
            Ok(other) => {
                log_warn!("node: bootstrap expected Join from {from}, got {other:?}; dropping");
            }
            Err(e) => {
                log_warn!("node: bootstrap read from {from} failed: {e}; dropping");
            }
        }
    }
    let mut roster: Vec<(usize, String)> = vec![(0, seed_addr.to_string())];
    for (i, (_, addr)) in joiners.iter().enumerate() {
        roster.push((i + 1, addr.clone()));
    }
    let peers = Frame::Peers {
        peers: roster.iter().map(|(id, a)| (*id as u32, a.clone())).collect(),
    };
    for (i, (mut conn, _)) in joiners.into_iter().enumerate() {
        write_frame(&mut conn, &Frame::Welcome(wl.welcome((i + 1) as u32)))?;
        write_frame(&mut conn, &peers)?;
        // The bootstrap connection's job is done; the run uses fresh
        // writer-owned connections in both directions.
    }
    Ok(roster)
}

/// Joiner side: connect to the seed (with retry/backoff until
/// `timeout` — the seed may not be up yet), announce our listen
/// address, and collect the `Welcome` + roster.
pub fn join_bootstrap(
    seed_addr: &str,
    my_addr: &str,
    timeout: Duration,
) -> io::Result<(Welcome, Vec<(usize, String)>)> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(10);
    let mut conn = loop {
        match TcpStream::connect(seed_addr) {
            Ok(c) => break c,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    };
    // The seed replies only once the whole cluster has dialed in; give
    // slow sibling processes a generous window.
    conn.set_read_timeout(Some(Duration::from_secs(120)))?;
    write_frame(&mut conn, &Frame::Join { addr: my_addr.to_string() })?;
    let welcome = match read_frame(&mut conn)? {
        Frame::Welcome(w) => w,
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bootstrap expected Welcome, got {other:?}"),
            ))
        }
    };
    let peers = match read_frame(&mut conn)? {
        Frame::Peers { peers } => {
            peers.into_iter().map(|(id, a)| (id as usize, a)).collect()
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bootstrap expected Peers, got {other:?}"),
            ))
        }
    };
    Ok((welcome, peers))
}

// ---------------------------------------------------------------------------
// Monitor endpoint
// ---------------------------------------------------------------------------

/// Minimal HTTP endpoint serving one JSON document — ring topology and
/// live engine counters. Any `GET` gets the current snapshot; the CI
/// cluster-smoke job curls it and asserts `dropped_deltas == 0`.
pub struct Monitor {
    addr: SocketAddr,
    state: Arc<Mutex<String>>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Monitor {
    /// Bind and start serving. Port 0 picks a free port; the real
    /// address is [`addr`](Self::addr).
    pub fn serve(listen: &str) -> io::Result<Monitor> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(Mutex::new(
            obj(vec![("status", Json::Str("starting".to_string()))]).to_string(),
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(mut conn) = conn else { continue };
                    let body = state.lock().unwrap().clone();
                    // Consume (and ignore) the request head — every
                    // path serves the same document.
                    let _ = conn.set_read_timeout(Some(Duration::from_millis(500)));
                    let mut scratch = [0u8; 1024];
                    let _ = conn.read(&mut scratch);
                    let resp = format!(
                        "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                        body.len(),
                        body
                    );
                    let _ = conn.write_all(resp.as_bytes());
                }
            })
        };
        Ok(Monitor { addr, state, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port-0 binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Swap the served document.
    pub fn set(&self, doc: &Json) {
        *self.state.lock().unwrap() = doc.to_string();
    }
}

impl Drop for Monitor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop so it can observe the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Live membership verdicts for the monitor document, so the chaos CI
/// job can assert *detection*, not just completion.
#[derive(Debug, Clone, Default)]
pub struct MembershipStatus {
    pub alive: Vec<usize>,
    pub suspect: Vec<usize>,
    pub confirmed_dead: Vec<usize>,
    pub repair_msgs: u64,
    pub repaired_rumors: u64,
    pub suspect_notices: u64,
}

/// The monitor document for one node: identity, ring order, step table
/// and the report counters the smoke gate asserts on. The `membership`
/// key appears only when the detector is running.
pub fn status_json(
    status: &str,
    cfg: &NodeConfig,
    ring: &Ring,
    report: &EngineReport,
    applied_of: &[u32],
    membership: Option<&MembershipStatus>,
) -> Json {
    let mut order: Vec<(u64, usize)> = (0..cfg.n)
        .filter_map(|i| ring.ring_id_of(i).map(|rid| (rid, i)))
        .collect();
    order.sort_unstable();
    let ids = |v: &[usize]| Json::Arr(v.iter().map(|&i| Json::Num(i as f64)).collect());
    let mut doc = vec![
        ("status", Json::Str(status.to_string())),
        ("id", Json::Num(cfg.id as f64)),
        ("n", Json::Num(cfg.n as f64)),
        ("ring", Json::Arr(order.iter().map(|&(_, i)| Json::Num(i as f64)).collect())),
        ("steps", Json::Arr(report.steps.iter().map(|&s| Json::Num(s as f64)).collect())),
        (
            "applied_of",
            Json::Arr(applied_of.iter().map(|&c| Json::Num(c as f64)).collect()),
        ),
        (
            "report",
            obj(vec![
                ("update_msgs", Json::Num(report.update_msgs as f64)),
                ("control_msgs", Json::Num(report.control_msgs as f64)),
                ("applied_rumors", Json::Num(report.applied_rumors as f64)),
                ("dup_rumors", Json::Num(report.dup_rumors as f64)),
                ("rumor_copies", Json::Num(report.rumor_copies as f64)),
                ("dropped_deltas", Json::Num(report.dropped_deltas as f64)),
                ("missing_rumors", Json::Num(report.missing_rumors as f64)),
                ("discarded_msgs", Json::Num(report.discarded_msgs as f64)),
                ("drain_polls", Json::Num(report.drain_polls as f64)),
                ("wall_secs", Json::Num(report.wall_secs)),
            ]),
        ),
        (
            "barrier",
            obj(vec![
                ("method", Json::Str(format!("{}", cfg.method))),
                (
                    "adaptive",
                    Json::Bool(
                        BarrierPolicy::with_adaptive(cfg.method, cfg.adaptive)
                            .is_adaptive(),
                    ),
                ),
                ("barrier_waits", Json::Num(report.barrier_waits as f64)),
                ("stall_ticks", Json::Num(report.stall_ticks as f64)),
                // ASP's unbounded staleness (u64::MAX) is encoded as -1:
                // JSON numbers are f64 and would mangle the sentinel.
                (
                    "eff_staleness",
                    Json::Arr(
                        report
                            .eff_staleness
                            .iter()
                            .map(|&s| {
                                if s == u64::MAX {
                                    Json::Num(-1.0)
                                } else {
                                    Json::Num(s as f64)
                                }
                            })
                            .collect(),
                    ),
                ),
                (
                    "eff_sample",
                    Json::Arr(
                        report
                            .eff_sample
                            .iter()
                            .map(|&b| Json::Num(b as f64))
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "compress",
            obj(vec![
                ("mode", Json::Str(report.compress_mode.to_string())),
                ("payload_bytes", Json::Num(report.payload_bytes as f64)),
                ("fed_back_mass", Json::Num(report.fed_back_mass)),
            ]),
        ),
    ];
    if let Some(ms) = membership {
        doc.push((
            "membership",
            obj(vec![
                ("alive", ids(&ms.alive)),
                ("suspect", ids(&ms.suspect)),
                ("confirmed_dead", ids(&ms.confirmed_dead)),
                ("repair_msgs", Json::Num(ms.repair_msgs as f64)),
                ("repaired_rumors", Json::Num(ms.repaired_rumors as f64)),
                ("suspect_notices", Json::Num(ms.suspect_notices as f64)),
            ]),
        ));
    }
    obj(doc)
}

// ---------------------------------------------------------------------------
// Node runtime
// ---------------------------------------------------------------------------

/// What a finished node hands back: the standard engine report plus the
/// per-origin applied-rumor counts — the signature the equivalence
/// tests diff across transports (channel vs TCP must match exactly).
#[derive(Debug, Clone)]
pub struct NodeOutcome {
    pub report: EngineReport,
    /// `applied_of[o]` = distinct rumors of origin `o` this node
    /// applied (own originations included).
    pub applied_of: Vec<u32>,
}

/// Mutable node state, factored out so the frame handler and the main
/// loop borrow disjoint fields without closure gymnastics.
struct NodeState {
    me: usize,
    n: usize,
    gossip: GossipNode,
    /// The single admission authority for this node. With adaptation
    /// off its decisions are value-identical to the legacy inline
    /// per-method match (and the quorum fraction follows the barrier
    /// trait's real-valued predicate, not integer-percent arithmetic).
    policy: BarrierPolicy,
    ring: Ring,
    w: Vec<f32>,
    /// Last known completed-step count per peer (fed by `Step` frames).
    steps_done: Vec<u64>,
    /// Max beat seen per peer — distinguishes fresh announcements from
    /// reconnect resends in debug logs; merging is max on both fields.
    beats: Vec<u64>,
    /// `Some(count)` once origin announced its final origination count
    /// (via `Done`, `Leave`, or a custodian `Repair`).
    expected: Vec<Option<u32>>,
    update_msgs: u64,
    control_msgs: u64,
    discarded_msgs: u64,
    /// SWIM-style suspect/confirm timers over the beat table; `None`
    /// when the membership plane is off.
    detector: Option<FailureDetector>,
    /// Dead origins whose custodian count has not arrived yet — each
    /// holds the drain open exactly like an unannounced `Done`.
    repair_pending: Vec<bool>,
    /// Latch so each suspect transition broadcasts once per episode,
    /// not once per detector pass.
    announced_suspect: Vec<bool>,
    confirmed_dead: u64,
    repair_msgs: u64,
    repaired_rumors: u64,
    suspect_notices: u64,
    /// Next observation pass, in µs since `t0` — passes are throttled
    /// to `detect_every` so the timer sweep is not a per-frame cost.
    next_detect: u64,
    detect_every: u64,
    /// Turns this node's dense pending deltas into wire payloads; in
    /// `Dense` mode the output is bit-identical to the legacy path.
    encoder: DeltaEncoder,
    t0: Instant,
}

impl NodeState {
    /// Detector clock: µs since this node started.
    fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    fn handle<T: Transport>(&mut self, frame: Frame, transport: &mut T) {
        match frame {
            Frame::Peer(PeerMsg::Gossip { rumors }) => {
                let w = &mut self.w;
                self.gossip.receive(rumors, |r| r.delta.apply_into(w));
            }
            Frame::Peer(PeerMsg::Delta { delta }) => delta.apply_into(&mut self.w),
            Frame::Peer(PeerMsg::Done { from, rumors }) => {
                let from = from as usize;
                self.expected[from] = Some(rumors);
                self.repair_pending[from] = false;
                let now = self.now_us();
                let was_dead =
                    self.detector.as_mut().is_some_and(|det| det.alive(from, now));
                if was_dead {
                    // Our confirmation was a false positive — the peer
                    // finished normally. Restore its ring position and
                    // writer, and re-seed its chain edge: it missed
                    // every flush routed around it.
                    self.announced_suspect[from] = false;
                    self.ring.join(from);
                    transport.revive_peer(from);
                    self.reseed_successor(from, transport);
                }
            }
            Frame::Peer(PeerMsg::Leave { from, rumors }) => {
                let from = from as usize;
                self.expected[from] = Some(rumors);
                self.repair_pending[from] = false;
                // The leaver handed its store to its successor itself;
                // we only repair our own chain edge if we owned it.
                self.evict_dead(from, false, transport);
            }
            Frame::Peer(PeerMsg::Repair { origin, rumors, store }) => {
                // A custodian re-announcing for a dead origin: stands in
                // for the Done the origin never sent. Max-merge — under
                // multi-crash a second custodian may know strictly more.
                let o = origin as usize;
                let e = &mut self.expected[o];
                *e = Some(e.map_or(rumors, |c| c.max(rumors)));
                self.repair_pending[o] = false;
                // A custody announcement doubles as a death notice:
                // evict without waiting for our own timers (no second
                // custody take — the sender already claimed it).
                if self.detector.as_mut().is_some_and(|det| det.declare_dead(o)) {
                    self.confirmed_dead += 1;
                    self.evict_dead(o, false, transport);
                }
                let w = &mut self.w;
                let repaired = &mut self.repaired_rumors;
                self.gossip.receive(store, |r| {
                    *repaired += 1;
                    r.delta.apply_into(w);
                });
            }
            Frame::Step { from, step, beat } => {
                let i = from as usize;
                if i < self.n {
                    self.steps_done[i] = self.steps_done[i].max(step);
                    self.beats[i] = self.beats[i].max(beat);
                } else {
                    self.discarded_msgs += 1;
                }
            }
            Frame::Suspect { from, peer } => {
                // Informational only: another observer's suspicion. Our
                // own timers decide; the notice is surfaced for
                // operators (and the chaos test) via the monitor.
                let _ = (from, peer);
                self.suspect_notices += 1;
            }
            Frame::Confirm { from, peer } => {
                // Adopt a peer's confirm verdict so the whole cluster
                // converges at roughly one detector's cost instead of
                // n staggered detections.
                let p = peer as usize;
                if p == self.me {
                    log_warn!(
                        "node {}: peer {from} confirmed us dead; ignoring — we are visibly alive",
                        self.me
                    );
                    self.discarded_msgs += 1;
                } else if p < self.n && self.expected[p].is_none() {
                    let changed =
                        self.detector.as_mut().is_some_and(|det| det.declare_dead(p));
                    if changed {
                        self.confirmed_dead += 1;
                        self.repair_pending[p] = true;
                        self.evict_dead(p, true, transport);
                    }
                }
            }
            other @ (Frame::Join { .. } | Frame::Welcome(_) | Frame::Peers { .. }) => {
                log_warn!("node {}: bootstrap frame after bootstrap: {other:?}", self.me);
                self.discarded_msgs += 1;
            }
        }
    }

    /// Re-send the custody store to `peer` if it is (again) our chain
    /// successor — it missed every chain flush we routed around it.
    fn reseed_successor<T: Transport>(&mut self, peer: usize, transport: &T) {
        if self.ring.successor_node(self.me) == Some(peer) {
            let rumors = self.gossip.handoff_rumors();
            if !rumors.is_empty()
                && transport.send(peer, Frame::Peer(PeerMsg::Gossip { rumors }))
            {
                self.repair_msgs += 1;
                self.update_msgs += 1;
            }
        }
    }

    /// Evict a departed or confirmed-dead node from the local view,
    /// take over whatever repair roles the eviction assigns, and tear
    /// down the transport writer so nobody reconnect-spins at a corpse.
    fn evict_dead<T: Transport>(&mut self, dead: usize, may_take_custody: bool, transport: &mut T) {
        match evict_from_view(&mut self.ring, self.me, dead) {
            None => {
                // Already out of the view (e.g. a re-confirm raced a
                // Leave): nothing to repair, nothing to hold the drain.
                self.repair_pending[dead] = false;
            }
            Some(out) => {
                if may_take_custody && out.custodian {
                    // Custody repair: the dead origin's flushes hit us
                    // first (per-peer FIFO), so our applied count is
                    // exactly what it ever announced. Stand in for its
                    // Done and re-inject the rumors for everyone who
                    // missed them.
                    let origin = dead as u32;
                    let count = self.gossip.applied_count(origin);
                    let e = &mut self.expected[dead];
                    *e = Some(e.map_or(count, |c| c.max(count)));
                    self.repair_pending[dead] = false;
                    let store = self.gossip.rumors_of(origin);
                    for j in 0..self.n {
                        if j != self.me
                            && j != dead
                            && transport.send(
                                j,
                                Frame::Peer(PeerMsg::Repair {
                                    origin,
                                    rumors: count,
                                    store: store.clone(),
                                }),
                            )
                        {
                            self.repair_msgs += 1;
                        }
                    }
                }
                if let Some(succ) = out.lost_successor {
                    // Successor repair: everything we ever applied goes
                    // to the node now clockwise of the gap; it dedups
                    // and relays the fresh remainder, restoring the
                    // chain's relay invariant.
                    let rumors = self.gossip.handoff_rumors();
                    if !rumors.is_empty()
                        && transport.send(succ, Frame::Peer(PeerMsg::Gossip { rumors }))
                    {
                        self.repair_msgs += 1;
                        self.update_msgs += 1;
                    }
                }
            }
        }
        transport.evict_peer(dead);
    }

    /// One throttled detector pass over the beat table. `force` skips
    /// the throttle — the drain's death-excused exit uses it to make
    /// sure no heartbeat arrived since the last scheduled pass.
    fn membership_tick<T: Transport>(&mut self, transport: &mut T, force: bool) {
        if self.detector.is_none() {
            return;
        }
        let now = self.now_us();
        if !force && now < self.next_detect {
            return;
        }
        self.next_detect = now + self.detect_every;
        let obs = {
            let beats = &self.beats;
            let expected = &self.expected;
            let det = self.detector.as_mut().expect("membership on");
            det.observe(now, |j| beats[j], |j| expected[j].is_some())
        };
        // Broadcast each fresh suspect transition once: informational,
        // but it lets operators and tests watch detection in flight.
        for j in 0..self.n {
            if j == self.me {
                continue;
            }
            match self.detector.as_ref().map(|d| d.state(j)) {
                Some(PeerState::Suspect) if !self.announced_suspect[j] => {
                    self.announced_suspect[j] = true;
                    for peer in 0..self.n {
                        if peer != self.me
                            && peer != j
                            && transport
                                .send(peer, Frame::Suspect { from: self.me as u32, peer: j as u32 })
                        {
                            self.control_msgs += 1;
                        }
                    }
                }
                Some(PeerState::Alive) => self.announced_suspect[j] = false,
                _ => {}
            }
        }
        for d in obs.dead {
            self.confirmed_dead += 1;
            // Until a custodian announces the dead origin's count we do
            // not know what we are owed — hold the drain open.
            self.repair_pending[d] = self.expected[d].is_none();
            for peer in 0..self.n {
                if peer != self.me
                    && peer != d
                    && transport.send(peer, Frame::Confirm { from: self.me as u32, peer: d as u32 })
                {
                    self.control_msgs += 1;
                }
            }
            self.evict_dead(d, true, transport);
        }
        for r in obs.resurrected {
            // False positive: restore the ring position and the writer,
            // and if the revived peer is our successor again it missed
            // every chain flush we routed around it — re-send the store.
            self.announced_suspect[r] = false;
            self.ring.join(r);
            transport.revive_peer(r);
            self.reseed_successor(r, transport);
        }
    }

    /// Exact drain-exit condition: every origin accounted for — its own
    /// `Done`/`Leave` count met, or a confirmed death whose custodian
    /// count has arrived and been met — with no repair still pending.
    fn drained(&self) -> bool {
        (0..self.n).all(|o| match self.expected[o] {
            Some(c) => self.gossip.applied_count(o as u32) >= c,
            None => self.detector.as_ref().is_some_and(|d| d.is_dead(o)),
        }) && self.repair_pending.iter().all(|&p| !p)
    }

    /// Live membership snapshot for the monitor; `None` when off.
    fn membership_status(&self) -> Option<MembershipStatus> {
        let det = self.detector.as_ref()?;
        let mut ms = MembershipStatus {
            repair_msgs: self.repair_msgs,
            repaired_rumors: self.repaired_rumors,
            suspect_notices: self.suspect_notices,
            ..MembershipStatus::default()
        };
        for j in 0..self.n {
            if j == self.me {
                ms.alive.push(j);
                continue;
            }
            match det.state(j) {
                PeerState::Alive => ms.alive.push(j),
                PeerState::Suspect => ms.suspect.push(j),
                PeerState::Dead => ms.confirmed_dead.push(j),
            }
        }
        Some(ms)
    }

    /// Flush queued gossip batches onto the wire.
    fn flush_gossip<T: Transport>(&mut self, cfg: &GossipConfig, rng: &mut Rng, transport: &T) {
        for (dst, rumors) in self.gossip.flush(cfg, &self.ring, rng) {
            if transport.send(dst, Frame::Peer(PeerMsg::Gossip { rumors })) {
                self.update_msgs += 1;
            }
        }
    }

    /// A peer's step count as the barrier sees it: a peer that already
    /// announced its final origination count — or one the detector
    /// confirmed dead — can never block anyone. (`bsp`/`ssp` read the
    /// full table, so without the dead-exemption one corpse would pin
    /// every survivor at its last step forever.)
    fn view(&self, j: usize) -> u64 {
        if self.expected[j].is_some()
            || self.detector.as_ref().is_some_and(|d| d.is_dead(j))
        {
            u64::MAX
        } else {
            self.steps_done[j]
        }
    }

    /// Can this node start computing step `my_step`? Returns the pass
    /// verdict and the overlay routing messages the sample cost. The
    /// decision itself is the policy's; this method only gathers the
    /// view — full step table for global methods, an overlay sample for
    /// the probabilistic family.
    fn barrier_pass(&mut self, my_step: u64, rng: &mut Rng) -> (bool, u64) {
        let (pass, lag, msgs) = match self.policy.view() {
            ViewRequirement::None => (true, None, 0),
            ViewRequirement::Global => {
                let steps: Vec<u64> = (0..self.n)
                    .filter(|&j| j != self.me)
                    .map(|j| self.view(j))
                    .collect();
                let lag =
                    steps.iter().min().map(|&m| my_step.saturating_sub(m));
                (self.policy.admit_view(my_step, &steps), lag, 0)
            }
            ViewRequirement::Sample(beta) => {
                let (peers, msgs) = self.ring.sample_nodes(self.me, beta, rng);
                let steps: Vec<u64> =
                    peers.iter().map(|&j| self.view(j)).collect();
                let lag =
                    steps.iter().min().map(|&m| my_step.saturating_sub(m));
                (self.policy.admit_view(my_step, &steps), lag, msgs)
            }
        };
        self.policy.record_decision(pass, lag);
        (pass, msgs)
    }
}

/// Run one deployed node to completion: compute `cfg.steps` SGD steps
/// under the configured barrier, disseminating deltas over the gossip
/// plane carried by `transport`, then drain until every announced rumor
/// of every origin has been applied (or `drain_timeout` fires — losses
/// are loud, never silent).
pub fn run_node<T: Transport>(
    cfg: &NodeConfig,
    transport: &mut T,
    grad_fn: GradFn,
    monitor: Option<&Monitor>,
) -> NodeOutcome {
    assert_eq!(cfg.id, transport.me(), "config/transport id mismatch");
    assert_eq!(cfg.n, transport.n(), "config/transport size mismatch");
    assert!(cfg.n >= 1 && cfg.id < cfg.n);
    let t0 = Instant::now();
    let me = cfg.id;
    let n = cfg.n;
    // Same fork recipe as the sim engines' per-worker RNGs: cluster
    // seed spread by the golden ratio, xor'd with the node id.
    let wseed = cfg.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ me as u64;
    let mut rng = Rng::new(wseed);
    // With membership on, the store is the crash-tolerance memory
    // trade: every rumor is pinned for the run so a custodian can
    // re-inject a dead origin's history (same trade as the p2p engine).
    let gossip = if cfg.membership.is_some() {
        GossipNode::with_handoff_store(me, n)
    } else {
        GossipNode::new(me, n)
    };
    let mut st = NodeState {
        me,
        n,
        gossip,
        policy: BarrierPolicy::with_adaptive(cfg.method, cfg.adaptive),
        ring: Ring::with_nodes(n, cfg.seed),
        w: vec![0.0; cfg.dim],
        steps_done: vec![0; n],
        beats: vec![0; n],
        expected: vec![None; n],
        update_msgs: 0,
        control_msgs: 0,
        discarded_msgs: 0,
        detector: cfg
            .membership
            .as_ref()
            .map(|mc| FailureDetector::new(me, n, 0, mc.clone())),
        repair_pending: vec![false; n],
        announced_suspect: vec![false; n],
        confirmed_dead: 0,
        repair_msgs: 0,
        repaired_rumors: 0,
        suspect_notices: 0,
        next_detect: 0,
        // Observation passes at a quarter of the suspect threshold:
        // often enough that detection latency is timer-dominated, rare
        // enough that the sweep is not a per-frame cost.
        detect_every: cfg
            .membership
            .as_ref()
            .map_or(u64::MAX, |mc| (mc.suspect_after / 4).clamp(1, 50_000)),
        encoder: DeltaEncoder::new(cfg.compress, cfg.dim),
        t0,
    };
    let gcfg = cfg.gossip.clone();
    let flush_every = gcfg.flush_every.max(1);
    let mut pending = vec![0.0f32; cfg.dim];
    let mut step: u64 = 0;
    let mut beat: u64 = 0;

    let broadcast_step =
        |st: &mut NodeState, transport: &T, step: u64, beat: u64| {
            for peer in 0..n {
                if peer != me && transport.send(peer, Frame::Step { from: me as u32, step, beat }) {
                    st.control_msgs += 1;
                }
            }
        };

    beat += 1;
    broadcast_step(&mut st, transport, 0, beat);
    let mut last_announce = Instant::now();
    // Wait/busy bookkeeping for the policy's adaptation window: the
    // barrier for a step opens at its first admission check and closes
    // at the pass; everything since the previous pass is compute.
    let mut iter_started = Instant::now();
    let mut barrier_entered: Option<Instant> = None;

    while step < cfg.steps {
        if cfg.crash_at == Some(step) {
            // Crash-stop: no flush, no Done, no drain — returning here
            // is the silence survivors must detect and repair around.
            log_warn!("node {me}: crash-stop at step {step} (scripted)");
            let report = interim_report(&st, t0, 0);
            let applied_of: Vec<u32> =
                (0..n).map(|o| st.gossip.applied_count(o as u32)).collect();
            if let Some(m) = monitor {
                m.set(&status_json(
                    "crashed", cfg, &st.ring, &report, &applied_of,
                    st.membership_status().as_ref(),
                ));
            }
            return NodeOutcome { report, applied_of };
        }
        while let Some(f) = transport.try_recv() {
            st.handle(f, transport);
        }
        // Ingest before detecting: a confirmation must never be based
        // on older knowledge than the queue holds — a custodian that
        // confirmed with the dead origin's final flush still queued
        // would broadcast an undercounted Repair.
        st.membership_tick(transport, false);
        let entered = *barrier_entered.get_or_insert_with(Instant::now);
        let (pass, sample_msgs) = st.barrier_pass(step, &mut rng);
        st.control_msgs += sample_msgs;
        if !pass {
            if let Some(f) = transport.recv_timeout(Duration::from_millis(2)) {
                st.handle(f, transport);
            }
            // Relay anything a received batch queued even while parked,
            // or the cluster can deadlock waiting on our shortcuts.
            st.flush_gossip(&gcfg, &mut rng, transport);
            st.membership_tick(transport, false);
            if last_announce.elapsed() >= STEP_REANNOUNCE {
                beat += 1;
                broadcast_step(&mut st, transport, step, beat);
                last_announce = Instant::now();
            }
            continue;
        }
        st.policy.record_crossing(
            entered.elapsed().as_secs_f64(),
            entered.duration_since(iter_started).as_secs_f64(),
        );
        barrier_entered = None;
        iter_started = Instant::now();

        if !cfg.step_pad.is_zero() {
            // Synthetic compute: pins run duration for the chaos demos.
            std::thread::sleep(cfg.step_pad);
        }
        let g = grad_fn(&st.w, wseed.wrapping_add(step));
        for d in 0..cfg.dim {
            let delta = -cfg.lr * g[d];
            st.w[d] += delta;
            pending[d] += delta;
        }
        step += 1;
        st.steps_done[me] = step;

        if step % flush_every == 0 || step == cfg.steps {
            let delta = std::mem::replace(&mut pending, vec![0.0; cfg.dim]);
            let payload = st.encoder.encode(delta);
            st.gossip.originate(payload, &gcfg);
            st.flush_gossip(&gcfg, &mut rng, transport);
        }
        beat += 1;
        broadcast_step(&mut st, transport, step, beat);
        last_announce = Instant::now();

        if let Some(m) = monitor {
            if step % 16 == 0 || step == cfg.steps {
                let snap = interim_report(&st, t0, 0);
                let applied: Vec<u32> =
                    (0..n).map(|o| st.gossip.applied_count(o as u32)).collect();
                m.set(&status_json(
                    "running", cfg, &st.ring, &snap, &applied,
                    st.membership_status().as_ref(),
                ));
            }
        }
    }

    // Announce our exact origination count so every peer's drain can
    // terminate deterministically, then drain ourselves.
    let announced = st.gossip.originated();
    st.expected[me] = Some(announced);
    for peer in 0..n {
        if peer != me
            && transport.send(peer, Frame::Peer(PeerMsg::Done { from: me as u32, rumors: announced }))
        {
            st.control_msgs += 1;
        }
    }

    let deadline = Instant::now() + cfg.drain_timeout;
    let mut drain_polls: u64 = 0;
    let mut timed_out = false;
    loop {
        if st.drained() {
            let excused = (0..n).any(|o| st.expected[o].is_none());
            if excused && st.detector.is_some() {
                // About to exit on a death excuse: run one ungated
                // observation first — a heartbeat since the last
                // throttled pass disproves the confirmation, and the
                // drain must keep waiting for the real Done.
                st.membership_tick(transport, true);
                if st.drained() {
                    break;
                }
            } else {
                break;
            }
        }
        let now = Instant::now();
        if now >= deadline {
            timed_out = true;
            break;
        }
        // Same clamp as the p2p engine: near the deadline recv_timeout
        // would degenerate to a hot spin without a floor. With the
        // detector on, also cap the wait — the drain is where crash
        // confirmation usually lands, so it must wake for the timers.
        let mut wait = (deadline - now).max(MIN_DRAIN_POLL);
        if st.detector.is_some() {
            wait = wait.min(Duration::from_millis(20));
        }
        drain_polls += 1;
        if let Some(f) = transport.recv_timeout(wait) {
            st.handle(f, transport);
            while let Some(f) = transport.try_recv() {
                st.handle(f, transport);
            }
            st.flush_gossip(&gcfg, &mut rng, transport);
        }
        st.membership_tick(transport, false);
    }

    let mut missing_rumors: u64 = 0;
    let mut discarded: u64 = st.discarded_msgs;
    if timed_out {
        for o in 0..n {
            match st.expected[o] {
                Some(c) => {
                    missing_rumors += u64::from(c.saturating_sub(st.gossip.applied_count(o as u32)))
                }
                None if st.detector.as_ref().is_some_and(|d| d.is_dead(o)) => log_warn!(
                    "node {me}: drain timed out awaiting custody repair for dead origin {o}"
                ),
                None => log_warn!(
                    "node {me}: drain timed out with no Done from {o}; its rumor count is unknown"
                ),
            }
        }
        while transport.try_recv().is_some() {
            discarded += 1;
        }
        log_warn!(
            "node {me}: drain safety-net fired after {:?} — {missing_rumors} rumors missing, {discarded} messages discarded",
            cfg.drain_timeout
        );
    }

    let mut report = interim_report(&st, t0, drain_polls);
    report.missing_rumors = missing_rumors;
    report.discarded_msgs = discarded;
    report.dropped_deltas = missing_rumors.max(discarded);
    let applied_of: Vec<u32> = (0..n).map(|o| st.gossip.applied_count(o as u32)).collect();
    if let Some(m) = monitor {
        m.set(&status_json(
            "done", cfg, &st.ring, &report, &applied_of,
            st.membership_status().as_ref(),
        ));
    }
    NodeOutcome { report, applied_of }
}

/// The report as far as `st` can tell; loss fields are filled by the
/// caller once the drain verdict is known.
fn interim_report(st: &NodeState, t0: Instant, drain_polls: u64) -> EngineReport {
    EngineReport {
        steps: st.steps_done.clone(),
        update_msgs: st.update_msgs,
        control_msgs: st.control_msgs + st.gossip.route_msgs,
        wall_secs: t0.elapsed().as_secs_f64(),
        model: st.w.clone(),
        applied_rumors: st.gossip.applied_rumors,
        dup_rumors: st.gossip.dup_rumors,
        rumor_copies: st.gossip.rumor_copies,
        drain_polls,
        confirmed_dead: st.confirmed_dead,
        repair_msgs: st.repair_msgs,
        repaired_rumors: st.repaired_rumors,
        barrier_waits: st.policy.stats().barrier_waits,
        stall_ticks: st.policy.stats().stall_ticks,
        eff_staleness: vec![st.policy.staleness()],
        eff_sample: vec![st.policy.sample_size() as u64],
        compress_mode: st.encoder.config().mode_str(),
        payload_bytes: st.encoder.payload_bytes,
        fed_back_mass: st.encoder.fed_back_mass,
        // Everyone no longer in our overlay view: graceful leavers and
        // confirmed-dead peers alike.
        departed: (0..st.n).filter(|&j| st.ring.ring_id_of(j).is_none()).collect(),
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::transport::ChannelTransport;
    use std::sync::Arc;

    fn test_workload(n: usize, steps: u64, method: Method) -> Workload {
        Workload {
            n,
            steps,
            dim: 8,
            lr: 0.1,
            seed: 42,
            method,
            gossip: GossipConfig { fanout: 2, flush_every: 1, ttl: 4 },
            compress: CompressConfig::default(),
            drain_timeout: Duration::from_secs(10),
            membership: None,
        }
    }

    fn seed_only_grad() -> GradFn {
        Arc::new(|w: &[f32], seed: u64| {
            let mut rng = Rng::new(seed);
            (0..w.len()).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
        })
    }

    fn run_cluster(wl: &Workload) -> Vec<NodeOutcome> {
        let transports = ChannelTransport::cluster(wl.n);
        let mut handles = Vec::new();
        for (id, mut tr) in transports.into_iter().enumerate() {
            let cfg = wl.node_config(id);
            let grad = seed_only_grad();
            handles.push(std::thread::spawn(move || {
                run_node(&cfg, &mut tr, grad, None)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("node thread")).collect()
    }

    #[test]
    fn channel_cluster_drains_with_zero_losses_under_pssp() {
        let wl = test_workload(4, 12, Method::Pssp { sample: 2, staleness: 2 });
        let outs = run_cluster(&wl);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.report.dropped_deltas, 0, "node {i} dropped deltas");
            assert_eq!(o.report.missing_rumors, 0, "node {i} missing rumors");
            // Every node applied every origin's full origination run.
            assert_eq!(o.applied_of, outs[0].applied_of, "node {i} applied_of diverges");
            assert_eq!(o.applied_of.iter().map(|&c| c as u64).sum::<u64>(), 4 * 12);
        }
    }

    #[test]
    fn channel_cluster_converges_under_bsp_lockstep() {
        // bsp over the broadcast step table: no node may ever lead by
        // more than one step, and all finish all steps.
        let wl = test_workload(3, 8, Method::Bsp);
        let outs = run_cluster(&wl);
        for o in &outs {
            assert_eq!(o.report.dropped_deltas, 0);
            assert_eq!(o.applied_of.iter().map(|&c| c as u64).sum::<u64>(), 3 * 8);
        }
    }

    #[test]
    fn flush_cadence_batches_originations() {
        // flush_every=3 over 7 steps -> originations at steps 3, 6, 7.
        let wl = Workload {
            gossip: GossipConfig { fanout: 1, flush_every: 3, ttl: 4 },
            ..test_workload(2, 7, Method::Asp)
        };
        let outs = run_cluster(&wl);
        for o in &outs {
            assert_eq!(o.applied_of, vec![3, 3]);
            assert_eq!(o.report.dropped_deltas, 0);
        }
    }

    #[test]
    fn welcome_round_trips_the_workload() {
        let wl = test_workload(5, 20, Method::Pquorum { sample: 3, staleness: 1, quorum_pct: 80 });
        let w = wl.welcome(3);
        assert_eq!(w.id, 3);
        assert_eq!(w.method, "pquorum:3:1:80");
        let back = Workload::from_welcome(&w, wl.drain_timeout).expect("parses");
        assert_eq!(back.n, wl.n);
        assert_eq!(back.steps, wl.steps);
        assert_eq!(back.dim, wl.dim);
        assert_eq!(back.method, wl.method);
        assert_eq!(back.gossip.fanout, wl.gossip.fanout);
        // Membership timing rides the Welcome; off encodes as 0/0.
        assert_eq!((w.suspect_us, w.confirm_us), (0, 0));
        assert!(back.membership.is_none());
        assert!(Workload::from_welcome(
            &Welcome { method: "warp-speed".into(), ..w },
            wl.drain_timeout
        )
        .is_none());
        let mut mwl = wl.clone();
        mwl.membership =
            Some(MembershipConfig { suspect_after: 250_000, confirm_after: 125_000 });
        let mw = mwl.welcome(1);
        assert_eq!((mw.suspect_us, mw.confirm_us), (250_000, 125_000));
        let mback = Workload::from_welcome(&mw, mwl.drain_timeout).expect("parses");
        let mc = mback.membership.expect("membership survives the round trip");
        assert_eq!(mc.suspect_after, 250_000);
        assert_eq!(mc.confirm_after, 125_000);
        // Compression rides the Welcome as (tag, top_k); an unknown tag
        // is a version-skewed seed and must be refused, not guessed.
        let mut cwl = wl.clone();
        cwl.compress = CompressConfig::parse("topk", 12, "i8").expect("valid mode");
        let cw = cwl.welcome(2);
        assert_eq!((cw.compress, cw.top_k), (1, 12));
        let cback = Workload::from_welcome(&cw, cwl.drain_timeout).expect("parses");
        assert_eq!(cback.compress, cwl.compress);
        assert!(
            Workload::from_welcome(&Welcome { compress: 9, ..cw }, cwl.drain_timeout).is_none()
        );
    }

    #[test]
    fn compressed_cluster_drains_cleanly_and_cuts_payload_bytes() {
        // Same workload, dense vs top-k originations: the compressed run
        // must still drain with zero losses, report its mode and the
        // error-feedback mass, and ship ≥4× fewer payload bytes.
        let mut dense = test_workload(3, 12, Method::Pssp { sample: 2, staleness: 2 });
        dense.dim = 32;
        let mut topk = dense.clone();
        topk.compress = CompressConfig::parse("topk", 2, "i8").expect("valid mode");
        let d: u64 = run_cluster(&dense).iter().map(|o| o.report.payload_bytes).sum();
        let outs = run_cluster(&topk);
        let c: u64 = outs.iter().map(|o| o.report.payload_bytes).sum();
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.report.dropped_deltas, 0, "node {i} dropped deltas");
            assert_eq!(o.report.compress_mode, "topk", "node {i} mislabeled its mode");
            assert!(o.report.fed_back_mass > 0.0, "node {i} never carried a residual");
        }
        assert!(d > 0 && c > 0, "payload accounting never ran (dense {d}, topk {c})");
        assert!(c * 4 <= d, "top-k payload bytes {c} are not >=4x under dense {d}");
    }

    #[test]
    fn channel_cluster_survives_a_crash_via_membership_repair() {
        // One node crash-stops mid-run; survivors must confirm it dead,
        // repair its rumors via the custodian, and drain losslessly in
        // ~suspect+confirm — far under the drain timeout.
        let victim = 2usize;
        let mut wl = test_workload(3, 30, Method::Pssp { sample: 2, staleness: 3 });
        wl.membership =
            Some(MembershipConfig { suspect_after: 80_000, confirm_after: 80_000 });
        wl.drain_timeout = Duration::from_secs(30);
        let t0 = std::time::Instant::now();
        let transports = ChannelTransport::cluster(wl.n);
        let mut handles = Vec::new();
        for (id, mut tr) in transports.into_iter().enumerate() {
            let mut cfg = wl.node_config(id);
            if id == victim {
                cfg.crash_at = Some(15);
            }
            let grad = seed_only_grad();
            handles.push(std::thread::spawn(move || run_node(&cfg, &mut tr, grad, None)));
        }
        let outs: Vec<NodeOutcome> =
            handles.into_iter().map(|h| h.join().expect("node thread")).collect();
        let wall = t0.elapsed();
        assert!(
            wall < Duration::from_secs(10),
            "survivors took {wall:?} — the crash stalled them toward drain_timeout"
        );
        for &i in &[0usize, 1] {
            let r = &outs[i].report;
            assert_eq!(r.dropped_deltas, 0, "node {i} dropped deltas");
            assert_eq!(r.missing_rumors, 0, "node {i} missing rumors");
            assert!(r.confirmed_dead >= 1, "node {i} never confirmed the crash");
            assert!(r.departed.contains(&victim), "node {i} still has the corpse in view");
            // Survivors finished all their own steps despite sampling a corpse.
            assert_eq!(r.steps[i], 30, "node {i} did not finish");
        }
        // The custodian (whichever survivor it was) re-announced.
        assert!(
            outs[0].report.repair_msgs + outs[1].report.repair_msgs > 0,
            "no custody repair was broadcast"
        );
        // Survivors agree exactly on every origin — including the dead
        // one, whose count the custodian pinned.
        assert_eq!(outs[0].applied_of, outs[1].applied_of, "survivors diverged");
        assert_eq!(outs[0].applied_of[0], 30);
        assert_eq!(outs[0].applied_of[1], 30);
    }

    #[test]
    fn monitor_serves_the_current_snapshot_over_http() {
        let m = Monitor::serve("127.0.0.1:0").expect("bind monitor");
        m.set(&obj(vec![
            ("status", Json::Str("done".to_string())),
            ("dropped_deltas", Json::Num(0.0)),
        ]));
        let mut conn = TcpStream::connect(m.addr()).expect("connect");
        conn.write_all(b"GET /status HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let _ = conn.read_to_string(&mut resp);
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "bad response: {resp}");
        assert!(resp.contains("\"dropped_deltas\":0") || resp.contains("\"dropped_deltas\": 0"),
            "body missing counter: {resp}");
    }

    #[test]
    fn bootstrap_handshake_assigns_ids_and_ships_the_roster() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind seed");
        let seed_addr = listener.local_addr().unwrap().to_string();
        let wl = test_workload(3, 4, Method::Asp);
        let seed_thread = {
            let wl = wl.clone();
            let seed_addr = seed_addr.clone();
            std::thread::spawn(move || seed_bootstrap(&listener, &wl, &seed_addr).expect("seed"))
        };
        let mut joiners = Vec::new();
        for j in 0..2 {
            let seed_addr = seed_addr.clone();
            joiners.push(std::thread::spawn(move || {
                let my_addr = format!("127.0.0.1:{}", 9000 + j);
                join_bootstrap(&seed_addr, &my_addr, Duration::from_secs(10)).expect("join")
            }));
        }
        let roster = seed_thread.join().expect("seed thread");
        assert_eq!(roster.len(), 3);
        assert_eq!(roster[0], (0, seed_addr.clone()));
        let mut ids = Vec::new();
        for j in joiners {
            let (welcome, peers) = j.join().expect("join thread");
            assert_eq!(welcome.n, 3);
            assert_eq!(welcome.method, "asp");
            assert_eq!(peers.len(), 3);
            assert_eq!(peers[0].1, seed_addr);
            ids.push(welcome.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
    }
}
