//! Map-reduce engine — BSP supersteps over worker actors (paper §4,
//! Table 1 row "MapReduce": *requires map to complete before reducing*).
//!
//! A generic `map → shuffle → reduce` round with an explicit BSP barrier
//! between phases (the master collects *all* map outputs before any
//! reduce starts), plus an iterative driver ([`iterate`]) used by the
//! examples for barrier-per-round computations.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::actor::System;

/// One map-reduce round over `inputs`, split across `n_workers` map tasks.
///
/// `map(input) -> [(k, v)]`, `reduce(k, values) -> v'`. Values for equal
/// keys are combined by `reduce` after the BSP barrier.
pub fn map_reduce<I, K, V, M, R>(
    inputs: Vec<I>,
    n_workers: usize,
    map: M,
    reduce: R,
) -> BTreeMap<K, V>
where
    I: Send + 'static,
    K: Ord + Send + Clone + 'static,
    V: Send + 'static,
    M: Fn(I) -> Vec<(K, V)> + Send + Sync + 'static,
    R: Fn(&K, Vec<V>) -> V,
{
    let sys = System::new();
    let map = Arc::new(map);
    let n_workers = n_workers.max(1);

    // Partition inputs round-robin into n_workers shards.
    let mut shards: Vec<Vec<I>> = (0..n_workers).map(|_| Vec::new()).collect();
    for (i, input) in inputs.into_iter().enumerate() {
        shards[i % n_workers].push(input);
    }

    // Map phase: one actor per shard.
    let tasks: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(i, shard)| {
            let map = Arc::clone(&map);
            sys.spawn::<(), Vec<(K, V)>, _>(&format!("map-{i}"), move |_mb| {
                let mut out = Vec::new();
                for input in shard {
                    out.extend(map(input));
                }
                out
            })
        })
        .collect();

    // BSP barrier: join ALL mappers before reducing (the superstep edge).
    let mut grouped: BTreeMap<K, Vec<V>> = BTreeMap::new();
    for t in tasks {
        let (addr, handle) = t.into_parts();
        drop(addr);
        for (k, v) in handle.join().expect("mapper panicked") {
            grouped.entry(k).or_default().push(v);
        }
    }

    // Reduce phase.
    grouped
        .into_iter()
        .map(|(k, vs)| {
            let r = reduce(&k, vs);
            (k, r)
        })
        .collect()
}

/// `collect`: gather distributed per-worker values at the master (the
/// paper's map-reduce API, §4). A degenerate map-reduce round with the
/// identity key.
pub fn collect<I, M, V>(inputs: Vec<I>, n_workers: usize, f: M) -> Vec<V>
where
    I: Send + 'static,
    V: Send + 'static,
    M: Fn(I) -> V + Send + Sync + 'static,
{
    let mut grouped = map_reduce(
        inputs.into_iter().enumerate().collect::<Vec<_>>(),
        n_workers,
        move |(i, x): (usize, I)| vec![(i, f(x))],
        |_k, mut vs| vs.pop().unwrap(),
    );
    // BTreeMap keyed by input index => original order restored.
    let mut out = Vec::with_capacity(grouped.len());
    while let Some((_, v)) = grouped.pop_first() {
        out.push(v);
    }
    out
}

/// `join`: co-group two keyed datasets (the paper's map-reduce API, §4):
/// returns, per key present in both sides, the pair of value lists.
pub fn join<K, A, B>(
    left: Vec<(K, A)>,
    right: Vec<(K, B)>,
    n_workers: usize,
) -> BTreeMap<K, (Vec<A>, Vec<B>)>
where
    K: Ord + Clone + Send + 'static,
    A: Send + 'static,
    B: Send + 'static,
{
    enum Side<A, B> {
        L(A),
        R(B),
    }
    let tagged: Vec<(K, Side<A, B>)> = left
        .into_iter()
        .map(|(k, a)| (k, Side::L(a)))
        .chain(right.into_iter().map(|(k, b)| (k, Side::R(b))))
        .collect();
    let grouped = map_reduce(
        tagged,
        n_workers,
        |(k, side): (K, Side<A, B>)| vec![(k, vec![side])],
        |_k, vs| vs.into_iter().flatten().collect(),
    );
    grouped
        .into_iter()
        .filter_map(|(k, sides)| {
            let mut ls = Vec::new();
            let mut rs = Vec::new();
            for s in sides {
                match s {
                    Side::L(a) => ls.push(a),
                    Side::R(b) => rs.push(b),
                }
            }
            (!ls.is_empty() && !rs.is_empty()).then_some((k, (ls, rs)))
        })
        .collect()
}

/// Iterative map-reduce: run `rounds` rounds, threading a state through.
/// Each round is a full BSP superstep; `step` receives the previous state
/// and the round index and produces the round's inputs; `fold` combines
/// the reduced output back into the state.
pub fn iterate<S, I, K, V, M, R, G, F>(
    mut state: S,
    rounds: usize,
    n_workers: usize,
    gen_inputs: G,
    map: M,
    reduce: R,
    fold: F,
) -> S
where
    I: Send + 'static,
    K: Ord + Send + Clone + 'static,
    V: Send + 'static,
    M: Fn(I) -> Vec<(K, V)> + Send + Sync + Clone + 'static,
    R: Fn(&K, Vec<V>) -> V,
    G: Fn(&S, usize) -> Vec<I>,
    F: Fn(S, BTreeMap<K, V>) -> S,
{
    for round in 0..rounds {
        let inputs = gen_inputs(&state, round);
        let reduced = map_reduce(inputs, n_workers, map.clone(), &reduce);
        state = fold(state, reduced);
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_count() {
        let docs = vec![
            "the quick brown fox".to_string(),
            "the lazy dog".to_string(),
            "the fox".to_string(),
        ];
        let counts = map_reduce(
            docs,
            2,
            |doc: String| {
                doc.split_whitespace()
                    .map(|w| (w.to_string(), 1usize))
                    .collect()
            },
            |_k, vs| vs.into_iter().sum(),
        );
        assert_eq!(counts["the"], 3);
        assert_eq!(counts["fox"], 2);
        assert_eq!(counts["dog"], 1);
    }

    #[test]
    fn single_worker_equals_many_workers() {
        let mk = || (0..100u64).collect::<Vec<_>>();
        let run = |workers| {
            map_reduce(
                mk(),
                workers,
                |x: u64| vec![(x % 7, x)],
                |_k, vs| vs.into_iter().sum::<u64>(),
            )
        };
        assert_eq!(run(1), run(8));
    }

    #[test]
    fn empty_inputs() {
        let out: BTreeMap<u32, u32> =
            map_reduce(Vec::<u32>::new(), 4, |x| vec![(x, x)], |_k, vs| vs[0]);
        assert!(out.is_empty());
    }

    #[test]
    fn collect_preserves_order() {
        let out = collect((0..50u32).collect(), 4, |x| x * 2);
        assert_eq!(out, (0..50u32).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn join_cogroups_matching_keys() {
        let left = vec![("a", 1), ("b", 2), ("a", 3)];
        let right = vec![("a", 10.0), ("c", 30.0)];
        let j = join(left, right, 2);
        assert_eq!(j.len(), 1); // only "a" is on both sides
        let (ls, rs) = &j["a"];
        assert_eq!(ls, &vec![1, 3]);
        assert_eq!(rs, &vec![10.0]);
    }

    #[test]
    fn join_empty_side_is_empty() {
        let j = join::<u8, u8, u8>(vec![(1, 1)], vec![], 2);
        assert!(j.is_empty());
    }

    #[test]
    fn iterative_rounds_thread_state() {
        // distributed sum-of-squares accumulation over 3 rounds
        let final_state = iterate(
            0u64,
            3,
            4,
            |_state, round| (0..10u64).map(|i| i + round as u64 * 10).collect(),
            |x: u64| vec![((), x * x)],
            |_k, vs| vs.into_iter().sum::<u64>(),
            |state, reduced| state + reduced.get(&()).copied().unwrap_or(0),
        );
        let expect: u64 = (0..30u64).map(|x| x * x).sum();
        assert_eq!(final_state, expect);
    }
}
