//! Parameter-server engine — centralised model, centralised states
//! (paper §4.1 case 1; supports all five barrier methods plus pQuorum).
//!
//! The model vector is partitioned across `n_shards` **shard actors**,
//! each with its own mailbox; barrier state (the [`StepTracker`]) lives
//! in a dedicated **coordinator actor**, so model-plane traffic
//! (pushes/pulls) and control-plane traffic (reports, barrier checks,
//! sampling) never serialise through one queue. Workers run the
//! `pull → compute → push → barrier` loop, accumulating gradients
//! locally for `push_batch` steps and then scattering **one batched
//! message per touched shard**.
//!
//! ## Placement ([`ShardLayout`])
//!
//! With `vnodes == 0` each shard owns a contiguous block
//! ([`shard_range`]) — the historical layout, preserved bit-for-bit.
//! With `vnodes ≥ 1` parameters are placed by consistent hashing on a
//! chord ring where every shard occupies `vnodes` virtual positions
//! ([`crate::overlay::Ring::join_vnodes`]): each parameter index is
//! owned by the ring-successor of its hashed key. One position per
//! shard reproduces the classic successor-placement skew (tens-of-×
//! max/min key imbalance); dozens of virtual positions flatten it —
//! measured by `benches/simulator.rs` and gated in CI.
//!
//! ## Replication and failover
//!
//! With `replication = r ≥ 1`, every shard actor streams each applied
//! batch to its `r` distinct ring successors (`Replicate`). The
//! worker's per-flush ack channel is the **quiescence barrier**: the
//! primary sends one `PushAck` and forwards the batch with a clone of
//! the ack sender; replicas apply and then *drop* the clone without
//! sending. The channel therefore disconnects only once the batch is
//! applied (or dead-lettered) everywhere it was addressed — so when a
//! worker proceeds past a flush, every replica is bitwise-identical to
//! its primary for all acknowledged pushes (asserted at join).
//!
//! A killed shard actor (crash-stop, injectable via
//! [`PsConfig::kill_shard`]) dies at a message boundary: a push it never
//! acknowledged was never applied *anywhere* (replication happens
//! before the ack), so worker retries cannot double-apply. Workers that
//! observe the silence report it to the coordinator, which — reusing
//! the membership plane's [`FailureDetector::declare_dead`] and ring
//! eviction as the trigger — promotes the first live successor to
//! primary and re-seeds the successor list via bulk `Install` handoff
//! (`handoff_bytes`). Pulls are served by whichever actor currently
//! holds the block; reads served from a block the actor was not the
//! original home of count as `replica_pulls` (safe for SGD: replica
//! reads lag the primary by at most the in-flight batch, the ASAP
//! argument). Acceptance bar: kill any single shard actor mid-run and
//! training completes with zero lost updates.

use std::ops::Range;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::actor::{Address, System};
use crate::barrier::{AdaptiveConfig, BarrierPolicy, Method, ViewRequirement};
use crate::engine::delta::{CompressConfig, DeltaEncoder, DeltaPayload};
use crate::engine::membership::{FailureDetector, MembershipConfig};
use crate::engine::{BarrierOut, EngineError, EngineReport, GradFn};
use crate::overlay::{node_ring_id, Ring};
use crate::sampling::StepTracker;
use crate::util::rng::Rng;

/// Namespace for shard placement positions on the ring.
const PLACEMENT_NAMESPACE: u64 = 0xB10C_B10C;
/// Namespace for hashing parameter indices to ring keys.
const KEY_NAMESPACE: u64 = 0x4B45_59;

/// Routing-table sentinel: the shard has no live candidate left (its
/// primary and every ring successor are confirmed dead). Workers that
/// adopt a route carrying this abort with a partial report instead of
/// retrying into the void.
pub const SHARD_LOST: usize = usize::MAX;

/// One primary acknowledgement per acked push (replicas never send —
/// they only release their clone of the sender once applied).
pub struct PushAck {
    pub shard: usize,
}

/// Messages understood by a shard actor (model plane).
pub enum ShardMsg {
    /// Addresses of every shard actor, delivered by the runtime before
    /// any worker traffic (FIFO) so primaries can forward replica
    /// streams and promoted actors can bulk-install.
    Init { peers: Vec<Address<ShardMsg>> },
    /// Batched model delta for shard `shard`'s block (values in
    /// owned-index order, already `-lr`-scaled at the worker, possibly
    /// sparsified/quantized per [`CompressConfig`]); the primary applies
    /// it, forwards the *same payload* to its replicas, then
    /// acknowledges. Dense payloads replay the legacy `w -= lr * grad`
    /// arithmetic bit-for-bit.
    Push { shard: usize, delta: DeltaPayload, ack: Sender<PushAck> },
    /// Replica stream: an applied payload forwarded by the primary. The
    /// replica applies the identical payload — so replica blocks stay
    /// bitwise-equal to the primary even under lossy encodings — and
    /// then drops `ack` unsent, disconnecting the worker's flush
    /// channel only after the apply.
    Replicate { shard: usize, delta: DeltaPayload, ack: Sender<PushAck> },
    /// Bulk handoff: adopt `block` as the current state of `shard`.
    Install { shard: usize, block: Vec<f32> },
    /// Become (or stay) primary for `shard`: forward future batches to
    /// `replicas` and bulk-install the current block on `install`
    /// targets. Replies with the handoff bytes shipped.
    Promote {
        shard: usize,
        replicas: Vec<usize>,
        install: Vec<usize>,
        reply: Sender<u64>,
    },
    /// Pull shard `shard`'s block: replies `(shard, block)` so a worker
    /// can gather all shards through one channel.
    Pull { shard: usize, reply: Sender<(usize, Vec<f32>)> },
    /// Shut down; final state is returned from the actor body.
    Stop,
}

/// Messages understood by the barrier coordinator (control plane).
pub enum CoordMsg {
    /// Worker reports that it advanced to `step`.
    Report { node: u32, step: u64 },
    /// Global-view barrier read: the tracked global minimum step, or
    /// `None` when a shard is lost (the barrier must release so
    /// survivors can observe the dead route and abort). The admission
    /// *decision* happens at the worker, through its
    /// [`crate::barrier::BarrierPolicy`] — the coordinator only serves
    /// the view, which is what lets each worker tune its own θ locally.
    MinStep { reply: Sender<Option<u64>> },
    /// Centralised sampling primitive: min step over β sampled peers.
    SampleMin { node: u32, beta: usize, reply: Sender<Option<u64>> },
    /// Worker observed shard `shard`'s routed actor go silent (failed
    /// send or missing ack). The coordinator confirms the death, re-homes
    /// every shard the actor served, and replies with the fresh routes.
    ShardDead { shard: usize, actor: usize, reply: Sender<Vec<usize>> },
    /// Shut down and report final control-plane state.
    Stop { reply: Sender<CoordStats> },
}

/// Coordinator final state, returned at shutdown.
pub struct CoordStats {
    /// Step reports handled.
    pub reports: u64,
    /// Final shard -> primary-actor routing table.
    pub route: Vec<usize>,
    /// Final shard -> replica-actor lists.
    pub replicas_of: Vec<Vec<usize>>,
    /// Per-actor death flags.
    pub dead: Vec<bool>,
    /// Deaths confirmed (distinct actors).
    pub confirmed_dead: u64,
}

/// Engine configuration.
#[derive(Clone)]
pub struct PsConfig {
    pub n_workers: usize,
    /// Steps each worker performs.
    pub steps_per_worker: u64,
    pub method: Method,
    pub lr: f32,
    pub dim: usize,
    pub seed: u64,
    /// Poll interval while blocked at the barrier.
    pub poll: Duration,
    /// Artificial per-step compute slowdown for designated stragglers:
    /// (worker index, extra sleep) pairs.
    pub stragglers: Vec<(usize, Duration)>,
    /// The paper's `schedule` API (§4): when `Some(nblocks)`, the model is
    /// partitioned into `nblocks` contiguous blocks and worker `i` at step
    /// `s` is scheduled to update only block `(i + s) mod nblocks` — the
    /// model-parallel pattern where each update touches a disjoint
    /// parameter shard. `None` = data-parallel (full-vector updates).
    pub schedule_blocks: Option<usize>,
    /// Number of model shards (server actors). 1 = the paper's single
    /// central server; more shards split both the model state and the
    /// push/pull queues.
    pub n_shards: usize,
    /// Steps a worker accumulates gradients locally before scattering one
    /// batched push per touched shard. 1 = push every step (paper). The
    /// trade-off is standard gradient accumulation: the server view lags
    /// a worker's local progress by up to `push_batch - 1` updates.
    pub push_batch: usize,
    /// Ring-successor replicas each shard streams applied batches to.
    /// 0 = no replication (pre-durability behaviour, bit-identical).
    pub replication: usize,
    /// Virtual placement positions per shard. 0 = contiguous blocks
    /// (historical layout); ≥ 1 = consistent-hash placement with that
    /// many vnodes per shard (≥ ~32 recommended for balance).
    pub vnodes: usize,
    /// Fault injection: `(shard, after)` crash-stops shard actor `shard`
    /// immediately after it acknowledges its `max(after, 1)`-th primary
    /// batch. Requires `replication ≥ 1` and `n_shards ≥ 2` (a replica
    /// must exist to inherit the block).
    pub kill_shard: Option<(usize, u64)>,
    /// Online barrier adaptation (DSSP-style). `None` = static knobs;
    /// the policy then replays the legacy admission decisions exactly.
    /// Each worker adapts its own θ/β locally — no consensus round.
    pub adaptive: Option<AdaptiveConfig>,
    /// Delta-payload compression for worker pushes. Replicas receive
    /// the identical payload the primary applied, so the bitwise
    /// replica invariant holds in every mode; `Dense` (the default) is
    /// bit-identical to the legacy uncompressed path.
    pub compress: CompressConfig,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig {
            n_workers: 8,
            steps_per_worker: 20,
            method: Method::Pssp { sample: 3, staleness: 2 },
            lr: 0.05,
            dim: 64,
            seed: 1,
            poll: Duration::from_micros(200),
            stragglers: Vec::new(),
            schedule_blocks: None,
            n_shards: 1,
            push_batch: 1,
            replication: 0,
            vnodes: 0,
            kill_shard: None,
            adaptive: None,
            compress: CompressConfig::default(),
        }
    }
}

/// The `schedule` decision: which parameter range worker `node` updates
/// at `step` (paper §4: "decide what model parameters should be computed
/// to update in this step"). Exposed for tests and custom engines.
pub fn scheduled_range(
    dim: usize,
    nblocks: usize,
    node: usize,
    step: u64,
) -> Range<usize> {
    let nblocks = nblocks.clamp(1, dim);
    let block = (node + step as usize) % nblocks;
    let size = dim.div_ceil(nblocks);
    let lo = block * size;
    lo.min(dim)..((block + 1) * size).min(dim)
}

/// The model range owned by shard `shard` when `dim` parameters are split
/// into `n_shards` contiguous blocks (same arithmetic as
/// [`scheduled_range`], so a schedule with `nblocks == n_shards` touches
/// exactly one shard per step). This is the `vnodes == 0` placement.
pub fn shard_range(dim: usize, n_shards: usize, shard: usize) -> Range<usize> {
    let n_shards = n_shards.clamp(1, dim.max(1));
    let size = dim.div_ceil(n_shards);
    let lo = (shard * size).min(dim);
    lo..((shard + 1) * size).min(dim)
}

/// Where every parameter lives and who replicates whom: the placement
/// ring evaluated once at startup, shared by workers (gather/scatter),
/// shard actors (initial forward lists) and the coordinator (failover
/// preference order).
#[derive(Debug, Clone)]
pub struct ShardLayout {
    pub n_shards: usize,
    /// Parameter indices owned by each shard, ascending.
    pub owned: Vec<Vec<usize>>,
    /// Owning shard of each parameter index.
    pub owner_of: Vec<usize>,
    /// Full clockwise distinct-successor order per shard — the replica
    /// preference list (first `r` entries are the live replica set; the
    /// rest are promotion candidates).
    pub succ_order: Vec<Vec<usize>>,
    /// The placement ring itself (evicted on confirmed deaths).
    pub ring: Ring,
}

impl ShardLayout {
    pub fn new(dim: usize, n_shards: usize, vnodes: usize) -> ShardLayout {
        let n_shards = n_shards.clamp(1, dim.max(1));
        let mut ring = Ring::new(PLACEMENT_NAMESPACE);
        for s in 0..n_shards {
            ring.join_vnodes(s, vnodes.max(1));
        }
        let mut owned: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        let mut owner_of = vec![0usize; dim];
        if vnodes == 0 {
            // Historical contiguous layout, preserved exactly.
            for s in 0..n_shards {
                for j in shard_range(dim, n_shards, s) {
                    owned[s].push(j);
                    owner_of[j] = s;
                }
            }
        } else {
            // Consistent hashing: successor of the key's ring position.
            for (j, owner) in owner_of.iter_mut().enumerate() {
                let key = node_ring_id(j, KEY_NAMESPACE);
                // `successor` is `None` only on an empty ring. The layout
                // ring joined every shard just above, so the lookup cannot
                // miss *here* — but the same ring is cloned into
                // [`Failover`] and evicted on confirmed deaths, where the
                // empty case is real and must surface as an
                // [`EngineError`], never a process abort (this line used
                // to `expect("non-empty ring")`).
                let Some((_, s)) = ring.successor(key) else {
                    debug_assert!(false, "placement ring empty at layout");
                    continue;
                };
                owned[s].push(j);
                *owner = s;
            }
        }
        let succ_order: Vec<Vec<usize>> = (0..n_shards)
            .map(|s| ring.successors_distinct(s, n_shards))
            .collect();
        ShardLayout { n_shards, owned, owner_of, succ_order, ring }
    }

    /// Replica set of shard `s` at replication factor `r`.
    pub fn replicas(&self, s: usize, r: usize) -> &[usize] {
        &self.succ_order[s][..r.min(self.succ_order[s].len())]
    }

    /// Per-shard push-traffic imbalance: max/min owned-key count (each
    /// batched push to shard `s` carries `owned[s].len()` values, so key
    /// counts are proportional to push bytes). Min is clamped to 1 so a
    /// shard that owns nothing reports the worst finite ratio.
    pub fn imbalance(&self) -> f64 {
        let max = self.owned.iter().map(Vec::len).max().unwrap_or(1);
        let min = self.owned.iter().map(Vec::len).min().unwrap_or(1);
        max as f64 / min.max(1) as f64
    }
}

/// A shard actor's final state, returned from its body at shutdown (or
/// at its injected crash) and recovered via `join`.
struct ShardDone {
    /// Block state per shard index (own block + replica copies + any
    /// blocks adopted by promotion).
    blocks: Vec<Option<Vec<f32>>>,
    /// Primary batches applied (and acknowledged).
    applied: u64,
    /// Replica batches applied from the forward stream.
    replica_applied: u64,
    /// Pulls served from a block this actor was not the home of.
    replica_pulls: u64,
    /// Bytes shipped in promotion-driven `Install` handoffs.
    handoff_bytes: u64,
    /// Messages discarded for lack of state / stale routing.
    discarded: u64,
}

/// A worker thread's final accounting, returned from its body.
struct WorkerDone {
    control_msgs: u64,
    update_msgs: u64,
    /// Steps fully completed (== `steps_per_worker` on a healthy run).
    steps_done: u64,
    /// Set when the worker aborted on a [`SHARD_LOST`] route.
    lost_shard: Option<usize>,
    /// Barrier-policy outcome: wait/stall counters + final effective θ/β.
    barrier: BarrierOut,
    /// Payload bytes this worker's pushes shipped (wire form).
    payload_bytes: u64,
    /// L1 mass its error-feedback accumulators re-injected.
    fed_back_mass: f64,
}

/// Assemble a worker's final accounting. Every return path — including
/// the mid-flush abort paths — must go through here so the barrier and
/// compression counters are never silently zeroed.
fn worker_done(
    control_msgs: u64,
    update_msgs: u64,
    steps_done: u64,
    lost_shard: Option<usize>,
    policy: &BarrierPolicy,
    encoders: &[DeltaEncoder],
) -> WorkerDone {
    WorkerDone {
        control_msgs,
        update_msgs,
        steps_done,
        lost_shard,
        barrier: BarrierOut::of(policy),
        payload_bytes: encoders.iter().map(|e| e.payload_bytes).sum(),
        fed_back_mass: encoders.iter().map(|e| e.fed_back_mass).sum(),
    }
}

/// Coordinator-side failover state: the routing table plus the
/// membership machinery that confirms deaths and re-homes shards.
struct Failover {
    route: Vec<usize>,
    replicas_of: Vec<Vec<usize>>,
    dead: Vec<bool>,
    confirmed_dead: u64,
    r: usize,
    succ_order: Vec<Vec<usize>>,
    peers: Vec<Address<ShardMsg>>,
    detector: FailureDetector,
    ring: Ring,
}

impl Failover {
    fn new(layout: &ShardLayout, r: usize, peers: Vec<Address<ShardMsg>>) -> Failover {
        let n = layout.n_shards;
        Failover {
            route: (0..n).collect(),
            replicas_of: (0..n).map(|s| layout.replicas(s, r).to_vec()).collect(),
            dead: vec![false; n],
            confirmed_dead: 0,
            r,
            succ_order: layout.succ_order.clone(),
            peers,
            // The coordinator observes as pseudo-member `n` so every
            // shard actor is a declarable peer.
            detector: FailureDetector::new(n, n + 1, 0, MembershipConfig::default()),
            ring: layout.ring.clone(),
        }
    }

    fn confirm(&mut self, actor: usize) {
        if self.dead[actor] {
            return;
        }
        self.dead[actor] = true;
        self.confirmed_dead += 1;
        // Membership plane: record the death and vacate the actor's ring
        // positions (all its vnodes) so placement state stays consistent.
        self.detector.declare_dead(actor);
        self.ring.evict(actor);
    }

    /// A worker reported `actor` (routed primary of `shard`) silent.
    /// Idempotent: a second report of an already-handled death only
    /// refreshes routes.
    fn on_shard_dead(&mut self, shard: usize, actor: usize) {
        if self.dead[actor] || self.route[shard] != actor {
            return; // stale report — the re-home already happened
        }
        self.confirm(actor);
        for s in 0..self.route.len() {
            let involved = self.route[s] == actor || self.replicas_of[s].contains(&actor);
            if involved {
                self.rehome(s);
            }
        }
    }

    /// Recompute shard `s`'s primary + replica set over live actors and
    /// push the change to the (possibly newly promoted) primary, which
    /// bulk-installs state on any replica that lacks it.
    fn rehome(&mut self, s: usize) {
        if self.route[s] == SHARD_LOST {
            return; // already declared lost
        }
        loop {
            let pref: Vec<usize> = std::iter::once(s)
                .chain(self.succ_order[s].iter().copied())
                .filter(|&x| !self.dead[x])
                .collect();
            let Some(&primary) = pref.first() else {
                // Every candidate is confirmed dead — the eviction that
                // emptied this preference list is the same one that used
                // to walk the engine into `expect("non-empty ring")` /
                // retry-exhaustion aborts. Mark the route LOST so workers
                // bail out loudly with a partial report instead.
                eprintln!(
                    "ps-coord: shard {s} LOST — primary and every ring \
                     successor confirmed dead before re-home completed \
                     ({} of {} actors live)",
                    self.dead.iter().filter(|&&d| !d).count(),
                    self.dead.len(),
                );
                self.route[s] = SHARD_LOST;
                return;
            };
            let replicas: Vec<usize> =
                pref.iter().skip(1).take(self.r).copied().collect();
            // Actors that already hold s's block (survivors of the old set).
            let mut holders: Vec<usize> = Vec::new();
            if !self.dead[self.route[s]] {
                holders.push(self.route[s]);
            }
            holders.extend(
                self.replicas_of[s].iter().copied().filter(|&x| !self.dead[x]),
            );
            let install: Vec<usize> = replicas
                .iter()
                .copied()
                .filter(|t| !holders.contains(t))
                .collect();
            let (ptx, prx) = channel();
            let sent = self.peers[primary].send(ShardMsg::Promote {
                shard: s,
                replicas: replicas.clone(),
                install,
                reply: ptx,
            });
            // Blocking on the reply is safe: shard actors never block, so
            // a live primary always answers. Waiting here guarantees the
            // handoff finished before any worker learns the new route.
            if sent && prx.recv().is_ok() {
                self.route[s] = primary;
                self.replicas_of[s] = replicas;
                return;
            }
            // The candidate died under us — confirm and take the next.
            self.confirm(primary);
        }
    }
}

/// What a worker learns from reporting a silent shard primary.
enum Refresh {
    /// Fresh routes adopted; every shard still has a live primary.
    Ok,
    /// The engine is shutting down (coordinator gone).
    Shutdown,
    /// This shard's route came back [`SHARD_LOST`]: no live candidate.
    Lost(usize),
}

/// Report a silent shard primary to the coordinator and adopt the
/// refreshed routing table.
fn confirm_dead_and_refresh(
    coord: &Address<CoordMsg>,
    routes: &mut Vec<usize>,
    control_msgs: &mut u64,
    shard: usize,
) -> Refresh {
    let (tx, rx) = channel();
    *control_msgs += 2;
    if !coord.send(CoordMsg::ShardDead { shard, actor: routes[shard], reply: tx }) {
        return Refresh::Shutdown;
    }
    match rx.recv() {
        Ok(fresh) => {
            *routes = fresh;
            // Any LOST entry aborts the worker — not just the reported
            // shard: the worker pulls every shard each step, so a single
            // unrecoverable block makes its step budget unfinishable.
            match routes.iter().position(|&r| r == SHARD_LOST) {
                Some(s) => Refresh::Lost(s),
                None => Refresh::Ok,
            }
        }
        Err(_) => Refresh::Shutdown,
    }
}

/// Run the engine to completion: every worker performs its step budget.
///
/// `grad_fn` supplies gradients (pure-Rust model or PJRT artifact);
/// `init_w` is the initial model. Panics if the run cannot complete —
/// callers that want the partial report instead use [`try_run`].
pub fn run(cfg: &PsConfig, init_w: Vec<f32>, grad_fn: GradFn) -> EngineReport {
    match try_run(cfg, init_w, grad_fn) {
        Ok(r) => r,
        Err(e) => panic!("paramserver engine failed: {e}"),
    }
}

/// [`run`], but a lost shard (every placement candidate confirmed dead
/// before re-home completed — e.g. `kill_shard` with no replica to
/// inherit the block) surfaces as an [`EngineError`] carrying the
/// partial [`EngineReport`] instead of aborting the process. The
/// partial model keeps the initial values for lost blocks; counters
/// cover everything up to the abort.
pub fn try_run(
    cfg: &PsConfig,
    init_w: Vec<f32>,
    grad_fn: GradFn,
) -> Result<EngineReport, EngineError> {
    assert_eq!(init_w.len(), cfg.dim);
    let start = Instant::now();
    let sys = System::new();
    let method = cfg.method;
    let adaptive = cfg.adaptive;
    let lr = cfg.lr;
    let n = cfg.n_workers;
    let seed = cfg.seed;
    let n_shards = cfg.n_shards.clamp(1, cfg.dim.max(1));
    let push_batch = cfg.push_batch.max(1);
    let replication = cfg.replication.min(n_shards.saturating_sub(1));
    let compress = cfg.compress;
    let layout = Arc::new(ShardLayout::new(cfg.dim, n_shards, cfg.vnodes));
    if cfg.kill_shard.is_some() && (replication == 0 || n_shards < 2) {
        // No replica exists to inherit the victim's block: the kill will
        // lose the shard. Legal — but say so up front, loudly.
        eprintln!(
            "paramserver: kill injection with replication={replication}, \
             n_shards={n_shards} — no replica can inherit the block; \
             expect a lost shard and a partial report"
        );
    }

    // ---- shard actors (model plane) ----
    let shards: Vec<_> = (0..n_shards)
        .map(|k| {
            let block: Vec<f32> =
                layout.owned[k].iter().map(|&j| init_w[j]).collect();
            let init_forward = layout.replicas(k, replication).to_vec();
            let kill = cfg.kill_shard;
            sys.spawn::<ShardMsg, ShardDone, _>(&format!("ps-shard-{k}"), move |mb| {
                let mut blocks: Vec<Option<Vec<f32>>> = vec![None; n_shards];
                blocks[k] = Some(block);
                let mut primary_of = vec![false; n_shards];
                primary_of[k] = true;
                let mut forward: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
                forward[k] = init_forward;
                let mut peers: Vec<Address<ShardMsg>> = Vec::new();
                let mut applied: u64 = 0;
                let mut replica_applied: u64 = 0;
                let mut replica_pulls: u64 = 0;
                let mut handoff_bytes: u64 = 0;
                let mut discarded: u64 = 0;
                // Batched receive: one wakeup drains a burst of queued
                // pushes, which is what makes many producers cheap.
                let mut buf = Vec::with_capacity(32);
                'serve: while mb.recv_batch(&mut buf, 32) > 0 {
                    for msg in buf.drain(..) {
                        match msg {
                            ShardMsg::Init { peers: p } => peers = p,
                            ShardMsg::Push { shard, delta, ack } => {
                                if !primary_of[shard] {
                                    // Stale route: neither apply nor ack —
                                    // the worker re-resolves and retries.
                                    discarded += 1;
                                    continue;
                                }
                                let w = blocks[shard]
                                    .as_mut()
                                    .expect("primary holds its block");
                                delta.apply_into(w);
                                applied += 1;
                                // Replicate BEFORE acking: an acked batch
                                // is on every addressed replica's queue —
                                // and it is the same payload the primary
                                // applied, so replicas stay bitwise-equal
                                // even under lossy encodings.
                                for &t in &forward[shard] {
                                    peers[t].send(ShardMsg::Replicate {
                                        shard,
                                        delta: delta.clone(),
                                        ack: ack.clone(),
                                    });
                                }
                                let _ = ack.send(PushAck { shard });
                                if let Some((victim, after)) = kill {
                                    if victim == k && applied >= after.max(1) {
                                        // Crash-stop at a message boundary:
                                        // everything acked is replicated,
                                        // everything queued dead-letters.
                                        break 'serve;
                                    }
                                }
                            }
                            ShardMsg::Replicate { shard, delta, ack } => {
                                match blocks[shard].as_mut() {
                                    Some(w) => {
                                        delta.apply_into(w);
                                        replica_applied += 1;
                                    }
                                    None => discarded += 1,
                                }
                                // Quiescence token: released post-apply.
                                drop(ack);
                            }
                            ShardMsg::Install { shard, block } => {
                                blocks[shard] = Some(block);
                            }
                            ShardMsg::Promote { shard, replicas, install, reply } => {
                                primary_of[shard] = true;
                                forward[shard] = replicas;
                                let mut bytes = 0u64;
                                if let Some(b) = blocks[shard].as_ref() {
                                    for &t in &install {
                                        if peers[t].send(ShardMsg::Install {
                                            shard,
                                            block: b.clone(),
                                        }) {
                                            bytes += 4 * b.len() as u64;
                                        }
                                    }
                                }
                                handoff_bytes += bytes;
                                let _ = reply.send(bytes);
                            }
                            ShardMsg::Pull { shard, reply } => match blocks[shard]
                                .as_ref()
                            {
                                Some(b) => {
                                    if shard != k {
                                        replica_pulls += 1;
                                    }
                                    let _ = reply.send((shard, b.clone()));
                                }
                                // No state: drop the reply sender so the
                                // worker re-resolves the route.
                                None => discarded += 1,
                            },
                            ShardMsg::Stop => break 'serve,
                        }
                    }
                }
                ShardDone {
                    blocks,
                    applied,
                    replica_applied,
                    replica_pulls,
                    handoff_bytes,
                    discarded,
                }
            })
        })
        .collect();

    // Wire the actors together and seed the initial replica blocks —
    // all before any worker exists, so these arrive first (FIFO).
    let peers: Vec<Address<ShardMsg>> =
        shards.iter().map(|s| s.addr.clone()).collect();
    for addr in &peers {
        addr.send(ShardMsg::Init { peers: peers.clone() });
    }
    for s in 0..n_shards {
        let block: Vec<f32> = layout.owned[s].iter().map(|&j| init_w[j]).collect();
        for &t in layout.replicas(s, replication) {
            peers[t].send(ShardMsg::Install { shard: s, block: block.clone() });
        }
    }

    // ---- coordinator actor (control plane: barrier state + failover) ----
    let coord_layout = Arc::clone(&layout);
    let coord_peers = peers.clone();
    let coord = sys.spawn::<CoordMsg, _, _>("ps-coord", move |mb| {
        let mut tracker = StepTracker::new(n);
        let mut rng = Rng::new(seed ^ SERVER_SEED_SALT);
        let mut scratch = Vec::new();
        let mut reports: u64 = 0;
        let mut fo = Failover::new(&coord_layout, replication, coord_peers);
        while let Some(msg) = mb.recv() {
            match msg {
                CoordMsg::Report { node, step } => {
                    reports += 1;
                    tracker.advance_to(node as usize, step);
                }
                CoordMsg::MinStep { reply } => {
                    // A lost shard means aborted workers will never report
                    // again: reply `None` so the worker's policy releases
                    // the barrier, the survivor advances to its next pull,
                    // observes the dead route, and aborts with a partial
                    // report instead of polling forever.
                    let m = if fo.route.contains(&SHARD_LOST) {
                        None
                    } else {
                        Some(tracker.min_step())
                    };
                    let _ = reply.send(m);
                }
                CoordMsg::SampleMin { node, beta, reply } => {
                    // Same release-on-loss rule: `None` reads as "pass".
                    let m = if fo.route.contains(&SHARD_LOST) {
                        None
                    } else {
                        tracker.sample_min(node as usize, beta, &mut rng, &mut scratch)
                    };
                    let _ = reply.send(m);
                }
                CoordMsg::ShardDead { shard, actor, reply } => {
                    fo.on_shard_dead(shard, actor);
                    let _ = reply.send(fo.route.clone());
                }
                CoordMsg::Stop { reply } => {
                    let _ = reply.send(CoordStats {
                        reports,
                        route: fo.route.clone(),
                        replicas_of: fo.replicas_of.clone(),
                        dead: fo.dead.clone(),
                        confirmed_dead: fo.confirmed_dead,
                    });
                    break;
                }
            }
        }
    });

    // ---- workers ----
    let workers: Vec<_> = (0..n)
        .map(|i| {
            let shard_addrs = peers.clone();
            let coord_addr = coord.addr.clone();
            let layout = Arc::clone(&layout);
            let grad_fn = grad_fn.clone();
            let poll = cfg.poll;
            let steps = cfg.steps_per_worker;
            let dim = cfg.dim;
            let slow = cfg
                .stragglers
                .iter()
                .find(|&&(idx, _)| idx == i)
                .map(|&(_, d)| d);
            let wseed = cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ i as u64;
            let schedule_blocks = cfg.schedule_blocks;
            sys.spawn::<(), WorkerDone, _>(&format!("ps-worker-{i}"), move |_mb| {
                let mut rng = Rng::new(wseed);
                // The single admission authority for this worker. With
                // `adaptive: None` its decisions are value-identical to
                // the legacy inline `min + θ >= step + 1` checks.
                let mut policy = BarrierPolicy::with_adaptive(method, adaptive);
                // One payload encoder per shard: error-feedback residuals
                // live per block, so they follow the placement exactly.
                let mut encoders: Vec<DeltaEncoder> = (0..n_shards)
                    .map(|s| DeltaEncoder::new(compress, layout.owned[s].len()))
                    .collect();
                let mut control_msgs = 0u64;
                let mut update_msgs = 0u64;
                // Local copy of the shard -> primary routing table,
                // refreshed from the coordinator after observed deaths.
                let mut routes: Vec<usize> = (0..n_shards).collect();
                let mut w = vec![0.0f32; dim];
                // Local accumulator for batched pushes + which shards the
                // accumulated updates touched.
                let mut acc = vec![0.0f32; dim];
                let mut touched = vec![false; n_shards];
                let mut pending: u64 = 0;
                for step in 0..steps {
                    let step_t0 = Instant::now();
                    // pull: gather every shard's block through one
                    // channel, re-routing around dead primaries
                    let mut need = vec![true; n_shards];
                    let mut outstanding = n_shards;
                    let mut attempts = 0usize;
                    while outstanding > 0 {
                        attempts += 1;
                        assert!(
                            attempts <= n_shards + 8,
                            "ps-worker-{i}: pull never converged on live shards"
                        );
                        let (tx, rx) = channel();
                        for s in 0..n_shards {
                            if need[s] {
                                shard_addrs[routes[s]]
                                    .send(ShardMsg::Pull { shard: s, reply: tx.clone() });
                            }
                        }
                        drop(tx);
                        // Disconnects once every addressed actor replied
                        // or dead-lettered the request.
                        while let Ok((s, block)) = rx.recv() {
                            for (&j, v) in layout.owned[s].iter().zip(&block) {
                                w[j] = *v;
                            }
                            if need[s] {
                                need[s] = false;
                                outstanding -= 1;
                            }
                        }
                        for s in 0..n_shards {
                            if !need[s] {
                                continue;
                            }
                            match confirm_dead_and_refresh(
                                &coord_addr,
                                &mut routes,
                                &mut control_msgs,
                                s,
                            ) {
                                Refresh::Ok => {}
                                Refresh::Shutdown => {
                                    return worker_done(
                                        control_msgs, update_msgs, step, None,
                                        &policy, &encoders,
                                    );
                                }
                                Refresh::Lost(ls) => {
                                    eprintln!(
                                        "ps-worker-{i}: shard {ls} lost — \
                                         aborting at step {step}/{steps}"
                                    );
                                    return worker_done(
                                        control_msgs, update_msgs, step, Some(ls),
                                        &policy, &encoders,
                                    );
                                }
                            }
                        }
                    }
                    // compute (stragglers sleep extra)
                    if let Some(d) = slow {
                        std::thread::sleep(d);
                    }
                    let g = grad_fn(&w, rng.next_u64());
                    // schedule + accumulate: restrict the update to this
                    // worker's block and fold it into the local batch
                    match schedule_blocks {
                        Some(nblocks) => {
                            let range = scheduled_range(g.len(), nblocks, i, step);
                            for (j, gj) in g[range.clone()].iter().enumerate() {
                                acc[range.start + j] += gj;
                            }
                            for j in range {
                                touched[layout.owner_of[j]] = true;
                            }
                        }
                        None => {
                            for (aj, gj) in acc.iter_mut().zip(&g) {
                                *aj += gj;
                            }
                            touched.iter_mut().for_each(|t| *t = true);
                        }
                    }
                    pending += 1;
                    // push: scatter one batched message per touched shard,
                    // then wait for the acks — the step report below must
                    // not outrun the updates it stands for. The channel
                    // disconnect additionally waits for the replica
                    // applies (the quiescence barrier).
                    if pending == push_batch as u64 || step + 1 == steps {
                        let mut flush: Vec<(usize, DeltaPayload)> = Vec::new();
                        for s in 0..n_shards {
                            if !touched[s] {
                                continue;
                            }
                            // The push carries the *delta* (already
                            // `-lr`-scaled): dense mode then replays the
                            // legacy `w -= lr * grad` bit-for-bit (IEEE
                            // `x + (-y) == x - y`), and lossy modes drop
                            // or round update mass, never raw gradients.
                            let delta: Vec<f32> = layout.owned[s]
                                .iter()
                                .map(|&j| -(lr * acc[j]))
                                .collect();
                            for &j in &layout.owned[s] {
                                acc[j] = 0.0;
                            }
                            touched[s] = false;
                            flush.push((s, encoders[s].encode(delta)));
                        }
                        let mut attempts = 0usize;
                        while !flush.is_empty() {
                            attempts += 1;
                            assert!(
                                attempts <= n_shards + 8,
                                "ps-worker-{i}: push never converged on live shards"
                            );
                            let (ack_tx, ack_rx) = channel();
                            for (s, delta) in &flush {
                                shard_addrs[routes[*s]].send(ShardMsg::Push {
                                    shard: *s,
                                    delta: delta.clone(),
                                    ack: ack_tx.clone(),
                                });
                            }
                            drop(ack_tx);
                            while let Ok(PushAck { shard }) = ack_rx.recv() {
                                update_msgs += 1;
                                flush.retain(|(s, _)| *s != shard);
                            }
                            // Unacked pushes were never applied anywhere
                            // (replication precedes the ack, the crash sits
                            // at a message boundary) — safe to re-send to
                            // the promoted primary.
                            let silent: Vec<usize> =
                                flush.iter().map(|(s, _)| *s).collect();
                            for s in silent {
                                match confirm_dead_and_refresh(
                                    &coord_addr,
                                    &mut routes,
                                    &mut control_msgs,
                                    s,
                                ) {
                                    Refresh::Ok => {}
                                    Refresh::Shutdown => {
                                        return worker_done(
                                            control_msgs, update_msgs, step, None,
                                            &policy, &encoders,
                                        );
                                    }
                                    Refresh::Lost(ls) => {
                                        eprintln!(
                                            "ps-worker-{i}: shard {ls} lost — \
                                             aborting at step {step}/{steps}"
                                        );
                                        return worker_done(
                                            control_msgs, update_msgs, step, Some(ls),
                                            &policy, &encoders,
                                        );
                                    }
                                }
                            }
                        }
                        pending = 0;
                    }
                    // report the new step (control plane, every step)
                    control_msgs += 1;
                    coord_addr.send(CoordMsg::Report {
                        node: i as u32,
                        step: step + 1,
                    });
                    // barrier (not after the final step)
                    if step + 1 == steps {
                        break;
                    }
                    let entered = Instant::now();
                    loop {
                        // Re-read the view each attempt: under adaptation
                        // β can change between polls of the same crossing.
                        let (pass, lag) = match policy.view() {
                            ViewRequirement::None => (true, None),
                            ViewRequirement::Global => {
                                let (tx, rx) = channel();
                                control_msgs += 2;
                                if !coord_addr.send(CoordMsg::MinStep { reply: tx }) {
                                    return worker_done(
                                        control_msgs, update_msgs, step + 1, None,
                                        &policy, &encoders,
                                    );
                                }
                                match rx.recv() {
                                    // `None` = shard lost: release.
                                    Ok(Some(min)) => (
                                        policy.admit_min(step + 1, Some(min)),
                                        Some((step + 1).saturating_sub(min)),
                                    ),
                                    _ => (true, None),
                                }
                            }
                            ViewRequirement::Sample(beta) => {
                                let (tx, rx) = channel();
                                control_msgs += 2 * beta as u64;
                                if !coord_addr.send(CoordMsg::SampleMin {
                                    node: i as u32,
                                    beta,
                                    reply: tx,
                                }) {
                                    return worker_done(
                                        control_msgs, update_msgs, step + 1, None,
                                        &policy, &encoders,
                                    );
                                }
                                match rx.recv() {
                                    // Empty sample / lost shard: release.
                                    Ok(Some(min)) => (
                                        policy.admit_min(step + 1, Some(min)),
                                        Some((step + 1).saturating_sub(min)),
                                    ),
                                    _ => (true, None),
                                }
                            }
                        };
                        policy.record_decision(pass, lag);
                        if pass {
                            break;
                        }
                        std::thread::sleep(poll);
                    }
                    policy.record_crossing(
                        entered.elapsed().as_secs_f64(),
                        entered.duration_since(step_t0).as_secs_f64(),
                    );
                }
                worker_done(control_msgs, update_msgs, steps, None, &policy, &encoders)
            })
        })
        .collect();

    // ---- join ----
    let mut control_msgs = 0;
    let mut update_msgs = 0;
    let mut worker_steps = Vec::with_capacity(n);
    let mut lost_reports: Vec<usize> = Vec::new();
    let mut barrier_waits = 0u64;
    let mut stall_ticks = 0u64;
    let mut eff_staleness = Vec::with_capacity(n);
    let mut eff_sample = Vec::with_capacity(n);
    let mut payload_bytes = 0u64;
    let mut fed_back_mass = 0.0f64;
    for wkr in workers {
        let (addr, handle) = wkr.into_parts();
        drop(addr);
        let done = handle.join().expect("worker panicked");
        control_msgs += done.control_msgs;
        update_msgs += done.update_msgs;
        worker_steps.push(done.steps_done);
        barrier_waits += done.barrier.waits;
        stall_ticks += done.barrier.ticks;
        eff_staleness.push(done.barrier.eff_staleness);
        eff_sample.push(done.barrier.eff_sample);
        payload_bytes += done.payload_bytes;
        fed_back_mass += done.fed_back_mass;
        if let Some(s) = done.lost_shard {
            lost_reports.push(s);
        }
    }
    // Coordinator first: its final routing table decides which actor's
    // copy of each block is authoritative.
    let (tx, rx) = channel();
    coord.addr.send(CoordMsg::Stop { reply: tx });
    let stats = rx.recv().expect("coordinator stats");
    let (caddr, chandle) = coord.into_parts();
    drop(caddr);
    chandle.join().expect("coordinator panicked");
    // Shard actors return their state from the body (a killed actor's
    // thread already finished at its crash point — join still recovers
    // its stats and the stale copies it held).
    let mut dones: Vec<ShardDone> = Vec::with_capacity(n_shards);
    for shard in shards {
        shard.addr.send(ShardMsg::Stop);
        let (saddr, shandle) = shard.into_parts();
        drop(saddr);
        dones.push(shandle.join().expect("shard panicked"));
    }
    drop(peers);

    // The coordinator's routing table is the authority on lost shards;
    // worker reports only corroborate it (a worker can abort on a LOST
    // entry before the coordinator hears from every survivor).
    let lost: Vec<usize> =
        (0..n_shards).filter(|&s| stats.route[s] == SHARD_LOST).collect();
    debug_assert!(
        lost_reports.iter().all(|s| lost.contains(s)),
        "worker reported a lost shard the coordinator never declared"
    );

    // Assemble the model from each shard's current primary and verify
    // the replication invariants of the final barrier boundary. Lost
    // blocks keep the initial values — there is no authoritative copy
    // anywhere, and returning zeros would silently look like data.
    let mut model = init_w.clone();
    let mut server_updates = 0u64;
    for s in 0..n_shards {
        let p = stats.route[s];
        if p == SHARD_LOST {
            continue;
        }
        assert!(!stats.dead[p], "shard {s}: no live primary survived");
        let block = dones[p].blocks[s].as_ref().expect("primary block present");
        for (&j, v) in layout.owned[s].iter().zip(block) {
            model[j] = *v;
        }
        // Every live replica must be bitwise-equal to its primary: the
        // run is quiescent (all flush channels disconnected), so lagging
        // even one acked update here would be a lost-durability bug.
        for &t in &stats.replicas_of[s] {
            if stats.dead[t] {
                continue;
            }
            let rb = dones[t].blocks[s].as_ref().expect("replica block present");
            let equal = rb.len() == block.len()
                && rb.iter().zip(block).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(equal, "shard {s}: replica on actor {t} diverged from primary");
        }
    }
    for d in &dones {
        server_updates += d.applied;
    }
    if lost.is_empty() {
        // Quiescence accounting only holds when every worker ran to its
        // full budget; an aborted run has in-flight pushes and missing
        // step reports by construction.
        assert_eq!(server_updates, update_msgs);
        assert_eq!(stats.reports, n as u64 * cfg.steps_per_worker);
    }

    let report = EngineReport {
        steps: worker_steps,
        update_msgs,
        control_msgs,
        wall_secs: start.elapsed().as_secs_f64(),
        model,
        confirmed_dead: stats.confirmed_dead,
        replica_pulls: dones.iter().map(|d| d.replica_pulls).sum(),
        handoff_bytes: dones.iter().map(|d| d.handoff_bytes).sum(),
        discarded_msgs: dones.iter().map(|d| d.discarded).sum(),
        barrier_waits,
        stall_ticks,
        eff_staleness,
        eff_sample,
        compress_mode: cfg.compress.mode_str(),
        payload_bytes,
        fed_back_mass,
        ..EngineReport::default()
    };
    if lost.is_empty() {
        Ok(report)
    } else {
        Err(EngineError {
            reason: format!(
                "shard(s) {lost:?} lost: every placement candidate was \
                 confirmed dead before re-home completed; partial model \
                 keeps the initial values for the lost block(s)"
            ),
            partial: report,
        })
    }
}

/// Salt separating the coordinator's sampling RNG stream from worker
/// streams.
const SERVER_SEED_SALT: u64 = 0x5EA5_1DE5;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::linear::{Dataset, LinearModel};
    use crate::testing::property;
    use crate::util::stats::l2_dist;
    use std::sync::Arc;
    use std::sync::Mutex;

    fn linear_grad_fn(dim: usize, seed: u64) -> (GradFn, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let data = Dataset::synthetic(512, dim, 0.05, &mut rng);
        let w_true = data.w_true.clone();
        let model = Mutex::new(LinearModel::new(dim));
        let f: GradFn = Arc::new(move |w, batch_seed| {
            model
                .lock()
                .unwrap()
                .minibatch_grad(&data, w, batch_seed, 32)
                .to_vec()
        });
        (f, w_true)
    }

    /// A gradient oracle that depends only on the step seed, never on the
    /// model. The multiset of applied updates is then independent of
    /// message interleaving, so any two engine configurations must land on
    /// the same final model up to float-summation rounding.
    fn seed_only_grad_fn(dim: usize) -> GradFn {
        Arc::new(move |_w, seed| {
            let mut rng = Rng::new(seed);
            (0..dim).map(|_| (rng.next_f32() - 0.5) * 0.2).collect()
        })
    }

    /// Replay what any interleaving of `seed_only_grad_fn` updates sums to.
    fn expected_seed_only_model(cfg: &PsConfig, grad: &GradFn) -> Vec<f32> {
        let mut w = vec![0.0f32; cfg.dim];
        for i in 0..cfg.n_workers {
            let wseed = cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ i as u64;
            let mut rng = Rng::new(wseed);
            for _ in 0..cfg.steps_per_worker {
                let g = grad(&w, rng.next_u64());
                for (wi, gi) in w.iter_mut().zip(&g) {
                    *wi -= cfg.lr * gi;
                }
            }
        }
        w
    }

    fn run_method(method: Method) -> (EngineReport, Vec<f32>) {
        let cfg = PsConfig {
            n_workers: 6,
            steps_per_worker: 15,
            method,
            dim: 32,
            lr: 0.05,
            seed: 3,
            ..PsConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 7);
        let report = run(&cfg, vec![0.0; cfg.dim], grad);
        (report, w_true)
    }

    #[test]
    fn all_methods_complete_and_learn() {
        for method in Method::paper_five(2, 2) {
            let (report, w_true) = run_method(method);
            assert_eq!(report.update_msgs, 6 * 15, "{method}");
            let err = l2_dist(&report.model, &w_true);
            let init = l2_dist(&vec![0.0; 32], &w_true);
            assert!(err < init * 0.8, "{method}: {init} -> {err}");
        }
    }

    #[test]
    fn sampled_methods_send_sampling_traffic() {
        let (pbsp, _) = run_method(Method::Pbsp { sample: 2 });
        assert!(pbsp.control_msgs > 6 * 15); // reports + sampling
        let (asp, _) = run_method(Method::Asp);
        assert_eq!(asp.control_msgs, 6 * 15); // step reports only
    }

    #[test]
    fn scheduled_range_partitions_dim() {
        // union of all blocks at a fixed step covers [0, dim) disjointly
        let (dim, nblocks) = (103, 7);
        let mut covered = vec![false; dim];
        for node in 0..nblocks {
            for j in scheduled_range(dim, nblocks, node, 0) {
                assert!(!covered[j], "overlap at {j}");
                covered[j] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        // rotation: the same worker touches different blocks across steps
        assert_ne!(
            scheduled_range(dim, nblocks, 0, 0),
            scheduled_range(dim, nblocks, 0, 1)
        );
    }

    #[test]
    fn shard_range_partitions_dim() {
        for (dim, shards) in [(64usize, 4usize), (103, 7), (10, 16), (1, 1)] {
            let mut covered = vec![false; dim];
            for k in 0..shards.clamp(1, dim) {
                for j in shard_range(dim, shards, k) {
                    assert!(!covered[j], "overlap at {j} (dim={dim} shards={shards})");
                    covered[j] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "gap (dim={dim} shards={shards})");
        }
    }

    #[test]
    fn vnode_layout_partitions_dim_and_flattens_skew() {
        for (dim, shards, vnodes) in [(103usize, 7usize, 0usize), (103, 7, 8), (512, 8, 32)] {
            let l = ShardLayout::new(dim, shards, vnodes);
            let mut covered = vec![false; dim];
            for s in 0..shards {
                for &j in &l.owned[s] {
                    assert!(!covered[j], "double-owned {j}");
                    covered[j] = true;
                    assert_eq!(l.owner_of[j], s);
                }
            }
            assert!(covered.iter().all(|&c| c), "unowned parameter");
        }
        // vnodes == 0 reproduces the contiguous pre-vnode split exactly
        let l = ShardLayout::new(103, 7, 0);
        for s in 0..7 {
            assert_eq!(l.owned[s], shard_range(103, 7, s).collect::<Vec<_>>());
        }
        // successor order: complete, distinct, never self
        let l = ShardLayout::new(512, 8, 16);
        for s in 0..8 {
            assert_eq!(l.succ_order[s].len(), 7);
            assert!(!l.succ_order[s].contains(&s));
            assert_eq!(l.replicas(s, 2).len(), 2);
        }
        // the headline: virtual nodes flatten hash-placement imbalance
        let skewed = ShardLayout::new(4096, 8, 1).imbalance();
        let flat = ShardLayout::new(4096, 8, 64).imbalance();
        assert!(
            skewed / flat >= 3.0,
            "vnodes should flatten push-traffic skew ≥ 3x: {skewed:.2} vs {flat:.2}"
        );
    }

    #[test]
    fn model_parallel_schedule_converges() {
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 30,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 32,
            lr: 0.1,
            seed: 9,
            schedule_blocks: Some(4),
            ..PsConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 7);
        let report = run(&cfg, vec![0.0; cfg.dim], grad);
        let err = l2_dist(&report.model, &w_true);
        let init = l2_dist(&vec![0.0; 32], &w_true);
        assert!(err < init * 0.7, "block-scheduled SGD: {init} -> {err}");
    }

    #[test]
    fn straggler_does_not_deadlock_bsp() {
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 6,
            method: Method::Bsp,
            dim: 16,
            seed: 5,
            stragglers: vec![(0, Duration::from_millis(3))],
            ..PsConfig::default()
        };
        let (grad, _) = linear_grad_fn(16, 9);
        let report = run(&cfg, vec![0.0; 16], grad);
        assert_eq!(report.update_msgs, 24);
    }

    #[test]
    fn sharding_preserves_single_worker_trajectory() {
        // One worker => fully deterministic pull/push interleaving, real
        // (model-dependent) gradients. Sharding must not change the math:
        // the same per-element updates apply in the same order.
        let base = PsConfig {
            n_workers: 1,
            steps_per_worker: 30,
            method: Method::Pssp { sample: 8, staleness: 4 },
            dim: 37, // ragged split across 4 shards
            lr: 0.05,
            seed: 11,
            ..PsConfig::default()
        };
        let (grad, _) = linear_grad_fn(base.dim, 13);
        let reference = run(&base, vec![0.0; base.dim], grad.clone());
        for shards in [2usize, 3, 4] {
            let cfg = PsConfig { n_shards: shards, ..base.clone() };
            let r = run(&cfg, vec![0.0; cfg.dim], grad.clone());
            let d = l2_dist(&r.model, &reference.model);
            assert!(d < 1e-6, "shards={shards}: diverged by {d}");
        }
    }

    #[test]
    fn sharded_engine_matches_unsharded_on_seed_only_grads() {
        // Acceptance sweep: BSP, SSP(4), pSSP(8,4) with n_shards in {1,4}
        // land on the same final model (within 1e-4) as the analytic
        // update sum — multi-worker, real threads.
        for method in [
            Method::Bsp,
            Method::Ssp { staleness: 4 },
            Method::Pssp { sample: 8, staleness: 4 },
        ] {
            let base = PsConfig {
                n_workers: 6,
                steps_per_worker: 20,
                method,
                dim: 50,
                lr: 0.05,
                seed: 21,
                ..PsConfig::default()
            };
            let grad = seed_only_grad_fn(base.dim);
            let expected = expected_seed_only_model(&base, &grad);
            for shards in [1usize, 4] {
                let cfg = PsConfig { n_shards: shards, ..base.clone() };
                let r = run(&cfg, vec![0.0; cfg.dim], grad.clone());
                let d = l2_dist(&r.model, &expected);
                assert!(d < 1e-4, "{method} shards={shards}: off by {d}");
            }
        }
    }

    #[test]
    fn push_batch_coalesces_messages_without_changing_the_sum() {
        let base = PsConfig {
            n_workers: 6,
            steps_per_worker: 16,
            method: Method::Ssp { staleness: 4 },
            dim: 48,
            lr: 0.05,
            seed: 31,
            n_shards: 4,
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(base.dim);
        let expected = expected_seed_only_model(&base, &grad);
        let unbatched = run(&base, vec![0.0; base.dim], grad.clone());
        // every step scatters to all 4 shards
        assert_eq!(unbatched.update_msgs, 6 * 16 * 4);
        let cfg = PsConfig { push_batch: 4, ..base.clone() };
        let batched = run(&cfg, vec![0.0; cfg.dim], grad.clone());
        // 16 steps / batch 4 => 4 flushes per worker, each to all 4 shards
        assert_eq!(batched.update_msgs, 6 * 4 * 4);
        assert!(l2_dist(&unbatched.model, &expected) < 1e-4);
        assert!(l2_dist(&batched.model, &expected) < 1e-4);
    }

    #[test]
    fn aligned_schedule_touches_one_shard_per_step() {
        // schedule_blocks == n_shards: each step's scheduled block is
        // exactly one shard, so a flush sends exactly one message.
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 12,
            method: Method::Asp,
            dim: 64,
            lr: 0.05,
            seed: 41,
            schedule_blocks: Some(4),
            n_shards: 4,
            ..PsConfig::default()
        };
        let (grad, _) = linear_grad_fn(cfg.dim, 43);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        assert_eq!(r.update_msgs, 4 * 12);
    }

    #[test]
    fn push_batch_ragged_tail_is_flushed() {
        // steps not divisible by push_batch: the final partial batch must
        // still reach the shards (total applied updates == analytic sum).
        let cfg = PsConfig {
            n_workers: 3,
            steps_per_worker: 7,
            method: Method::Asp,
            dim: 20,
            lr: 0.1,
            seed: 51,
            n_shards: 2,
            push_batch: 3,
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(cfg.dim);
        let expected = expected_seed_only_model(&cfg, &grad);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        // per worker: flushes after steps 3, 6 and the final step 7
        assert_eq!(r.update_msgs, 3 * 3 * 2);
        assert!(l2_dist(&r.model, &expected) < 1e-4);
    }

    #[test]
    fn replication_preserves_results_and_counters() {
        // Fault-free replication must be invisible: same model, same
        // message counts, zero failover traffic. The bitwise
        // replica == primary check at every run's end is asserted
        // inside `run` itself.
        for replication in [1usize, 2, 3] {
            let cfg = PsConfig {
                n_workers: 4,
                steps_per_worker: 10,
                method: Method::Ssp { staleness: 2 },
                dim: 40,
                lr: 0.05,
                seed: 61,
                n_shards: 4,
                replication,
                ..PsConfig::default()
            };
            let grad = seed_only_grad_fn(cfg.dim);
            let expected = expected_seed_only_model(&cfg, &grad);
            let r = run(&cfg, vec![0.0; cfg.dim], grad);
            assert_eq!(r.update_msgs, 4 * 10 * 4, "r={replication}");
            assert!(l2_dist(&r.model, &expected) < 1e-4, "r={replication}");
            assert_eq!(r.confirmed_dead, 0);
            assert_eq!(r.handoff_bytes, 0, "fault-free run shipped handoffs");
            assert_eq!(r.replica_pulls, 0, "fault-free run read a replica");
        }
    }

    #[test]
    fn topk_compression_cuts_push_bytes_and_keeps_replicas_identical() {
        // Same workload, dense vs compressed pushes. Replication is on,
        // so the bitwise replica == primary assertion inside `run`
        // doubles as the decode-once / forward-identical check. The
        // compressed runs must ack every logical push, ship ≥4× fewer
        // payload bytes (top-k and int4), and still move the model
        // toward the analytic update sum.
        let base = PsConfig {
            n_workers: 4,
            steps_per_worker: 24,
            method: Method::Ssp { staleness: 2 },
            dim: 256,
            lr: 0.05,
            seed: 101,
            n_shards: 2,
            replication: 1,
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(base.dim);
        let expected = expected_seed_only_model(&base, &grad);
        let init = l2_dist(&vec![0.0; base.dim], &expected);
        let dense = run(&base, vec![0.0; base.dim], grad.clone());
        assert_eq!(dense.compress_mode, "dense");
        assert_eq!(dense.fed_back_mass, 0.0, "dense mode fed mass back");
        assert!(dense.payload_bytes > 0, "payload accounting never ran");
        for (mode, top_k, quant) in [("topk", 14, "i8"), ("quant", 14, "i4")] {
            let cfg = PsConfig {
                compress: CompressConfig::parse(mode, top_k, quant).expect("valid mode"),
                ..base.clone()
            };
            let r = run(&cfg, vec![0.0; cfg.dim], grad.clone());
            let label = r.compress_mode;
            assert_eq!(r.update_msgs, dense.update_msgs, "{label}: lost pushes");
            assert!(r.fed_back_mass > 0.0, "{label}: no error feedback");
            assert!(
                r.payload_bytes * 4 <= dense.payload_bytes,
                "{label}: {} bytes is not >=4x under dense {}",
                r.payload_bytes,
                dense.payload_bytes,
            );
            let err = l2_dist(&r.model, &expected);
            assert!(err < init, "{label}: did not move toward the update sum");
        }
    }

    #[test]
    fn compressed_pushes_survive_a_killed_shard_actor() {
        // The chaos bar under compression: the retry path re-sends the
        // stored payload (never re-encodes), so a kill must not disturb
        // the error-feedback stream — every logical push acked once.
        let cfg = PsConfig {
            n_workers: 3,
            steps_per_worker: 8,
            method: Method::Ssp { staleness: 2 },
            dim: 64,
            lr: 0.05,
            seed: 111,
            n_shards: 4,
            replication: 2,
            kill_shard: Some((1, 3)),
            compress: CompressConfig::parse("quant", 8, "i4").expect("valid mode"),
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(cfg.dim);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        assert_eq!(r.update_msgs, 3 * 8 * 4);
        assert_eq!(r.confirmed_dead, 1);
        assert!(r.handoff_bytes > 0);
        assert_eq!(r.compress_mode, "qi4");
        assert!(r.payload_bytes > 0);
    }

    #[test]
    fn prop_replicas_bitwise_equal_at_barrier_boundaries() {
        // Randomised sweep over shapes, methods and placement: every run
        // ends with each replica block bitwise-equal to its primary
        // (checked inside `run`) and the model equal to the analytic
        // update sum.
        property("replica blocks bitwise equal", 10, |g| {
            let n_shards = g.usize_in(2, 5);
            let methods = [
                Method::Asp,
                Method::Bsp,
                Method::Ssp { staleness: 2 },
                Method::Pssp { sample: 3, staleness: 2 },
            ];
            let cfg = PsConfig {
                n_workers: g.usize_in(1, 4),
                steps_per_worker: g.usize_in(1, 8) as u64,
                method: methods[g.usize_in(0, 3)],
                dim: g.usize_in(n_shards, 40),
                lr: 0.05,
                seed: g.rng().next_u64(),
                n_shards,
                push_batch: g.usize_in(1, 3),
                replication: g.usize_in(1, n_shards - 1),
                vnodes: [0usize, 4][g.usize_in(0, 1)],
                ..PsConfig::default()
            };
            let grad = seed_only_grad_fn(cfg.dim);
            let expected = expected_seed_only_model(&cfg, &grad);
            let r = run(&cfg, vec![0.0; cfg.dim], grad);
            let d = l2_dist(&r.model, &expected);
            assert!(d < 1e-3, "off by {d}");
            assert_eq!(r.confirmed_dead, 0);
        });
    }

    #[test]
    fn chaos_killed_shard_actor_loses_no_acked_updates() {
        // The PR's acceptance bar: kill ANY single shard actor mid-run
        // and training completes with zero lost updates — every
        // acknowledged push is in the final model, the death is
        // confirmed, post-kill pulls are replica-served, and the
        // re-home shipped a bulk handoff.
        let base = PsConfig {
            n_workers: 3,
            steps_per_worker: 8,
            method: Method::Ssp { staleness: 2 },
            dim: 33,
            lr: 0.05,
            seed: 71,
            n_shards: 4,
            replication: 2,
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(base.dim);
        let expected = expected_seed_only_model(&base, &grad);
        for victim in 0..base.n_shards {
            let cfg = PsConfig { kill_shard: Some((victim, 3)), ..base.clone() };
            let r = run(&cfg, vec![0.0; cfg.dim], grad.clone());
            // every logical push acked exactly once (retries replace the
            // dead-lettered attempt, never duplicate it)
            assert_eq!(r.update_msgs, 3 * 8 * 4, "victim {victim}");
            let d = l2_dist(&r.model, &expected);
            assert!(d < 1e-4, "victim {victim}: lost updates, off by {d}");
            assert_eq!(r.confirmed_dead, 1, "victim {victim}");
            assert!(r.replica_pulls > 0, "victim {victim}: no replica-served pull");
            assert!(r.handoff_bytes > 0, "victim {victim}: no bulk handoff");
        }
    }

    #[test]
    fn chaos_kill_under_vnode_placement_and_batching() {
        // Same zero-loss bar with consistent-hash placement and push
        // batching — the re-home must hand off vnode-scattered blocks.
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 9,
            method: Method::Pssp { sample: 3, staleness: 2 },
            dim: 50,
            lr: 0.05,
            seed: 81,
            n_shards: 5,
            push_batch: 3,
            replication: 2,
            vnodes: 8,
            kill_shard: Some((2, 2)),
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(cfg.dim);
        let expected = expected_seed_only_model(&cfg, &grad);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        // per worker: flushes after steps 3, 6 and the final step 9,
        // each touching all 5 shards
        assert_eq!(r.update_msgs, 4 * 3 * 5);
        let d = l2_dist(&r.model, &expected);
        assert!(d < 1e-4, "lost updates under vnode placement: off by {d}");
        assert_eq!(r.confirmed_dead, 1);
        assert!(r.handoff_bytes > 0);
    }

    #[test]
    fn losing_the_last_shard_errors_loudly_with_a_partial_report() {
        // The PR 7 regression: kill the only shard of a replication-0 run.
        // This used to abort the whole process (retry-exhaustion assert
        // downstream of the `expect("non-empty ring")` family); now it
        // must come back as a loud `EngineError` carrying the partial
        // report, with the process — and the test harness — intact.
        let cfg = PsConfig {
            n_workers: 2,
            steps_per_worker: 6,
            method: Method::Asp,
            dim: 8,
            lr: 0.1,
            seed: 91,
            n_shards: 1,
            replication: 0,
            kill_shard: Some((0, 2)),
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(cfg.dim);
        let err = try_run(&cfg, vec![1.0; cfg.dim], grad)
            .expect_err("last shard died with no replica — run must not complete");
        assert!(err.reason.contains("[0]"), "reason should name the shard: {}", err.reason);
        let r = &err.partial;
        // The crash fires deterministically after the 2nd acked batch, so
        // exactly two pushes were ever acknowledged.
        assert_eq!(r.update_msgs, 2);
        assert_eq!(r.confirmed_dead, 1);
        // No worker can finish its budget without the model.
        assert_eq!(r.steps.len(), 2);
        assert!(
            r.steps.iter().all(|&s| s < 6),
            "a worker claims a full budget on a lost model: {:?}",
            r.steps
        );
        // The lost block keeps the initial values bitwise — zeros here
        // would masquerade as trained data.
        assert_eq!(r.model, vec![1.0; 8]);
    }

    #[test]
    fn losing_the_last_shard_releases_barrier_waiters() {
        // Same loss under a staleness-bounded barrier: survivors parked at
        // the barrier must be released (aborted peers never report again),
        // hit the dead route at their next pull, and abort — not poll
        // forever. Completing at all is the assertion; the 4-worker spread
        // makes at least one worker barrier-wait across the kill.
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 8,
            method: Method::Ssp { staleness: 1 },
            dim: 12,
            lr: 0.1,
            seed: 92,
            n_shards: 1,
            replication: 0,
            kill_shard: Some((0, 5)),
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(cfg.dim);
        let err = try_run(&cfg, vec![0.0; cfg.dim], grad)
            .expect_err("last shard died with no replica — run must not complete");
        assert_eq!(err.partial.update_msgs, 5);
        assert_eq!(err.partial.confirmed_dead, 1);
    }
}
