//! Parameter-server engine — centralised model, centralised states
//! (paper §4.1 case 1; supports all five barrier methods plus pQuorum).
//!
//! The model vector is partitioned into `n_shards` contiguous blocks, each
//! owned by its own **shard actor** with its own mailbox; barrier state
//! (the [`StepTracker`]) lives in a dedicated **coordinator actor**, so
//! model-plane traffic (pushes/pulls) and control-plane traffic (reports,
//! barrier checks, sampling) never serialise through one queue. Workers
//! run the `pull → compute → push → barrier` loop, accumulating gradients
//! locally for `push_batch` steps and then scattering **one batched
//! message per touched shard**.
//!
//! Pushes are **acknowledged**: a worker reports its new step to the
//! coordinator only after every touched shard has applied its batch, so
//! the single-server invariant "a reported step's updates are visible"
//! survives the split — a BSP/SSP barrier pass still implies the model
//! contains every update of the steps it waited for. `n_shards = 1,
//! push_batch = 1` reproduces the paper's single-server scenario exactly
//! (one mailbox, atomic pulls). With more shards, each *block* is
//! individually consistent but a pull assembles blocks while concurrent
//! pushes land — the standard sharded-parameter-server consistency
//! model. For global methods the coordinator answers barrier checks from
//! its tracker; for PSP methods it *samples* the tracker (the
//! centralised sampling scenario of §5) — workers never see global state
//! either way, which is why the sharding is invisible to barrier
//! semantics: sampled decisions never needed the model actor at all.

use std::ops::Range;
use std::sync::mpsc::{channel, Sender};
use std::time::{Duration, Instant};

use crate::actor::System;
use crate::barrier::{Method, ViewRequirement};
use crate::engine::{EngineReport, GradFn};
use crate::sampling::StepTracker;
use crate::util::rng::Rng;

/// Messages understood by a shard actor (model plane).
pub enum ShardMsg {
    /// Batched gradient slice for this shard's block; the shard applies
    /// `w[j] -= lr * grad[j]` elementwise, then acknowledges so the
    /// worker can report the step as visible.
    Push { grad: Vec<f32>, ack: Sender<()> },
    /// Pull this shard's block: replies `(shard index, block)` so a
    /// worker can gather all shards through one channel.
    Pull { reply: Sender<(usize, Vec<f32>)> },
    /// Shut down and report `(block, pushes applied)`.
    Stop { reply: Sender<(Vec<f32>, u64)> },
}

/// Messages understood by the barrier coordinator (control plane).
pub enum CoordMsg {
    /// Worker reports that it advanced to `step`.
    Report { node: u32, step: u64 },
    /// Global-view barrier check: may a worker at `step` advance?
    Barrier { step: u64, reply: Sender<bool> },
    /// Centralised sampling primitive: min step over β sampled peers.
    SampleMin { node: u32, beta: usize, reply: Sender<Option<u64>> },
    /// Shut down and report the number of step reports handled.
    Stop { reply: Sender<u64> },
}

/// Engine configuration.
#[derive(Clone)]
pub struct PsConfig {
    pub n_workers: usize,
    /// Steps each worker performs.
    pub steps_per_worker: u64,
    pub method: Method,
    pub lr: f32,
    pub dim: usize,
    pub seed: u64,
    /// Poll interval while blocked at the barrier.
    pub poll: Duration,
    /// Artificial per-step compute slowdown for designated stragglers:
    /// (worker index, extra sleep) pairs.
    pub stragglers: Vec<(usize, Duration)>,
    /// The paper's `schedule` API (§4): when `Some(nblocks)`, the model is
    /// partitioned into `nblocks` contiguous blocks and worker `i` at step
    /// `s` is scheduled to update only block `(i + s) mod nblocks` — the
    /// model-parallel pattern where each update touches a disjoint
    /// parameter shard. `None` = data-parallel (full-vector updates).
    pub schedule_blocks: Option<usize>,
    /// Number of model shards (server actors). 1 = the paper's single
    /// central server; more shards split both the model state and the
    /// push/pull queues.
    pub n_shards: usize,
    /// Steps a worker accumulates gradients locally before scattering one
    /// batched push per touched shard. 1 = push every step (paper). The
    /// trade-off is standard gradient accumulation: the server view lags
    /// a worker's local progress by up to `push_batch - 1` updates.
    pub push_batch: usize,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig {
            n_workers: 8,
            steps_per_worker: 20,
            method: Method::Pssp { sample: 3, staleness: 2 },
            lr: 0.05,
            dim: 64,
            seed: 1,
            poll: Duration::from_micros(200),
            stragglers: Vec::new(),
            schedule_blocks: None,
            n_shards: 1,
            push_batch: 1,
        }
    }
}

/// The `schedule` decision: which parameter range worker `node` updates
/// at `step` (paper §4: "decide what model parameters should be computed
/// to update in this step"). Exposed for tests and custom engines.
pub fn scheduled_range(
    dim: usize,
    nblocks: usize,
    node: usize,
    step: u64,
) -> Range<usize> {
    let nblocks = nblocks.clamp(1, dim);
    let block = (node + step as usize) % nblocks;
    let size = dim.div_ceil(nblocks);
    let lo = block * size;
    lo.min(dim)..((block + 1) * size).min(dim)
}

/// The model range owned by shard `shard` when `dim` parameters are split
/// into `n_shards` contiguous blocks (same arithmetic as
/// [`scheduled_range`], so a schedule with `nblocks == n_shards` touches
/// exactly one shard per step).
pub fn shard_range(dim: usize, n_shards: usize, shard: usize) -> Range<usize> {
    let n_shards = n_shards.clamp(1, dim.max(1));
    let size = dim.div_ceil(n_shards);
    let lo = (shard * size).min(dim);
    lo..((shard + 1) * size).min(dim)
}

/// Run the engine to completion: every worker performs its step budget.
///
/// `grad_fn` supplies gradients (pure-Rust model or PJRT artifact);
/// `init_w` is the initial model.
pub fn run(cfg: &PsConfig, init_w: Vec<f32>, grad_fn: GradFn) -> EngineReport {
    assert_eq!(init_w.len(), cfg.dim);
    let start = Instant::now();
    let sys = System::new();
    let method = cfg.method;
    let barrier = method.build();
    let staleness = barrier.staleness();
    let lr = cfg.lr;
    let n = cfg.n_workers;
    let seed = cfg.seed;
    let n_shards = cfg.n_shards.clamp(1, cfg.dim.max(1));
    let push_batch = cfg.push_batch.max(1);
    let ranges: Vec<Range<usize>> =
        (0..n_shards).map(|k| shard_range(cfg.dim, n_shards, k)).collect();

    // ---- shard actors (model plane) ----
    let shards: Vec<_> = ranges
        .iter()
        .enumerate()
        .map(|(k, range)| {
            let block = init_w[range.clone()].to_vec();
            sys.spawn::<ShardMsg, _, _>(&format!("ps-shard-{k}"), move |mb| {
                let mut w = block;
                let mut updates: u64 = 0;
                // Batched receive: one wakeup drains a burst of queued
                // pushes, which is what makes many producers cheap.
                let mut buf = Vec::with_capacity(32);
                'serve: while mb.recv_batch(&mut buf, 32) > 0 {
                    for msg in buf.drain(..) {
                        match msg {
                            ShardMsg::Push { grad, ack } => {
                                updates += 1;
                                for (wi, gi) in w.iter_mut().zip(&grad) {
                                    *wi -= lr * gi;
                                }
                                let _ = ack.send(());
                            }
                            ShardMsg::Pull { reply } => {
                                let _ = reply.send((k, w.clone()));
                            }
                            ShardMsg::Stop { reply } => {
                                let _ = reply.send((w, updates));
                                break 'serve;
                            }
                        }
                    }
                }
            })
        })
        .collect();

    // ---- coordinator actor (control plane: barrier state) ----
    let coord = sys.spawn::<CoordMsg, _, _>("ps-coord", move |mb| {
        let mut tracker = StepTracker::new(n);
        let mut rng = Rng::new(seed ^ SERVER_SEED_SALT);
        let mut scratch = Vec::new();
        let mut reports: u64 = 0;
        while let Some(msg) = mb.recv() {
            match msg {
                CoordMsg::Report { node, step } => {
                    reports += 1;
                    tracker.advance_to(node as usize, step);
                }
                CoordMsg::Barrier { step, reply } => {
                    let pass = tracker.min_step() + staleness >= step;
                    let _ = reply.send(pass);
                }
                CoordMsg::SampleMin { node, beta, reply } => {
                    let m =
                        tracker.sample_min(node as usize, beta, &mut rng, &mut scratch);
                    let _ = reply.send(m);
                }
                CoordMsg::Stop { reply } => {
                    let _ = reply.send(reports);
                    break;
                }
            }
        }
    });

    // ---- workers ----
    let view = method.build().view();
    let workers: Vec<_> = (0..n)
        .map(|i| {
            let shard_addrs: Vec<_> = shards.iter().map(|s| s.addr.clone()).collect();
            let coord_addr = coord.addr.clone();
            let ranges = ranges.clone();
            let grad_fn = grad_fn.clone();
            let poll = cfg.poll;
            let steps = cfg.steps_per_worker;
            let dim = cfg.dim;
            let slow = cfg
                .stragglers
                .iter()
                .find(|&&(idx, _)| idx == i)
                .map(|&(_, d)| d);
            let wseed = cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ i as u64;
            let schedule_blocks = cfg.schedule_blocks;
            sys.spawn::<(), (u64, u64), _>(&format!("ps-worker-{i}"), move |_mb| {
                let mut rng = Rng::new(wseed);
                let mut control_msgs = 0u64;
                let mut update_msgs = 0u64;
                let mut w = vec![0.0f32; dim];
                // Local accumulator for batched pushes + which shards the
                // accumulated updates touched.
                let mut acc = vec![0.0f32; dim];
                let mut touched = vec![false; ranges.len()];
                let mut pending: u64 = 0;
                for step in 0..steps {
                    // pull: gather every shard's block through one channel
                    let (tx, rx) = channel();
                    let mut requested = 0usize;
                    for addr in &shard_addrs {
                        if addr.send(ShardMsg::Pull { reply: tx.clone() }) {
                            requested += 1;
                        }
                    }
                    if requested < shard_addrs.len() {
                        break; // a shard is gone: shutting down
                    }
                    let mut received = 0usize;
                    while received < requested {
                        let Ok((k, block)) = rx.recv() else { break };
                        w[ranges[k].clone()].copy_from_slice(&block);
                        received += 1;
                    }
                    if received < requested {
                        break;
                    }
                    // compute (stragglers sleep extra)
                    if let Some(d) = slow {
                        std::thread::sleep(d);
                    }
                    let g = grad_fn(&w, rng.next_u64());
                    // schedule + accumulate: restrict the update to this
                    // worker's block and fold it into the local batch
                    match schedule_blocks {
                        Some(nblocks) => {
                            let range = scheduled_range(g.len(), nblocks, i, step);
                            for (j, gj) in g[range.clone()].iter().enumerate() {
                                acc[range.start + j] += gj;
                            }
                            for (k, r) in ranges.iter().enumerate() {
                                if r.start < range.end && range.start < r.end {
                                    touched[k] = true;
                                }
                            }
                        }
                        None => {
                            for (aj, gj) in acc.iter_mut().zip(&g) {
                                *aj += gj;
                            }
                            touched.iter_mut().for_each(|t| *t = true);
                        }
                    }
                    pending += 1;
                    // push: scatter one batched message per touched shard,
                    // then wait for the applies — the step report below
                    // must not outrun the updates it stands for
                    if pending == push_batch as u64 || step + 1 == steps {
                        let (ack_tx, ack_rx) = channel();
                        let mut in_flight = 0usize;
                        for (k, r) in ranges.iter().enumerate() {
                            if !touched[k] {
                                continue;
                            }
                            update_msgs += 1;
                            if shard_addrs[k].send(ShardMsg::Push {
                                grad: acc[r.clone()].to_vec(),
                                ack: ack_tx.clone(),
                            }) {
                                in_flight += 1;
                            }
                            acc[r.clone()].iter_mut().for_each(|v| *v = 0.0);
                            touched[k] = false;
                        }
                        drop(ack_tx);
                        for _ in 0..in_flight {
                            if ack_rx.recv().is_err() {
                                break;
                            }
                        }
                        pending = 0;
                    }
                    // report the new step (control plane, every step)
                    control_msgs += 1;
                    coord_addr.send(CoordMsg::Report {
                        node: i as u32,
                        step: step + 1,
                    });
                    // barrier (not after the final step)
                    if step + 1 == steps {
                        break;
                    }
                    loop {
                        let pass = match view {
                            ViewRequirement::None => true,
                            ViewRequirement::Global => {
                                let (tx, rx) = channel();
                                control_msgs += 2;
                                if !coord_addr
                                    .send(CoordMsg::Barrier { step: step + 1, reply: tx })
                                {
                                    return (control_msgs, update_msgs);
                                }
                                rx.recv().unwrap_or(true)
                            }
                            ViewRequirement::Sample(beta) => {
                                let (tx, rx) = channel();
                                control_msgs += 2 * beta as u64;
                                if !coord_addr.send(CoordMsg::SampleMin {
                                    node: i as u32,
                                    beta,
                                    reply: tx,
                                }) {
                                    return (control_msgs, update_msgs);
                                }
                                match rx.recv() {
                                    Ok(Some(min)) => min + staleness >= step + 1,
                                    _ => true,
                                }
                            }
                        };
                        if pass {
                            break;
                        }
                        std::thread::sleep(poll);
                    }
                }
                (control_msgs, update_msgs)
            })
        })
        .collect();

    // ---- join ----
    let mut control_msgs = 0;
    let mut update_msgs = 0;
    for wkr in workers {
        let (addr, handle) = wkr.into_parts();
        drop(addr);
        let (c, u) = handle.join().expect("worker panicked");
        control_msgs += c;
        update_msgs += u;
    }
    let mut model = vec![0.0f32; cfg.dim];
    let mut server_updates = 0u64;
    for (k, shard) in shards.into_iter().enumerate() {
        let (tx, rx) = channel();
        shard.addr.send(ShardMsg::Stop { reply: tx });
        let (block, updates) = rx.recv().expect("shard stats");
        model[ranges[k].clone()].copy_from_slice(&block);
        server_updates += updates;
        let (saddr, shandle) = shard.into_parts();
        drop(saddr);
        shandle.join().expect("shard panicked");
    }
    let (tx, rx) = channel();
    coord.addr.send(CoordMsg::Stop { reply: tx });
    let reports = rx.recv().expect("coordinator stats");
    let (caddr, chandle) = coord.into_parts();
    drop(caddr);
    chandle.join().expect("coordinator panicked");
    assert_eq!(server_updates, update_msgs);
    assert_eq!(reports, n as u64 * cfg.steps_per_worker);

    EngineReport {
        steps: vec![cfg.steps_per_worker; n],
        update_msgs,
        control_msgs,
        wall_secs: start.elapsed().as_secs_f64(),
        model,
        ..EngineReport::default()
    }
}

/// Salt separating the coordinator's sampling RNG stream from worker
/// streams.
const SERVER_SEED_SALT: u64 = 0x5EA5_1DE5;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::linear::{Dataset, LinearModel};
    use crate::util::stats::l2_dist;
    use std::sync::Arc;
    use std::sync::Mutex;

    fn linear_grad_fn(dim: usize, seed: u64) -> (GradFn, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let data = Dataset::synthetic(512, dim, 0.05, &mut rng);
        let w_true = data.w_true.clone();
        let model = Mutex::new(LinearModel::new(dim));
        let f: GradFn = Arc::new(move |w, batch_seed| {
            model
                .lock()
                .unwrap()
                .minibatch_grad(&data, w, batch_seed, 32)
                .to_vec()
        });
        (f, w_true)
    }

    /// A gradient oracle that depends only on the step seed, never on the
    /// model. The multiset of applied updates is then independent of
    /// message interleaving, so any two engine configurations must land on
    /// the same final model up to float-summation rounding.
    fn seed_only_grad_fn(dim: usize) -> GradFn {
        Arc::new(move |_w, seed| {
            let mut rng = Rng::new(seed);
            (0..dim).map(|_| (rng.next_f32() - 0.5) * 0.2).collect()
        })
    }

    /// Replay what any interleaving of `seed_only_grad_fn` updates sums to.
    fn expected_seed_only_model(cfg: &PsConfig, grad: &GradFn) -> Vec<f32> {
        let mut w = vec![0.0f32; cfg.dim];
        for i in 0..cfg.n_workers {
            let wseed = cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ i as u64;
            let mut rng = Rng::new(wseed);
            for _ in 0..cfg.steps_per_worker {
                let g = grad(&w, rng.next_u64());
                for (wi, gi) in w.iter_mut().zip(&g) {
                    *wi -= cfg.lr * gi;
                }
            }
        }
        w
    }

    fn run_method(method: Method) -> (EngineReport, Vec<f32>) {
        let cfg = PsConfig {
            n_workers: 6,
            steps_per_worker: 15,
            method,
            dim: 32,
            lr: 0.05,
            seed: 3,
            ..PsConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 7);
        let report = run(&cfg, vec![0.0; cfg.dim], grad);
        (report, w_true)
    }

    #[test]
    fn all_methods_complete_and_learn() {
        for method in Method::paper_five(2, 2) {
            let (report, w_true) = run_method(method);
            assert_eq!(report.update_msgs, 6 * 15, "{method}");
            let err = l2_dist(&report.model, &w_true);
            let init = l2_dist(&vec![0.0; 32], &w_true);
            assert!(err < init * 0.8, "{method}: {init} -> {err}");
        }
    }

    #[test]
    fn sampled_methods_send_sampling_traffic() {
        let (pbsp, _) = run_method(Method::Pbsp { sample: 2 });
        assert!(pbsp.control_msgs > 6 * 15); // reports + sampling
        let (asp, _) = run_method(Method::Asp);
        assert_eq!(asp.control_msgs, 6 * 15); // step reports only
    }

    #[test]
    fn scheduled_range_partitions_dim() {
        // union of all blocks at a fixed step covers [0, dim) disjointly
        let (dim, nblocks) = (103, 7);
        let mut covered = vec![false; dim];
        for node in 0..nblocks {
            for j in scheduled_range(dim, nblocks, node, 0) {
                assert!(!covered[j], "overlap at {j}");
                covered[j] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        // rotation: the same worker touches different blocks across steps
        assert_ne!(
            scheduled_range(dim, nblocks, 0, 0),
            scheduled_range(dim, nblocks, 0, 1)
        );
    }

    #[test]
    fn shard_range_partitions_dim() {
        for (dim, shards) in [(64usize, 4usize), (103, 7), (10, 16), (1, 1)] {
            let mut covered = vec![false; dim];
            for k in 0..shards.clamp(1, dim) {
                for j in shard_range(dim, shards, k) {
                    assert!(!covered[j], "overlap at {j} (dim={dim} shards={shards})");
                    covered[j] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "gap (dim={dim} shards={shards})");
        }
    }

    #[test]
    fn model_parallel_schedule_converges() {
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 30,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 32,
            lr: 0.1,
            seed: 9,
            schedule_blocks: Some(4),
            ..PsConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 7);
        let report = run(&cfg, vec![0.0; cfg.dim], grad);
        let err = l2_dist(&report.model, &w_true);
        let init = l2_dist(&vec![0.0; 32], &w_true);
        assert!(err < init * 0.7, "block-scheduled SGD: {init} -> {err}");
    }

    #[test]
    fn straggler_does_not_deadlock_bsp() {
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 6,
            method: Method::Bsp,
            dim: 16,
            seed: 5,
            stragglers: vec![(0, Duration::from_millis(3))],
            ..PsConfig::default()
        };
        let (grad, _) = linear_grad_fn(16, 9);
        let report = run(&cfg, vec![0.0; 16], grad);
        assert_eq!(report.update_msgs, 24);
    }

    #[test]
    fn sharding_preserves_single_worker_trajectory() {
        // One worker => fully deterministic pull/push interleaving, real
        // (model-dependent) gradients. Sharding must not change the math:
        // the same per-element updates apply in the same order.
        let base = PsConfig {
            n_workers: 1,
            steps_per_worker: 30,
            method: Method::Pssp { sample: 8, staleness: 4 },
            dim: 37, // ragged split across 4 shards
            lr: 0.05,
            seed: 11,
            ..PsConfig::default()
        };
        let (grad, _) = linear_grad_fn(base.dim, 13);
        let reference = run(&base, vec![0.0; base.dim], grad.clone());
        for shards in [2usize, 3, 4] {
            let cfg = PsConfig { n_shards: shards, ..base.clone() };
            let r = run(&cfg, vec![0.0; cfg.dim], grad.clone());
            let d = l2_dist(&r.model, &reference.model);
            assert!(d < 1e-6, "shards={shards}: diverged by {d}");
        }
    }

    #[test]
    fn sharded_engine_matches_unsharded_on_seed_only_grads() {
        // Acceptance sweep: BSP, SSP(4), pSSP(8,4) with n_shards in {1,4}
        // land on the same final model (within 1e-4) as the analytic
        // update sum — multi-worker, real threads.
        for method in [
            Method::Bsp,
            Method::Ssp { staleness: 4 },
            Method::Pssp { sample: 8, staleness: 4 },
        ] {
            let base = PsConfig {
                n_workers: 6,
                steps_per_worker: 20,
                method,
                dim: 50,
                lr: 0.05,
                seed: 21,
                ..PsConfig::default()
            };
            let grad = seed_only_grad_fn(base.dim);
            let expected = expected_seed_only_model(&base, &grad);
            for shards in [1usize, 4] {
                let cfg = PsConfig { n_shards: shards, ..base.clone() };
                let r = run(&cfg, vec![0.0; cfg.dim], grad.clone());
                let d = l2_dist(&r.model, &expected);
                assert!(d < 1e-4, "{method} shards={shards}: off by {d}");
            }
        }
    }

    #[test]
    fn push_batch_coalesces_messages_without_changing_the_sum() {
        let base = PsConfig {
            n_workers: 6,
            steps_per_worker: 16,
            method: Method::Ssp { staleness: 4 },
            dim: 48,
            lr: 0.05,
            seed: 31,
            n_shards: 4,
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(base.dim);
        let expected = expected_seed_only_model(&base, &grad);
        let unbatched = run(&base, vec![0.0; base.dim], grad.clone());
        // every step scatters to all 4 shards
        assert_eq!(unbatched.update_msgs, 6 * 16 * 4);
        let cfg = PsConfig { push_batch: 4, ..base.clone() };
        let batched = run(&cfg, vec![0.0; cfg.dim], grad.clone());
        // 16 steps / batch 4 => 4 flushes per worker, each to all 4 shards
        assert_eq!(batched.update_msgs, 6 * 4 * 4);
        assert!(l2_dist(&unbatched.model, &expected) < 1e-4);
        assert!(l2_dist(&batched.model, &expected) < 1e-4);
    }

    #[test]
    fn aligned_schedule_touches_one_shard_per_step() {
        // schedule_blocks == n_shards: each step's scheduled block is
        // exactly one shard, so a flush sends exactly one message.
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 12,
            method: Method::Asp,
            dim: 64,
            lr: 0.05,
            seed: 41,
            schedule_blocks: Some(4),
            n_shards: 4,
            ..PsConfig::default()
        };
        let (grad, _) = linear_grad_fn(cfg.dim, 43);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        assert_eq!(r.update_msgs, 4 * 12);
    }

    #[test]
    fn push_batch_ragged_tail_is_flushed() {
        // steps not divisible by push_batch: the final partial batch must
        // still reach the shards (total applied updates == analytic sum).
        let cfg = PsConfig {
            n_workers: 3,
            steps_per_worker: 7,
            method: Method::Asp,
            dim: 20,
            lr: 0.1,
            seed: 51,
            n_shards: 2,
            push_batch: 3,
            ..PsConfig::default()
        };
        let grad = seed_only_grad_fn(cfg.dim);
        let expected = expected_seed_only_model(&cfg, &grad);
        let r = run(&cfg, vec![0.0; cfg.dim], grad);
        // per worker: flushes after steps 3, 6 and the final step 7
        assert_eq!(r.update_msgs, 3 * 3 * 2);
        assert!(l2_dist(&r.model, &expected) < 1e-4);
    }
}
