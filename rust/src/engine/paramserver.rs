//! Parameter-server engine — centralised model, centralised states
//! (paper §4.1 case 1; supports all five barrier methods).
//!
//! One server actor owns the model vector and the [`StepTracker`]; worker
//! threads run the `pull → compute → push → barrier` loop. For global
//! methods the server answers barrier checks from its tracker; for PSP
//! methods the server *samples* its tracker (the centralised sampling
//! scenario of §5) — workers never see global state either way.

use std::sync::mpsc::{channel, Sender};
use std::time::{Duration, Instant};

use crate::actor::System;
use crate::barrier::{Method, ViewRequirement};
use crate::engine::{EngineReport, GradFn};
use crate::sampling::StepTracker;
use crate::util::rng::Rng;

/// Messages understood by the server actor.
pub enum ServerMsg {
    /// Worker pushes a gradient; server applies `w -= lr * g`.
    Push { grad: Vec<f32> },
    /// Worker pulls the current model.
    Pull { reply: Sender<Vec<f32>> },
    /// Worker reports that it advanced to `step`.
    Report { node: u32, step: u64 },
    /// Global-view barrier check: may `node` (at `step`) advance?
    Barrier { step: u64, reply: Sender<bool> },
    /// Centralised sampling primitive: min step over β sampled peers.
    SampleMin { node: u32, beta: usize, reply: Sender<Option<u64>> },
    /// Shut down and report stats.
    Stop { reply: Sender<(Vec<f32>, u64)> },
}

/// Engine configuration.
#[derive(Clone)]
pub struct PsConfig {
    pub n_workers: usize,
    /// Steps each worker performs.
    pub steps_per_worker: u64,
    pub method: Method,
    pub lr: f32,
    pub dim: usize,
    pub seed: u64,
    /// Poll interval while blocked at the barrier.
    pub poll: Duration,
    /// Artificial per-step compute slowdown for designated stragglers:
    /// (worker index, extra sleep) pairs.
    pub stragglers: Vec<(usize, Duration)>,
    /// The paper's `schedule` API (§4): when `Some(nblocks)`, the model is
    /// partitioned into `nblocks` contiguous blocks and worker `i` at step
    /// `s` is scheduled to update only block `(i + s) mod nblocks` — the
    /// model-parallel pattern where each update touches a disjoint
    /// parameter shard. `None` = data-parallel (full-vector updates).
    pub schedule_blocks: Option<usize>,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig {
            n_workers: 8,
            steps_per_worker: 20,
            method: Method::Pssp { sample: 3, staleness: 2 },
            lr: 0.05,
            dim: 64,
            seed: 1,
            poll: Duration::from_micros(200),
            stragglers: Vec::new(),
            schedule_blocks: None,
        }
    }
}

/// The `schedule` decision: which parameter range worker `node` updates
/// at `step` (paper §4: "decide what model parameters should be computed
/// to update in this step"). Exposed for tests and custom engines.
pub fn scheduled_range(
    dim: usize,
    nblocks: usize,
    node: usize,
    step: u64,
) -> std::ops::Range<usize> {
    let nblocks = nblocks.clamp(1, dim);
    let block = (node + step as usize) % nblocks;
    let size = dim.div_ceil(nblocks);
    let lo = block * size;
    lo.min(dim)..((block + 1) * size).min(dim)
}

/// Run the engine to completion: every worker performs its step budget.
///
/// `grad_fn` supplies gradients (pure-Rust model or PJRT artifact);
/// `init_w` is the initial model.
pub fn run(cfg: &PsConfig, init_w: Vec<f32>, grad_fn: GradFn) -> EngineReport {
    assert_eq!(init_w.len(), cfg.dim);
    let start = Instant::now();
    let sys = System::new();
    let method = cfg.method;
    let barrier = method.build();
    let staleness = barrier.staleness();
    let lr = cfg.lr;
    let n = cfg.n_workers;
    let seed = cfg.seed;

    // ---- server actor ----
    let server = sys.spawn::<ServerMsg, _, _>("ps-server", move |mb| {
        let mut w = init_w;
        let mut tracker = StepTracker::new(n);
        let mut rng = Rng::new(seed ^ SERVER_SEED_SALT);
        let mut scratch = Vec::new();
        let mut updates: u64 = 0;
        while let Some(msg) = mb.recv() {
            match msg {
                ServerMsg::Push { grad } => {
                    updates += 1;
                    for (wi, gi) in w.iter_mut().zip(&grad) {
                        *wi -= lr * gi;
                    }
                }
                ServerMsg::Pull { reply } => {
                    let _ = reply.send(w.clone());
                }
                ServerMsg::Report { node, step } => {
                    debug_assert_eq!(tracker.step_of(node as usize) + 1, step);
                    tracker.advance(node as usize);
                }
                ServerMsg::Barrier { step, reply } => {
                    let pass = tracker.min_step() + staleness >= step;
                    let _ = reply.send(pass);
                }
                ServerMsg::SampleMin { node, beta, reply } => {
                    let m =
                        tracker.sample_min(node as usize, beta, &mut rng, &mut scratch);
                    let _ = reply.send(m);
                }
                ServerMsg::Stop { reply } => {
                    let _ = reply.send((w, updates));
                    break;
                }
            }
        }
    });

    // ---- workers ----
    let view = method.build().view();
    let workers: Vec<_> = (0..n)
        .map(|i| {
            let server_addr = server.addr.clone();
            let grad_fn = grad_fn.clone();
            let poll = cfg.poll;
            let steps = cfg.steps_per_worker;
            let slow = cfg
                .stragglers
                .iter()
                .find(|&&(idx, _)| idx == i)
                .map(|&(_, d)| d);
            let wseed = cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ i as u64;
            let schedule_blocks = cfg.schedule_blocks;
            sys.spawn::<(), (u64, u64), _>(&format!("ps-worker-{i}"), move |_mb| {
                let mut rng = Rng::new(wseed);
                let mut control_msgs = 0u64;
                let mut update_msgs = 0u64;
                for step in 0..steps {
                    // pull
                    let (tx, rx) = channel();
                    if !server_addr.send(ServerMsg::Pull { reply: tx }) {
                        break;
                    }
                    let Ok(w) = rx.recv() else { break };
                    // compute (stragglers sleep extra)
                    if let Some(d) = slow {
                        std::thread::sleep(d);
                    }
                    let mut g = grad_fn(&w, rng.next_u64());
                    // schedule: restrict the update to this worker's block
                    if let Some(nblocks) = schedule_blocks {
                        let range = scheduled_range(g.len(), nblocks, i, step);
                        for (j, gj) in g.iter_mut().enumerate() {
                            if !range.contains(&j) {
                                *gj = 0.0;
                            }
                        }
                    }
                    // push
                    update_msgs += 1;
                    server_addr.send(ServerMsg::Push { grad: g });
                    // report new step
                    control_msgs += 1;
                    server_addr.send(ServerMsg::Report {
                        node: i as u32,
                        step: step + 1,
                    });
                    // barrier (not after the final step)
                    if step + 1 == steps {
                        break;
                    }
                    loop {
                        let pass = match view {
                            ViewRequirement::None => true,
                            ViewRequirement::Global => {
                                let (tx, rx) = channel();
                                control_msgs += 2;
                                if !server_addr
                                    .send(ServerMsg::Barrier { step: step + 1, reply: tx })
                                {
                                    return (control_msgs, update_msgs);
                                }
                                rx.recv().unwrap_or(true)
                            }
                            ViewRequirement::Sample(beta) => {
                                let (tx, rx) = channel();
                                control_msgs += 2 * beta as u64;
                                if !server_addr.send(ServerMsg::SampleMin {
                                    node: i as u32,
                                    beta,
                                    reply: tx,
                                }) {
                                    return (control_msgs, update_msgs);
                                }
                                match rx.recv() {
                                    Ok(Some(min)) => min + staleness >= step + 1,
                                    _ => true,
                                }
                            }
                        };
                        if pass {
                            break;
                        }
                        std::thread::sleep(poll);
                    }
                }
                (control_msgs, update_msgs)
            })
        })
        .collect();

    // ---- join ----
    let mut control_msgs = 0;
    let mut update_msgs = 0;
    for wkr in workers {
        let (addr, handle) = wkr.into_parts();
        drop(addr);
        let (c, u) = handle.join().expect("worker panicked");
        control_msgs += c;
        update_msgs += u;
    }
    let (tx, rx) = channel();
    server.addr.send(ServerMsg::Stop { reply: tx });
    let (model, server_updates) = rx.recv().expect("server stats");
    let (saddr, shandle) = server.into_parts();
    drop(saddr);
    shandle.join().expect("server panicked");
    assert_eq!(server_updates, update_msgs);

    EngineReport {
        steps: vec![cfg.steps_per_worker; n],
        update_msgs,
        control_msgs,
        wall_secs: start.elapsed().as_secs_f64(),
        model,
    }
}

/// Salt separating the server's sampling RNG stream from worker streams.
const SERVER_SEED_SALT: u64 = 0x5EA5_1DE5;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::linear::{Dataset, LinearModel};
    use crate::util::stats::l2_dist;
    use std::sync::Arc;
    use std::sync::Mutex;

    fn linear_grad_fn(dim: usize, seed: u64) -> (GradFn, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let data = Dataset::synthetic(512, dim, 0.05, &mut rng);
        let w_true = data.w_true.clone();
        let model = Mutex::new(LinearModel::new(dim));
        let f: GradFn = Arc::new(move |w, batch_seed| {
            model
                .lock()
                .unwrap()
                .minibatch_grad(&data, w, batch_seed, 32)
                .to_vec()
        });
        (f, w_true)
    }

    fn run_method(method: Method) -> (EngineReport, Vec<f32>) {
        let cfg = PsConfig {
            n_workers: 6,
            steps_per_worker: 15,
            method,
            dim: 32,
            lr: 0.05,
            seed: 3,
            ..PsConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 7);
        let report = run(&cfg, vec![0.0; cfg.dim], grad);
        (report, w_true)
    }

    #[test]
    fn all_methods_complete_and_learn() {
        for method in Method::paper_five(2, 2) {
            let (report, w_true) = run_method(method);
            assert_eq!(report.update_msgs, 6 * 15, "{method}");
            let err = l2_dist(&report.model, &w_true);
            let init = l2_dist(&vec![0.0; 32], &w_true);
            assert!(err < init * 0.8, "{method}: {init} -> {err}");
        }
    }

    #[test]
    fn sampled_methods_send_sampling_traffic() {
        let (pbsp, _) = run_method(Method::Pbsp { sample: 2 });
        assert!(pbsp.control_msgs > 6 * 15); // reports + sampling
        let (asp, _) = run_method(Method::Asp);
        assert_eq!(asp.control_msgs, 6 * 15); // step reports only
    }

    #[test]
    fn scheduled_range_partitions_dim() {
        // union of all blocks at a fixed step covers [0, dim) disjointly
        let (dim, nblocks) = (103, 7);
        let mut covered = vec![false; dim];
        for node in 0..nblocks {
            for j in scheduled_range(dim, nblocks, node, 0) {
                assert!(!covered[j], "overlap at {j}");
                covered[j] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        // rotation: the same worker touches different blocks across steps
        assert_ne!(
            scheduled_range(dim, nblocks, 0, 0),
            scheduled_range(dim, nblocks, 0, 1)
        );
    }

    #[test]
    fn model_parallel_schedule_converges() {
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 30,
            method: Method::Pssp { sample: 2, staleness: 2 },
            dim: 32,
            lr: 0.1,
            seed: 9,
            schedule_blocks: Some(4),
            ..PsConfig::default()
        };
        let (grad, w_true) = linear_grad_fn(cfg.dim, 7);
        let report = run(&cfg, vec![0.0; cfg.dim], grad);
        let err = l2_dist(&report.model, &w_true);
        let init = l2_dist(&vec![0.0; 32], &w_true);
        assert!(err < init * 0.7, "block-scheduled SGD: {init} -> {err}");
    }

    #[test]
    fn straggler_does_not_deadlock_bsp() {
        let cfg = PsConfig {
            n_workers: 4,
            steps_per_worker: 6,
            method: Method::Bsp,
            dim: 16,
            seed: 5,
            stragglers: vec![(0, Duration::from_millis(3))],
            ..PsConfig::default()
        };
        let (grad, _) = linear_grad_fn(16, 9);
        let report = run(&cfg, vec![0.0; 16], grad);
        assert_eq!(report.update_msgs, 24);
    }
}
