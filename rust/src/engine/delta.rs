//! One owner for model-delta payloads: dense, sparsified, quantized.
//!
//! Before this module, a "model delta" was spelled independently in five
//! places — `Arc<[f32]>` in [`super::gossip`] rumors, per-shard `Vec<f32>`
//! push batches in [`super::paramserver`], raw float arrays in the
//! [`super::transport`] wire frames, dense accumulators in
//! [`super::node`]/[`super::p2p`] originations, and the delta ring in
//! [`crate::sim::snapshots`]. Every layer now carries a [`DeltaPayload`]
//! instead, which makes *approximate communication* (ASAP-style top-k
//! sparsification and int8/f16 quantization, the ROADMAP item-4 byte
//! lever) a property of the payload, not of any one engine:
//!
//! * [`DeltaPayload::Dense`] — the legacy exact vector; with
//!   `[compress] mode = "dense"` (the default) every layer is
//!   value-identical to the pre-refactor code, which is what lets the
//!   seed-42 goldens keep replaying bit-for-bit.
//! * [`DeltaPayload::TopK`] — the `k` largest-magnitude coordinates as
//!   `(index, value)` pairs; ~`8k` payload bytes instead of `4·dim`.
//! * [`DeltaPayload::QuantI8`] — linear int8 quantization, one shared
//!   `scale = max|v| / 127`; ~`dim` bytes.
//! * [`DeltaPayload::QuantF16`] — IEEE half-precision (round to nearest
//!   even, saturating); `2·dim` bytes.
//! * [`DeltaPayload::QuantI4`] — linear int4 (codes in `[-7, 7]`) packed
//!   two per byte; ~`dim/2` bytes. This is the quantized mode that
//!   clears the ≥4× byte-cut floor: int8 against dense f32 is
//!   asymptotically `4× − ε` once the scale + length header is counted,
//!   so a sub-byte code is what actually gets past 4×.
//!
//! Lossy modes only converge because of **error feedback**
//! ([`DeltaEncoder`]): the mass a payload drops or rounds away is kept
//! in a per-origin residual and re-injected into the next delta, so the
//! *sum* of everything an origin ever ships equals the sum of its true
//! deltas up to the (bounded) residual still in flight — the property
//! `error_feedback_conserves_the_delta_sum` pins below, and the reason
//! top-k with `k = dim` is *exactly* the dense run.
//!
//! The wire form (`payload_wire_len`/`encode_into`/`decode_from`) is
//! part of the cross-language codec contract: `tools/verify_wire_port.py`
//! carries a bit-exact Python port of both the byte layout *and* the
//! encoders, pinned by the known-answer constants in the tests below and
//! by the two digests in `transport.rs` (`CROSS_DIGEST` for the wire
//! bytes, `ENCODER_DIGEST` for the encoder arithmetic + residual).

use std::sync::Arc;

// ---------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------

/// Which payload form an origin ships.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressMode {
    /// Exact dense f32 vector (legacy wire form, no residual).
    Dense,
    /// Keep the `top_k` largest-|v| coordinates; rest feeds the residual.
    TopK,
    /// Linear int8: one `scale` + a code per coordinate.
    QuantI8,
    /// IEEE half precision per coordinate.
    QuantF16,
    /// Linear int4: codes in `[-7, 7]`, two per byte.
    QuantI4,
}

/// The `[compress]` knobs every engine and the simulator accept.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompressConfig {
    pub mode: CompressMode,
    /// Coordinates kept per delta in [`CompressMode::TopK`] (clamped to
    /// `[1, dim]` at encode time; ignored by the other modes).
    pub top_k: usize,
}

impl Default for CompressConfig {
    fn default() -> CompressConfig {
        CompressConfig { mode: CompressMode::Dense, top_k: 32 }
    }
}

impl CompressConfig {
    /// Parse the config/CLI triple (`mode`, `top_k`, `quant`). `mode` is
    /// `dense` | `topk` | `quant`; `quant` picks the quantizer (`i8` |
    /// `f16` | `i4`) when mode is `quant`. `None` on anything
    /// unrecognised.
    pub fn parse(mode: &str, top_k: usize, quant: &str) -> Option<CompressConfig> {
        let mode = match mode {
            "dense" => CompressMode::Dense,
            "topk" => CompressMode::TopK,
            "quant" => match quant {
                "i8" => CompressMode::QuantI8,
                "f16" => CompressMode::QuantF16,
                "i4" => CompressMode::QuantI4,
                _ => return None,
            },
            _ => return None,
        };
        Some(CompressConfig { mode, top_k: top_k.max(1) })
    }

    /// True when every payload is the exact legacy dense form.
    pub fn is_dense(&self) -> bool {
        self.mode == CompressMode::Dense
    }

    /// Short display / report name for the mode.
    pub fn mode_str(&self) -> &'static str {
        match self.mode {
            CompressMode::Dense => "dense",
            CompressMode::TopK => "topk",
            CompressMode::QuantI8 => "qi8",
            CompressMode::QuantF16 => "qf16",
            CompressMode::QuantI4 => "qi4",
        }
    }

    /// Wire tag for the mode (rides the `Welcome` frame so every joiner
    /// encodes payloads identically to the seed).
    pub fn mode_tag(&self) -> u8 {
        match self.mode {
            CompressMode::Dense => 0,
            CompressMode::TopK => 1,
            CompressMode::QuantI8 => 2,
            CompressMode::QuantF16 => 3,
            CompressMode::QuantI4 => 4,
        }
    }

    /// Inverse of [`CompressConfig::mode_tag`].
    pub fn from_tag(tag: u8, top_k: usize) -> Option<CompressConfig> {
        let mode = match tag {
            0 => CompressMode::Dense,
            1 => CompressMode::TopK,
            2 => CompressMode::QuantI8,
            3 => CompressMode::QuantF16,
            4 => CompressMode::QuantI4,
            _ => return None,
        };
        Some(CompressConfig { mode, top_k: top_k.max(1) })
    }
}

// ---------------------------------------------------------------------
// The payload
// ---------------------------------------------------------------------

/// A model delta in whichever form the origin's [`CompressConfig`]
/// produced. Cheap to clone (the bulk is behind `Arc`), which is what
/// the gossip plane's per-destination rumor copies rely on.
#[derive(Debug, Clone, PartialEq)]
pub enum DeltaPayload {
    /// Exact dense vector; applying adds `v[i]` to `w[i]`.
    Dense(Arc<[f32]>),
    /// Sparse `(idx, val)` pairs over a `dim`-sized vector. Indices are
    /// canonical: strictly ascending, all `< dim` (the decoder rejects
    /// anything else, so applying never writes out of bounds).
    TopK { dim: u32, idx: Arc<[u32]>, val: Arc<[f32]> },
    /// `v[i] = scale * codes[i]`.
    QuantI8 { scale: f32, codes: Arc<[i8]> },
    /// `v[i] = f16_to_f32(codes[i])`.
    QuantF16 { codes: Arc<[u16]> },
    /// `v[i] = scale * c_i` with 4-bit two's-complement codes in
    /// `[-7, 7]` packed two per byte — even index in the low nibble; an
    /// odd `n` leaves the final high nibble zero (the decoder enforces
    /// that, keeping the wire form canonical).
    QuantI4 { n: u32, scale: f32, packed: Arc<[u8]> },
}

/// Sign-extend the 4-bit code for coordinate `i` out of the packed form.
fn i4_code(packed: &[u8], i: usize) -> i8 {
    let byte = packed[i / 2];
    let nib = if i % 2 == 0 { byte & 0x0f } else { byte >> 4 };
    ((nib as i8) << 4) >> 4
}

impl DeltaPayload {
    /// The exact dense payload (the only place in `engine/` that builds
    /// an `Arc<[f32]>` delta).
    pub fn dense(v: impl Into<Arc<[f32]>>) -> DeltaPayload {
        DeltaPayload::Dense(v.into())
    }

    /// Logical vector length.
    pub fn dim(&self) -> usize {
        match self {
            DeltaPayload::Dense(v) => v.len(),
            DeltaPayload::TopK { dim, .. } => *dim as usize,
            DeltaPayload::QuantI8 { codes, .. } => codes.len(),
            DeltaPayload::QuantF16 { codes } => codes.len(),
            DeltaPayload::QuantI4 { n, .. } => *n as usize,
        }
    }

    /// The dense slice when this is an exact payload (tests and the
    /// snapshot ring's zero-copy reuse).
    pub fn dense_slice(&self) -> Option<&[f32]> {
        match self {
            DeltaPayload::Dense(v) => Some(v),
            _ => None,
        }
    }

    /// `w[i] += v[i]` — the gossip/p2p/node application convention. For
    /// `Dense` this is exactly the legacy `add_delta` loop.
    pub fn apply_into(&self, w: &mut [f32]) {
        match self {
            DeltaPayload::Dense(v) => {
                for (wi, di) in w.iter_mut().zip(v.iter()) {
                    *wi += di;
                }
            }
            DeltaPayload::TopK { idx, val, .. } => {
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    if let Some(wi) = w.get_mut(i as usize) {
                        *wi += v;
                    }
                }
            }
            DeltaPayload::QuantI8 { scale, codes } => {
                for (wi, &c) in w.iter_mut().zip(codes.iter()) {
                    *wi += scale * c as f32;
                }
            }
            DeltaPayload::QuantF16 { codes } => {
                for (wi, &c) in w.iter_mut().zip(codes.iter()) {
                    *wi += f16_bits_to_f32(c);
                }
            }
            DeltaPayload::QuantI4 { n, scale, packed } => {
                let n = (*n as usize).min(2 * packed.len());
                for (i, wi) in w.iter_mut().enumerate().take(n) {
                    *wi += scale * i4_code(packed, i) as f32;
                }
            }
        }
    }

    /// `w[i] -= v[i]` — the snapshot-store ring convention. For `Dense`
    /// this is exactly the legacy subtraction loop (bit-identical
    /// replays depend on it).
    pub fn sub_from(&self, w: &mut [f32]) {
        match self {
            DeltaPayload::Dense(v) => {
                for (wi, di) in w.iter_mut().zip(v.iter()) {
                    *wi -= di;
                }
            }
            DeltaPayload::TopK { idx, val, .. } => {
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    if let Some(wi) = w.get_mut(i as usize) {
                        *wi -= v;
                    }
                }
            }
            DeltaPayload::QuantI8 { scale, codes } => {
                for (wi, &c) in w.iter_mut().zip(codes.iter()) {
                    *wi -= scale * c as f32;
                }
            }
            DeltaPayload::QuantF16 { codes } => {
                for (wi, &c) in w.iter_mut().zip(codes.iter()) {
                    *wi -= f16_bits_to_f32(c);
                }
            }
            DeltaPayload::QuantI4 { n, scale, packed } => {
                let n = (*n as usize).min(2 * packed.len());
                for (i, wi) in w.iter_mut().enumerate().take(n) {
                    *wi -= scale * i4_code(packed, i) as f32;
                }
            }
        }
    }

    /// Decode into a freshly materialised dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0; self.dim()];
        self.apply_into(&mut w);
        w
    }

    /// Sum of two payloads as an exact dense payload (`dim` must match).
    /// Origin-side compaction across payload forms — lossless given the
    /// already-lossy inputs.
    pub fn merge(&self, other: &DeltaPayload) -> DeltaPayload {
        assert_eq!(self.dim(), other.dim(), "merging mismatched delta dims");
        let mut w = self.to_dense();
        other.apply_into(&mut w);
        DeltaPayload::dense(w)
    }

    /// Wire tag of the variant (first payload byte).
    pub fn tag(&self) -> u8 {
        match self {
            DeltaPayload::Dense(_) => 0,
            DeltaPayload::TopK { .. } => 1,
            DeltaPayload::QuantI8 { .. } => 2,
            DeltaPayload::QuantF16 { .. } => 3,
            DeltaPayload::QuantI4 { .. } => 4,
        }
    }

    /// Exact encoded size in bytes: `[u8 tag]` + variant body.
    pub fn wire_len(&self) -> usize {
        1 + match self {
            DeltaPayload::Dense(v) => 4 + 4 * v.len(),
            DeltaPayload::TopK { idx, .. } => 4 + 4 + 8 * idx.len(),
            DeltaPayload::QuantI8 { codes, .. } => 4 + 4 + codes.len(),
            DeltaPayload::QuantF16 { codes } => 4 + 2 * codes.len(),
            DeltaPayload::QuantI4 { packed, .. } => 4 + 4 + packed.len(),
        }
    }

    /// Append the wire form (little-endian throughout, like the rest of
    /// the codec).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.push(self.tag());
        match self {
            DeltaPayload::Dense(v) => {
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            DeltaPayload::TopK { dim, idx, val } => {
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for i in idx.iter() {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for v in val.iter() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            DeltaPayload::QuantI8 { scale, codes } => {
                out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
                out.extend_from_slice(&scale.to_le_bytes());
                for &c in codes.iter() {
                    out.push(c as u8);
                }
            }
            DeltaPayload::QuantF16 { codes } => {
                out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
                for c in codes.iter() {
                    out.extend_from_slice(&c.to_le_bytes());
                }
            }
            DeltaPayload::QuantI4 { n, scale, packed } => {
                out.extend_from_slice(&n.to_le_bytes());
                out.extend_from_slice(&scale.to_le_bytes());
                out.extend_from_slice(packed);
            }
        }
    }

    /// Decode one payload from the front of `buf`, returning it and the
    /// bytes consumed. `None` on truncation, an unknown tag, counts that
    /// claim more bytes than `buf` holds (so a hostile length can never
    /// force a huge allocation), or non-canonical top-k indices.
    pub fn decode_from(buf: &[u8]) -> Option<(DeltaPayload, usize)> {
        let (&tag, rest) = buf.split_first()?;
        let u32_at = |b: &[u8], off: usize| -> Option<u32> {
            Some(u32::from_le_bytes(b.get(off..off + 4)?.try_into().ok()?))
        };
        match tag {
            0 => {
                let n = u32_at(rest, 0)? as usize;
                let body = rest.get(4..4 + 4 * n)?;
                let v: Vec<f32> = body
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Some((DeltaPayload::dense(v), 1 + 4 + 4 * n))
            }
            1 => {
                let dim = u32_at(rest, 0)?;
                let k = u32_at(rest, 4)? as usize;
                let body = rest.get(8..8 + 8 * k)?;
                let idx: Vec<u32> = body[..4 * k]
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                // Canonical form: strictly ascending, in range — which
                // also bounds k by dim and makes apply_into safe.
                let canonical = idx.iter().all(|&i| i < dim)
                    && idx.windows(2).all(|w| w[0] < w[1]);
                if !canonical {
                    return None;
                }
                let val: Vec<f32> = body[4 * k..]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Some((
                    DeltaPayload::TopK { dim, idx: idx.into(), val: val.into() },
                    1 + 8 + 8 * k,
                ))
            }
            2 => {
                let n = u32_at(rest, 0)? as usize;
                let scale =
                    f32::from_le_bytes(rest.get(4..8)?.try_into().ok()?);
                let body = rest.get(8..8 + n)?;
                let codes: Vec<i8> = body.iter().map(|&b| b as i8).collect();
                Some((
                    DeltaPayload::QuantI8 { scale, codes: codes.into() },
                    1 + 8 + n,
                ))
            }
            3 => {
                let n = u32_at(rest, 0)? as usize;
                let body = rest.get(4..4 + 2 * n)?;
                let codes: Vec<u16> = body
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Some((DeltaPayload::QuantF16 { codes: codes.into() }, 1 + 4 + 2 * n))
            }
            4 => {
                let n = u32_at(rest, 0)?;
                let scale =
                    f32::from_le_bytes(rest.get(4..8)?.try_into().ok()?);
                let nb = (n as usize + 1) / 2;
                let body = rest.get(8..8 + nb)?;
                // Canonical: an odd n leaves the final high nibble zero.
                if n % 2 == 1 && body.last().is_some_and(|b| b >> 4 != 0) {
                    return None;
                }
                Some((
                    DeltaPayload::QuantI4 { n, scale, packed: body.to_vec().into() },
                    1 + 8 + nb,
                ))
            }
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// Origin-side encoder with error feedback
// ---------------------------------------------------------------------

/// Turns an origin's dense deltas into wire payloads, carrying the
/// dropped/rounded mass forward so lossy modes stay unbiased: each call
/// first folds the previous residual into the new delta, encodes, and
/// keeps `folded - decoded` as the next residual. `Dense` mode never
/// touches the residual (bit-identity with the legacy path).
#[derive(Debug, Clone)]
pub struct DeltaEncoder {
    cfg: CompressConfig,
    residual: Vec<f32>,
    /// Payload bytes this origin shipped (wire form, before framing).
    pub payload_bytes: u64,
    /// L1 mass carried in the residual across all encodes — how much
    /// correction error feedback re-injected.
    pub fed_back_mass: f64,
    /// Deltas encoded.
    pub encoded: u64,
}

impl DeltaEncoder {
    pub fn new(cfg: CompressConfig, dim: usize) -> DeltaEncoder {
        DeltaEncoder {
            cfg,
            residual: vec![0.0; dim],
            payload_bytes: 0,
            fed_back_mass: 0.0,
            encoded: 0,
        }
    }

    /// Residual still awaiting re-injection (tests and drain accounting).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// The config this encoder was built with (report labelling).
    pub fn config(&self) -> CompressConfig {
        self.cfg
    }

    /// Encode one dense delta, consuming the buffer.
    pub fn encode(&mut self, mut dense: Vec<f32>) -> DeltaPayload {
        self.encoded += 1;
        let payload = match self.cfg.mode {
            CompressMode::Dense => DeltaPayload::dense(dense),
            CompressMode::TopK => {
                self.fold_residual(&mut dense);
                let dim = dense.len();
                let k = self.cfg.top_k.max(1).min(dim.max(1)).min(dim);
                // Largest |v| first; ties broken by the lower index so
                // the selection is deterministic (and portable to the
                // Python mirror).
                let mut order: Vec<u32> = (0..dim as u32).collect();
                order.sort_unstable_by(|&a, &b| {
                    let (fa, fb) =
                        (dense[a as usize].abs(), dense[b as usize].abs());
                    fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
                });
                let mut idx = order[..k].to_vec();
                idx.sort_unstable();
                let val: Vec<f32> =
                    idx.iter().map(|&i| dense[i as usize]).collect();
                for &i in &idx {
                    dense[i as usize] = 0.0;
                }
                self.stash_residual(dense);
                DeltaPayload::TopK {
                    dim: dim as u32,
                    idx: idx.into(),
                    val: val.into(),
                }
            }
            CompressMode::QuantI8 => {
                self.fold_residual(&mut dense);
                let max = dense.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = max / 127.0;
                let codes: Vec<i8> = dense
                    .iter()
                    .map(|&v| {
                        if scale == 0.0 {
                            0
                        } else {
                            (v / scale).round().clamp(-127.0, 127.0) as i8
                        }
                    })
                    .collect();
                for (v, &c) in dense.iter_mut().zip(&codes) {
                    *v -= scale * c as f32;
                }
                self.stash_residual(dense);
                DeltaPayload::QuantI8 { scale, codes: codes.into() }
            }
            CompressMode::QuantF16 => {
                self.fold_residual(&mut dense);
                let codes: Vec<u16> =
                    dense.iter().map(|&v| f32_to_f16_bits(v)).collect();
                for (v, &c) in dense.iter_mut().zip(&codes) {
                    *v -= f16_bits_to_f32(c);
                }
                self.stash_residual(dense);
                DeltaPayload::QuantF16 { codes: codes.into() }
            }
            CompressMode::QuantI4 => {
                self.fold_residual(&mut dense);
                let max = dense.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = max / 7.0;
                let codes: Vec<i8> = dense
                    .iter()
                    .map(|&v| {
                        if scale == 0.0 {
                            0
                        } else {
                            (v / scale).round().clamp(-7.0, 7.0) as i8
                        }
                    })
                    .collect();
                for (v, &c) in dense.iter_mut().zip(&codes) {
                    *v -= scale * c as f32;
                }
                let mut packed = vec![0u8; codes.len().div_ceil(2)];
                for (i, &c) in codes.iter().enumerate() {
                    let nib = (c as u8) & 0x0f;
                    packed[i / 2] |= if i % 2 == 0 { nib } else { nib << 4 };
                }
                self.stash_residual(dense);
                DeltaPayload::QuantI4 {
                    n: codes.len() as u32,
                    scale,
                    packed: packed.into(),
                }
            }
        };
        self.payload_bytes += payload.wire_len() as u64;
        payload
    }

    fn fold_residual(&mut self, dense: &mut [f32]) {
        self.residual.resize(dense.len(), 0.0);
        for (v, r) in dense.iter_mut().zip(&self.residual) {
            *v += r;
        }
    }

    fn stash_residual(&mut self, rem: Vec<f32>) {
        self.fed_back_mass +=
            rem.iter().map(|&x| x.abs() as f64).sum::<f64>();
        self.residual = rem;
    }
}

// ---------------------------------------------------------------------
// Half-precision conversion (no `half` crate in-container)
// ---------------------------------------------------------------------

/// f32 → IEEE binary16 bits: round to nearest even, **saturating** to
/// ±65504 instead of overflowing to infinity (keeps error feedback
/// finite on outlier coordinates). NaN maps to a quiet f16 NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf and NaN: quantizer saturates infinities like overflow.
        return if mant != 0 { sign | 0x7e00 } else { sign | 0x7bff };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7bff; // overflow: saturate to max finite
    }
    if e <= 0 {
        // Subnormal (or underflow to zero): code = round(m24 / 2^shift).
        let shift = 14 - e;
        if shift > 24 {
            return sign;
        }
        let m24 = mant | 0x0080_0000;
        return sign | round_shift(m24, shift as u32) as u16;
    }
    // Normal: drop 13 mantissa bits with RNE; a rounding carry walks
    // into the exponent (correct), saturating if it reaches 0x1f.
    let out = ((e as u32) << 10) | round_shift(mant, 13);
    if out >= 0x7c00 {
        return sign | 0x7bff;
    }
    sign | out as u16
}

/// IEEE binary16 bits → f32 (exact; every f16 is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp != 0 {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    } else if mant == 0 {
        sign
    } else {
        // Subnormal: normalise into an f32 exponent.
        let mut e = 127 - 15 + 1;
        let mut m = mant;
        while m & 0x0400 == 0 {
            m <<= 1;
            e -= 1;
        }
        sign | ((e as u32) << 23) | ((m & 0x03ff) << 13)
    };
    f32::from_bits(bits)
}

/// `m >> shift` with round-to-nearest-even on the dropped bits.
fn round_shift(m: u32, shift: u32) -> u32 {
    let base = m >> shift;
    let dropped = m & ((1 << shift) - 1);
    let half = 1 << (shift - 1);
    if dropped > half || (dropped == half && base & 1 == 1) {
        base + 1
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(mode: CompressMode, top_k: usize) -> CompressConfig {
        CompressConfig { mode, top_k }
    }

    fn random_delta(dim: usize, rng: &mut Rng) -> Vec<f32> {
        (0..dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn dense_apply_matches_the_legacy_loops() {
        let p = DeltaPayload::dense(vec![1.0, -2.5, 0.5]);
        let mut w = vec![10.0, 10.0, 10.0];
        p.apply_into(&mut w);
        assert_eq!(w, vec![11.0, 7.5, 10.5]);
        p.sub_from(&mut w);
        assert_eq!(w, vec![10.0, 10.0, 10.0]);
        assert_eq!(p.dim(), 3);
        assert_eq!(p.dense_slice(), Some(&[1.0, -2.5, 0.5][..]));
    }

    #[test]
    fn topk_encoder_keeps_the_largest_coordinates() {
        let mut enc = DeltaEncoder::new(cfg(CompressMode::TopK, 2), 4);
        let p = enc.encode(vec![0.5, -2.5, 0.125, 3.0]);
        match &p {
            DeltaPayload::TopK { dim, idx, val } => {
                assert_eq!(*dim, 4);
                assert_eq!(&idx[..], &[1, 3]);
                assert_eq!(&val[..], &[-2.5, 3.0]);
            }
            other => panic!("expected TopK, got {other:?}"),
        }
        // Dropped mass waits in the residual and folds into the next
        // delta: index 0 carries 0.5 + 0.5 = 1.0 now, displacing 3.
        assert_eq!(enc.residual(), &[0.5, 0.0, 0.125, 0.0]);
        let p2 = enc.encode(vec![0.5, -2.0, 0.0, 0.25]);
        match &p2 {
            DeltaPayload::TopK { idx, val, .. } => {
                assert_eq!(&idx[..], &[0, 1]);
                assert_eq!(&val[..], &[1.0, -2.0]);
            }
            other => panic!("expected TopK, got {other:?}"),
        }
        assert!(enc.fed_back_mass > 0.0);
    }

    #[test]
    fn topk_ties_break_toward_the_lower_index() {
        let mut enc = DeltaEncoder::new(cfg(CompressMode::TopK, 2), 4);
        let p = enc.encode(vec![1.0, -1.0, 1.0, -1.0]);
        match p {
            DeltaPayload::TopK { idx, .. } => assert_eq!(&idx[..], &[0, 1]),
            other => panic!("expected TopK, got {other:?}"),
        }
    }

    #[test]
    fn quant_i8_scale_covers_the_max_coordinate() {
        let mut enc = DeltaEncoder::new(cfg(CompressMode::QuantI8, 0), 3);
        let p = enc.encode(vec![1.0, -0.25, 0.0]);
        match &p {
            DeltaPayload::QuantI8 { scale, codes } => {
                assert!((scale - 1.0 / 127.0).abs() < 1e-6);
                assert_eq!(&codes[..], &[127, -32, 0]);
            }
            other => panic!("expected QuantI8, got {other:?}"),
        }
        // The rounding error 0.25 - 32·scale waits in the residual.
        assert_eq!(enc.residual()[0], 0.0);
        assert!(enc.residual()[1] > 0.0019 && enc.residual()[1] < 0.0020);
        // An all-zero delta (fresh encoder, empty residual) still
        // encodes: scale 0, codes 0.
        let mut enc0 = DeltaEncoder::new(cfg(CompressMode::QuantI8, 0), 3);
        let z = enc0.encode(vec![0.0; 3]);
        match z {
            DeltaPayload::QuantI8 { scale, codes } => {
                assert_eq!(scale, 0.0);
                assert!(codes.iter().all(|&c| c == 0));
            }
            other => panic!("expected QuantI8, got {other:?}"),
        }
    }

    #[test]
    fn quant_i4_packs_two_codes_per_byte() {
        let mut enc = DeltaEncoder::new(cfg(CompressMode::QuantI4, 0), 4);
        let p = enc.encode(vec![0.7, -0.3, 0.0, 0.1]);
        match &p {
            DeltaPayload::QuantI4 { n, scale, packed } => {
                assert_eq!(*n, 4);
                assert!((scale - 0.1).abs() < 1e-6);
                // codes [7, -3, 0, 1]: low nibble = even index.
                assert_eq!(&packed[..], &[0xd7, 0x10]);
            }
            other => panic!("expected QuantI4, got {other:?}"),
        }
        // Odd length leaves the final high nibble clear.
        let mut enc3 = DeltaEncoder::new(cfg(CompressMode::QuantI4, 0), 3);
        let q = enc3.encode(vec![0.7, -0.3, 0.1]);
        match &q {
            DeltaPayload::QuantI4 { n, packed, .. } => {
                assert_eq!(*n, 3);
                assert_eq!(&packed[..], &[0xd7, 0x01]);
            }
            other => panic!("expected QuantI4, got {other:?}"),
        }
        let dec = q.to_dense();
        assert_eq!(dec.len(), 3);
        assert!((dec[0] - 0.7).abs() < 0.05 && (dec[1] + 0.3).abs() < 0.05);
    }

    #[test]
    fn f16_conversion_round_trips_known_values() {
        // (f32, f16 bits) — standard binary16 encodings.
        let cases: &[(f32, u16)] = &[
            (0.0, 0x0000),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.5, 0xc100),
            (0.1, 0x2e66),   // RNE on the dropped mantissa bits
            (65504.0, 0x7bff),
            (1.0e9, 0x7bff), // saturates instead of inf
            (f32::INFINITY, 0x7bff),
            (-1.0e9, 0xfbff),
            (5.960_464_5e-8, 0x0001), // smallest subnormal, 2^-24
            (2.980_232_2e-8, 0x0000), // 2^-25 ties to even -> 0
        ];
        for &(x, bits) in cases {
            assert_eq!(
                f32_to_f16_bits(x),
                bits,
                "f32_to_f16({x}) != {bits:#06x}"
            );
        }
        // Exact decode: every f16 value is f32-representable.
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0xc100), -2.5);
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8);
        assert!(f16_bits_to_f32(0x7c00).is_infinite());
        assert!(f16_bits_to_f32(0x7e00).is_nan());
        // Round-trip through the saturating encoder is lossless for
        // values already representable in f16.
        for h in [0x0000u16, 0x0001, 0x03ff, 0x0400, 0x3c00, 0x7bff, 0x8001] {
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h, "h={h:#06x}");
        }
    }

    #[test]
    fn every_variant_round_trips_through_the_wire_form() {
        let payloads = vec![
            DeltaPayload::dense(vec![1.0, -2.5]),
            DeltaPayload::dense(Vec::new()),
            DeltaPayload::TopK {
                dim: 8,
                idx: vec![1, 5, 7].into(),
                val: vec![0.5, -0.25, 4.0].into(),
            },
            DeltaPayload::QuantI8 {
                scale: 0.03125,
                codes: vec![-127, 0, 64, 127].into(),
            },
            DeltaPayload::QuantF16 { codes: vec![0x3c00, 0xc100, 0x0001].into() },
            DeltaPayload::QuantI4 {
                n: 5,
                scale: 0.25,
                packed: vec![0x21, 0xf7, 0x05].into(),
            },
        ];
        for p in payloads {
            let mut buf = Vec::new();
            p.encode_into(&mut buf);
            assert_eq!(buf.len(), p.wire_len(), "{p:?}: wire_len inexact");
            let (q, used) = DeltaPayload::decode_from(&buf).expect("decode");
            assert_eq!(used, buf.len());
            assert_eq!(q, p);
            // Trailing bytes are left for the caller.
            buf.push(0xAB);
            let (_, used2) = DeltaPayload::decode_from(&buf).unwrap();
            assert_eq!(used2, buf.len() - 1);
        }
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        let mut buf = Vec::new();
        DeltaPayload::TopK {
            dim: 8,
            idx: vec![1, 5].into(),
            val: vec![0.5, -0.25].into(),
        }
        .encode_into(&mut buf);
        // Truncation at every prefix.
        for cut in 0..buf.len() {
            assert!(
                DeltaPayload::decode_from(&buf[..cut]).is_none(),
                "decoded a {cut}-byte prefix"
            );
        }
        // Unknown tag.
        assert!(DeltaPayload::decode_from(&[9, 0, 0, 0, 0]).is_none());
        // A count claiming more bytes than the buffer holds must be
        // rejected before any allocation happens.
        let huge = [0u8, 0xff, 0xff, 0xff, 0xff];
        assert!(DeltaPayload::decode_from(&huge).is_none());
        // Non-canonical top-k: out-of-range index.
        let mut bad = Vec::new();
        DeltaPayload::TopK { dim: 4, idx: vec![9].into(), val: vec![1.0].into() }
            .encode_into(&mut bad);
        assert!(DeltaPayload::decode_from(&bad).is_none());
        // Non-canonical top-k: unsorted (duplicate) indices.
        let mut dup = Vec::new();
        DeltaPayload::TopK {
            dim: 4,
            idx: vec![2, 2].into(),
            val: vec![1.0, 1.0].into(),
        }
        .encode_into(&mut dup);
        assert!(DeltaPayload::decode_from(&dup).is_none());
        // Non-canonical int4: odd n with a dirty final high nibble.
        let mut nib = Vec::new();
        DeltaPayload::QuantI4 { n: 1, scale: 1.0, packed: vec![0x10].into() }
            .encode_into(&mut nib);
        assert!(DeltaPayload::decode_from(&nib).is_none());
    }

    #[test]
    fn merge_is_the_dense_sum() {
        let a = DeltaPayload::TopK {
            dim: 4,
            idx: vec![0, 3].into(),
            val: vec![1.0, 2.0].into(),
        };
        let b = DeltaPayload::dense(vec![0.5, 0.5, 0.5, 0.5]);
        let m = a.merge(&b);
        assert_eq!(m.dense_slice().unwrap(), &[1.5, 0.5, 0.5, 2.5]);
    }

    #[test]
    fn compress_config_parses_and_round_trips_the_wire_tag() {
        let topk = CompressConfig::parse("topk", 16, "i8").unwrap();
        assert_eq!(topk.mode, CompressMode::TopK);
        assert_eq!(topk.top_k, 16);
        assert!(!topk.is_dense());
        let qi8 = CompressConfig::parse("quant", 0, "i8").unwrap();
        assert_eq!(qi8.mode, CompressMode::QuantI8);
        let qf16 = CompressConfig::parse("quant", 0, "f16").unwrap();
        assert_eq!(qf16.mode, CompressMode::QuantF16);
        let qi4 = CompressConfig::parse("quant", 0, "i4").unwrap();
        assert_eq!(qi4.mode, CompressMode::QuantI4);
        assert_eq!(qi4.mode_str(), "qi4");
        assert!(CompressConfig::parse("zstd", 0, "i8").is_none());
        assert!(CompressConfig::parse("quant", 0, "i2").is_none());
        for c in [CompressConfig::default(), topk, qi8, qf16, qi4] {
            let back = CompressConfig::from_tag(c.mode_tag(), c.top_k).unwrap();
            assert_eq!(back, c);
        }
        assert!(CompressConfig::from_tag(7, 1).is_none());
        assert_eq!(CompressConfig::default().mode_str(), "dense");
        assert_eq!(qf16.mode_str(), "qf16");
    }

    /// The error-feedback contract (ISSUE satellite): per origin, the
    /// sum of everything actually applied equals the sum of the true
    /// dense deltas, up to the residual still held back — within the
    /// quantization bound for the lossy modes, *exactly* for top-k with
    /// `k = dim`.
    #[test]
    fn error_feedback_conserves_the_delta_sum() {
        let dim = 32;
        let rounds = 200;
        for (mode, top_k) in [
            (CompressMode::TopK, 4),
            (CompressMode::TopK, dim), // k = dim: exact
            (CompressMode::QuantI8, 0),
            (CompressMode::QuantF16, 0),
            (CompressMode::QuantI4, 0),
        ] {
            let mut rng = Rng::new(0x5EED_00FE);
            let mut enc = DeltaEncoder::new(cfg(mode, top_k), dim);
            let mut dense_sum = vec![0.0f64; dim];
            let mut applied_sum = vec![0.0f64; dim];
            for _ in 0..rounds {
                let d = random_delta(dim, &mut rng);
                for (s, &x) in dense_sum.iter_mut().zip(&d) {
                    *s += x as f64;
                }
                let p = enc.encode(d);
                for (s, x) in applied_sum.iter_mut().zip(p.to_dense()) {
                    *s += x as f64;
                }
            }
            let exact = mode == CompressMode::TopK && top_k == dim;
            for i in 0..dim {
                let gap =
                    dense_sum[i] - applied_sum[i] - enc.residual()[i] as f64;
                if exact {
                    assert_eq!(
                        dense_sum[i], applied_sum[i],
                        "k=dim coord {i} diverged"
                    );
                    assert_eq!(enc.residual()[i], 0.0);
                } else {
                    // Slack: f32 rounding of the fold, ~eps per round.
                    assert!(
                        gap.abs() < 1e-3,
                        "{mode:?} coord {i}: dense {} vs applied {} + \
                         residual {} (gap {gap})",
                        dense_sum[i],
                        applied_sum[i],
                        enc.residual()[i],
                    );
                }
            }
            if exact {
                assert_eq!(enc.fed_back_mass, 0.0);
            } else {
                assert!(enc.fed_back_mass > 0.0);
            }
            assert_eq!(enc.encoded, rounds as u64);
            assert!(enc.payload_bytes > 0);
        }
    }

    /// Compression must actually compress: the bytes/delta ratios the
    /// `ext_compress` ablation and the bench gate rely on.
    #[test]
    fn lossy_payloads_are_at_least_4x_smaller_at_k_dim_over_16() {
        let dim = 1024;
        let mut rng = Rng::new(42);
        let d = random_delta(dim, &mut rng);
        let dense = DeltaPayload::dense(d.clone()).wire_len();
        let mut topk = DeltaEncoder::new(cfg(CompressMode::TopK, dim / 16), dim);
        let mut qi8 = DeltaEncoder::new(cfg(CompressMode::QuantI8, 0), dim);
        let mut qf16 = DeltaEncoder::new(cfg(CompressMode::QuantF16, 0), dim);
        let mut qi4 = DeltaEncoder::new(cfg(CompressMode::QuantI4, 0), dim);
        // dense = 4101B at dim 1024; topk/16 = 521B, qi4 = 521B (both
        // ~7.9x), qi8 = 1033B (3.97x — the scale+len header keeps int8
        // under 4x forever), qf16 = 2053B (~2x).
        assert!(dense >= 4 * topk.encode(d.clone()).wire_len());
        assert!(dense >= 4 * qi4.encode(d.clone()).wire_len());
        assert!(dense >= 3 * qi8.encode(d.clone()).wire_len());
        assert!(2 * dense >= 3 * qf16.encode(d).wire_len());
    }
}
