//! Message transport for the deployment plane (`actor node` / `actor join`).
//!
//! The simulation engines move [`PeerMsg`] values over in-process
//! `mpsc` channels; a *deployed* cluster moves the same protocol over
//! TCP between OS processes. This module makes the carrier pluggable:
//!
//! * [`Frame`] — the on-the-wire protocol: every `PeerMsg` plus the
//!   frames only a real deployment needs (step announcements, because
//!   there is no shared coordinator to read step tables from, and the
//!   `Join`/`Welcome`/`Peers` bootstrap handshake).
//! * the **codec** — a hand-rolled length-prefixed little-endian binary
//!   format ([`encode`] / [`decode`] / [`read_frame`] / [`write_frame`]),
//!   zero-dependency in the same spirit as [`crate::util::json`]. The
//!   format is pinned by known-answer vectors and a cross-language
//!   digest mirrored bit-for-bit by `tools/verify_wire_port.py`.
//! * [`Transport`] — the trait the node runtime is generic over, with
//!   two implementations: [`ChannelTransport`] (in-process, used by the
//!   equivalence tests so a "cluster" can run inside one test binary)
//!   and [`TcpTransport`] (real sockets: an accept loop feeding a shared
//!   inbox, one reader thread per accepted connection, one writer thread
//!   per peer with reconnect + exponential backoff).
//!
//! Delivery contract: **at-least-once, unordered across peers, FIFO per
//! peer while a connection lives**. A writer that loses its connection
//! reconnects and resends the in-flight frame, so a frame can arrive
//! twice. The protocol absorbs that: rumors dedup by `(origin, seq)`,
//! `Step` carries a monotone step (receivers keep the max), and
//! `Done`/`Leave`/`Repair` are idempotent by construction.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::engine::gossip::Rumor;
use crate::engine::p2p::PeerMsg;

/// Hard ceiling on one frame's body (tag + payload), bytes. A frame
/// declaring more than this is rejected before any allocation — a
/// corrupt or hostile length prefix must not OOM the node.
pub const MAX_FRAME: usize = 64 << 20;

/// How long a reader blocks per `read` before re-checking the stop
/// flag. Bounds shutdown latency without busy-waiting.
const READ_POLL: Duration = Duration::from_millis(200);

// ---------------------------------------------------------------------------
// Frame: the deployment-plane protocol
// ---------------------------------------------------------------------------

/// Full workload description a seed node hands each joiner, so a
/// cluster is configured in exactly one place (the seed's flags) and
/// every process still computes bit-identical seeds/schedules.
#[derive(Debug, Clone, PartialEq)]
pub struct Welcome {
    /// The id assigned to the joiner (seed is always 0).
    pub id: u32,
    /// Cluster size; the seed accepts exactly `n - 1` joiners.
    pub n: u32,
    /// Base RNG seed (forked per worker exactly like the sim engines).
    pub seed: u64,
    /// Steps per worker.
    pub steps: u64,
    /// Model dimension.
    pub dim: u32,
    /// Learning rate.
    pub lr: f32,
    /// Barrier method, as its canonical `Display` string (`pssp:3:2`);
    /// strings survive protocol evolution better than a numeric enum.
    pub method: String,
    /// Gossip fanout.
    pub fanout: u32,
    /// Gossip flush cadence (steps per origination).
    pub flush: u64,
    /// Gossip shortcut TTL.
    pub ttl: u32,
}

/// One wire message. `Peer` embeds the engines' protocol unchanged;
/// the rest exist only because deployed processes share no memory.
#[derive(Debug, Clone)]
pub enum Frame {
    /// An engine message (deltas, gossip, drain/leave/repair control).
    Peer(PeerMsg),
    /// Barrier plane: `from` has completed `step` steps. `beat` is a
    /// send counter so receivers can tell fresh announcements from
    /// reconnect resends (max-merge on both fields).
    Step { from: u32, step: u64, beat: u64 },
    /// Bootstrap: a joiner announces the address it listens on.
    Join { addr: String },
    /// Bootstrap: the seed's reply — id assignment + workload.
    Welcome(Welcome),
    /// Bootstrap: the full roster `(id, listen addr)`, seed included.
    Peers { peers: Vec<(u32, String)> },
}

// ---------------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------------

/// Why a byte sequence is not a frame.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// Fewer bytes than the layout requires.
    Truncated,
    /// First body byte names no known frame type.
    UnknownTag(u8),
    /// Bytes left over after a complete decode (count).
    TrailingBytes(usize),
    /// Declared body length above [`MAX_FRAME`].
    Oversize(u64),
    /// A string field was not UTF-8.
    BadUtf8,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::UnknownTag(t) => write!(f, "unknown frame tag {t}"),
            WireError::TrailingBytes(n) => write!(f, "{n} trailing bytes after frame"),
            WireError::Oversize(n) => write!(f, "frame body of {n} bytes exceeds MAX_FRAME"),
            WireError::BadUtf8 => write!(f, "string field is not UTF-8"),
        }
    }
}

impl std::error::Error for WireError {}

const TAG_DELTA: u8 = 1;
const TAG_GOSSIP: u8 = 2;
const TAG_DONE: u8 = 3;
const TAG_LEAVE: u8 = 4;
const TAG_REPAIR: u8 = 5;
const TAG_STEP: u8 = 6;
const TAG_JOIN: u8 = 7;
const TAG_WELCOME: u8 = 8;
const TAG_PEERS: u8 = 9;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        put_f32(out, x);
    }
}

fn put_rumor(out: &mut Vec<u8>, r: &Rumor) {
    put_u32(out, r.origin);
    put_u32(out, r.seq);
    put_u32(out, r.ttl);
    put_f32s(out, &r.delta);
}

fn put_rumors(out: &mut Vec<u8>, rs: &[Rumor]) {
    put_u32(out, rs.len() as u32);
    for r in rs {
        put_rumor(out, r);
    }
}

/// Encode a frame to its complete wire bytes:
/// `[u32 LE body length][u8 tag][payload]`, everything little-endian.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut body = Vec::with_capacity(wire_len(frame));
    match frame {
        Frame::Peer(PeerMsg::Delta { delta }) => {
            body.push(TAG_DELTA);
            put_f32s(&mut body, delta);
        }
        Frame::Peer(PeerMsg::Gossip { rumors }) => {
            body.push(TAG_GOSSIP);
            put_rumors(&mut body, rumors);
        }
        Frame::Peer(PeerMsg::Done { from, rumors }) => {
            body.push(TAG_DONE);
            put_u32(&mut body, *from);
            put_u32(&mut body, *rumors);
        }
        Frame::Peer(PeerMsg::Leave { from, rumors }) => {
            body.push(TAG_LEAVE);
            put_u32(&mut body, *from);
            put_u32(&mut body, *rumors);
        }
        Frame::Peer(PeerMsg::Repair { origin, rumors, store }) => {
            body.push(TAG_REPAIR);
            put_u32(&mut body, *origin);
            put_u32(&mut body, *rumors);
            put_rumors(&mut body, store);
        }
        Frame::Step { from, step, beat } => {
            body.push(TAG_STEP);
            put_u32(&mut body, *from);
            put_u64(&mut body, *step);
            put_u64(&mut body, *beat);
        }
        Frame::Join { addr } => {
            body.push(TAG_JOIN);
            put_str(&mut body, addr);
        }
        Frame::Welcome(w) => {
            body.push(TAG_WELCOME);
            put_u32(&mut body, w.id);
            put_u32(&mut body, w.n);
            put_u64(&mut body, w.seed);
            put_u64(&mut body, w.steps);
            put_u32(&mut body, w.dim);
            put_f32(&mut body, w.lr);
            put_str(&mut body, &w.method);
            put_u32(&mut body, w.fanout);
            put_u64(&mut body, w.flush);
            put_u32(&mut body, w.ttl);
        }
        Frame::Peers { peers } => {
            body.push(TAG_PEERS);
            put_u32(&mut body, peers.len() as u32);
            for (id, addr) in peers {
                put_u32(&mut body, *id);
                put_str(&mut body, addr);
            }
        }
    }
    debug_assert!(body.len() <= MAX_FRAME, "frame body exceeds MAX_FRAME");
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    debug_assert_eq!(out.len(), wire_len(frame));
    out
}

/// Exact encoded size of a frame (length prefix included), computed
/// without encoding — writers use it for bandwidth accounting.
pub fn wire_len(frame: &Frame) -> usize {
    fn rumors_len(rs: &[Rumor]) -> usize {
        4 + rs.iter().map(|r| 16 + 4 * r.delta.len()).sum::<usize>()
    }
    let body = match frame {
        Frame::Peer(PeerMsg::Delta { delta }) => 1 + 4 + 4 * delta.len(),
        Frame::Peer(PeerMsg::Gossip { rumors }) => 1 + rumors_len(rumors),
        Frame::Peer(PeerMsg::Done { .. }) | Frame::Peer(PeerMsg::Leave { .. }) => 1 + 8,
        Frame::Peer(PeerMsg::Repair { store, .. }) => 1 + 8 + rumors_len(store),
        Frame::Step { .. } => 1 + 4 + 8 + 8,
        Frame::Join { addr } => 1 + 4 + addr.len(),
        Frame::Welcome(w) => 1 + 4 + 4 + 8 + 8 + 4 + 4 + (4 + w.method.len()) + 4 + 8 + 4,
        Frame::Peers { peers } => {
            1 + 4 + peers.iter().map(|(_, a)| 8 + a.len()).sum::<usize>()
        }
    };
    4 + body
}

/// Byte-at-a-time reader over a decoded body.
struct Rd<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.off < n {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        // A count that can't fit in the remaining bytes is a truncation,
        // caught here before we reserve anything on its behalf.
        if self.buf.len() - self.off < 4 * n {
            return Err(WireError::Truncated);
        }
        (0..n).map(|_| self.f32()).collect()
    }

    fn string(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    fn rumor(&mut self) -> Result<Rumor, WireError> {
        let origin = self.u32()?;
        let seq = self.u32()?;
        let ttl = self.u32()?;
        let delta: Arc<[f32]> = self.f32s()?.into();
        Ok(Rumor { origin, seq, ttl, delta })
    }

    fn rumors(&mut self) -> Result<Vec<Rumor>, WireError> {
        let n = self.u32()? as usize;
        // Each rumor is at least 16 bytes; reject impossible counts.
        if (self.buf.len() - self.off) / 16 < n {
            return Err(WireError::Truncated);
        }
        (0..n).map(|_| self.rumor()).collect()
    }

    fn finish(self, frame: Frame) -> Result<Frame, WireError> {
        if self.off != self.buf.len() {
            return Err(WireError::TrailingBytes(self.buf.len() - self.off));
        }
        Ok(frame)
    }
}

/// Decode a frame *body* (the bytes after the length prefix).
pub fn decode_body(body: &[u8]) -> Result<Frame, WireError> {
    let (&tag, rest) = body.split_first().ok_or(WireError::Truncated)?;
    let mut rd = Rd { buf: rest, off: 0 };
    let frame = match tag {
        TAG_DELTA => Frame::Peer(PeerMsg::Delta { delta: rd.f32s()? }),
        TAG_GOSSIP => Frame::Peer(PeerMsg::Gossip { rumors: rd.rumors()? }),
        TAG_DONE => Frame::Peer(PeerMsg::Done { from: rd.u32()?, rumors: rd.u32()? }),
        TAG_LEAVE => Frame::Peer(PeerMsg::Leave { from: rd.u32()?, rumors: rd.u32()? }),
        TAG_REPAIR => Frame::Peer(PeerMsg::Repair {
            origin: rd.u32()?,
            rumors: rd.u32()?,
            store: rd.rumors()?,
        }),
        TAG_STEP => Frame::Step { from: rd.u32()?, step: rd.u64()?, beat: rd.u64()? },
        TAG_JOIN => Frame::Join { addr: rd.string()? },
        TAG_WELCOME => Frame::Welcome(Welcome {
            id: rd.u32()?,
            n: rd.u32()?,
            seed: rd.u64()?,
            steps: rd.u64()?,
            dim: rd.u32()?,
            lr: rd.f32()?,
            method: rd.string()?,
            fanout: rd.u32()?,
            flush: rd.u64()?,
            ttl: rd.u32()?,
        }),
        TAG_PEERS => {
            let n = rd.u32()? as usize;
            if (rd.buf.len() - rd.off) / 8 < n {
                return Err(WireError::Truncated);
            }
            let mut peers = Vec::with_capacity(n);
            for _ in 0..n {
                let id = rd.u32()?;
                let addr = rd.string()?;
                peers.push((id, addr));
            }
            Frame::Peers { peers }
        }
        other => return Err(WireError::UnknownTag(other)),
    };
    rd.finish(frame)
}

/// Decode complete wire bytes (length prefix included) into a frame.
pub fn decode(bytes: &[u8]) -> Result<Frame, WireError> {
    if bytes.len() < 4 {
        return Err(WireError::Truncated);
    }
    let len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(WireError::Oversize(len as u64));
    }
    match (bytes.len() - 4).cmp(&len) {
        std::cmp::Ordering::Less => Err(WireError::Truncated),
        std::cmp::Ordering::Greater => Err(WireError::TrailingBytes(bytes.len() - 4 - len)),
        std::cmp::Ordering::Equal => decode_body(&bytes[4..]),
    }
}

fn wire_to_io(e: WireError) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, e)
}

/// Write one frame to a stream (blocking).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> io::Result<()> {
    w.write_all(&encode(frame))
}

/// Read one frame from a stream (blocking). Errors on EOF mid-frame,
/// an oversize length prefix, or a body that fails to decode.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Frame> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME {
        return Err(wire_to_io(WireError::Oversize(len as u64)));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_body(&body).map_err(wire_to_io)
}

// ---------------------------------------------------------------------------
// Transport trait + in-process implementation
// ---------------------------------------------------------------------------

/// The carrier the node runtime is generic over. Implementations own
/// their receive queue; `send` never blocks on the network (TCP queues
/// to a writer thread) so a slow peer cannot stall the compute loop.
pub trait Transport {
    /// This node's id.
    fn me(&self) -> usize;
    /// Cluster size.
    fn n(&self) -> usize;
    /// Queue a frame to `to` (self-send allowed: loops back to the
    /// inbox). `false` means the peer is gone for good — its queue no
    /// longer exists; the frame was dropped.
    fn send(&self, to: usize, frame: Frame) -> bool;
    /// Next inbound frame, if one is already queued.
    fn try_recv(&mut self) -> Option<Frame>;
    /// Next inbound frame, waiting up to `timeout`.
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Frame>;
}

/// In-process transport over `mpsc` channels — the same carrier the sim
/// engines use, behind the deployment-plane interface. The equivalence
/// tests run a "cluster" of these in one process and diff its results
/// against [`TcpTransport`].
pub struct ChannelTransport {
    me: usize,
    peers: Vec<Sender<Frame>>,
    inbox: Receiver<Frame>,
}

impl ChannelTransport {
    /// Build a fully connected in-process cluster of `n` transports.
    pub fn cluster(n: usize) -> Vec<ChannelTransport> {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| mpsc::channel()).unzip();
        rxs.into_iter()
            .enumerate()
            .map(|(me, inbox)| ChannelTransport { me, peers: txs.clone(), inbox })
            .collect()
    }
}

impl Transport for ChannelTransport {
    fn me(&self) -> usize {
        self.me
    }

    fn n(&self) -> usize {
        self.peers.len()
    }

    fn send(&self, to: usize, frame: Frame) -> bool {
        self.peers[to].send(frame).is_ok()
    }

    fn try_recv(&mut self) -> Option<Frame> {
        self.inbox.try_recv().ok()
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Option<Frame> {
        self.inbox.recv_timeout(timeout).ok()
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

/// Knobs for the deployed transport (`[transport]` config section and
/// `actor node` / `actor join` flags).
#[derive(Debug, Clone)]
pub struct TransportConfig {
    /// Address to listen on. Port 0 lets the OS pick (joiners' default).
    pub listen: String,
    /// Monitor HTTP endpoint address; `None` disables the monitor.
    pub monitor: Option<String>,
    /// Seconds to keep the process (and monitor) alive after the run —
    /// CI scrapes final counters during this window.
    pub linger_secs: f64,
    /// First reconnect backoff.
    pub reconnect_min: Duration,
    /// Backoff ceiling (doubles from min up to this).
    pub reconnect_max: Duration,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            listen: "127.0.0.1:0".to_string(),
            monitor: None,
            linger_secs: 0.0,
            reconnect_min: Duration::from_millis(10),
            reconnect_max: Duration::from_millis(500),
        }
    }
}

/// A writer-thread command: a pre-encoded frame, or the stop sentinel.
/// The sentinel rides the same FIFO queue, so everything queued before
/// drop is flushed (or dropped loudly) before the writer exits.
enum WCmd {
    Frame(Vec<u8>),
    Stop,
}

/// Real-socket transport: `bind` (or adopt a listener the bootstrap
/// handshake already used), then `connect_peers` with the roster.
///
/// Threads: one accept loop (spawns a reader per accepted connection;
/// readers decode into a shared inbox), one writer per peer (owns the
/// outbound connection, reconnects with exponential backoff and resends
/// the in-flight frame — at-least-once, which the protocol absorbs).
pub struct TcpTransport {
    me: usize,
    n: usize,
    local_addr: std::net::SocketAddr,
    inbox_tx: Sender<Frame>,
    inbox: Receiver<Frame>,
    writers: Vec<Option<Sender<WCmd>>>,
    writer_handles: Vec<JoinHandle<()>>,
    accept_handle: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    bytes_out: Arc<AtomicU64>,
    bytes_in: Arc<AtomicU64>,
    reconnect_min: Duration,
    reconnect_max: Duration,
}

/// `read_exact` that a 200ms read timeout cannot desync: timeouts
/// resume at the current offset unless the stop flag is up. Returns
/// `Ok(false)` on clean EOF before the first byte, or on stop.
fn read_exact_interruptible(
    s: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> io::Result<bool> {
    let mut off = 0;
    while off < buf.len() {
        match s.read(&mut buf[off..]) {
            Ok(0) => {
                if off == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "EOF mid-frame"));
            }
            Ok(k) => off += k,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(false);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// One reader: decode frames off an accepted connection into the inbox
/// until EOF, a decode error, or stop.
fn reader_loop(
    mut conn: TcpStream,
    inbox: Sender<Frame>,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<AtomicU64>,
) {
    let _ = conn.set_read_timeout(Some(READ_POLL));
    loop {
        let mut len4 = [0u8; 4];
        match read_exact_interruptible(&mut conn, &mut len4, &stop) {
            Ok(true) => {}
            Ok(false) => return,
            Err(e) => {
                crate::log_warn!("transport: reader dropped connection: {e}");
                return;
            }
        }
        let len = u32::from_le_bytes(len4) as usize;
        if len > MAX_FRAME {
            crate::log_warn!("transport: reader rejecting {len}-byte frame (> MAX_FRAME)");
            return;
        }
        let mut body = vec![0u8; len];
        match read_exact_interruptible(&mut conn, &mut body, &stop) {
            Ok(true) => {}
            // EOF or stop mid-frame: the sender's writer will resend on
            // its next connection if the cluster is still running.
            Ok(false) => return,
            Err(e) => {
                crate::log_warn!("transport: reader dropped connection: {e}");
                return;
            }
        }
        match decode_body(&body) {
            Ok(frame) => {
                bytes_in.fetch_add(4 + len as u64, Ordering::Relaxed);
                if inbox.send(frame).is_err() {
                    return; // transport dropped; nobody is listening
                }
            }
            Err(e) => {
                crate::log_warn!("transport: undecodable frame ({e}); dropping connection");
                return;
            }
        }
    }
}

/// One writer: own the outbound connection to `addr`, (re)connect with
/// exponential backoff, resend the frame that was in flight when a
/// connection died. After stop, each frame gets a bounded number of
/// connect attempts before being dropped loudly, so shutdown cannot
/// hang on a peer that already exited.
fn writer_loop(
    addr: String,
    rx: Receiver<WCmd>,
    stop: Arc<AtomicBool>,
    bytes_out: Arc<AtomicU64>,
    min_backoff: Duration,
    max_backoff: Duration,
) {
    let mut conn: Option<TcpStream> = None;
    let mut backoff = min_backoff;
    loop {
        let bytes = match rx.recv() {
            Ok(WCmd::Frame(b)) => b,
            Ok(WCmd::Stop) | Err(_) => return,
        };
        let mut attempts_while_stopped = 0u32;
        loop {
            let Some(c) = conn.as_mut() else {
                match TcpStream::connect(&addr) {
                    Ok(c) => {
                        let _ = c.set_nodelay(true);
                        conn = Some(c);
                        backoff = min_backoff;
                    }
                    Err(_) => {
                        if stop.load(Ordering::Relaxed) {
                            attempts_while_stopped += 1;
                            if attempts_while_stopped >= 3 {
                                crate::log_warn!(
                                    "transport: dropping {}-byte frame for {addr} (unreachable at shutdown)",
                                    bytes.len()
                                );
                                break;
                            }
                        }
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(max_backoff);
                    }
                }
                continue;
            };
            match c.write_all(&bytes) {
                Ok(()) => {
                    bytes_out.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    break;
                }
                Err(e) => {
                    crate::log_warn!("transport: write to {addr} failed ({e}); reconnecting");
                    conn = None; // resend this frame on the next connection
                }
            }
        }
    }
}

impl TcpTransport {
    /// Bind a fresh listener and start the accept loop. Peers are not
    /// connected yet — call [`connect_peers`](Self::connect_peers) once
    /// the roster is known (after the bootstrap handshake).
    pub fn bind<A: ToSocketAddrs>(me: usize, n: usize, listen: A) -> io::Result<TcpTransport> {
        Self::with_listener(me, n, TcpListener::bind(listen)?)
    }

    /// Adopt a listener that already exists — the seed node reuses the
    /// socket the bootstrap handshake accepted joiners on, so there is
    /// no rebind race between handshake and run.
    pub fn with_listener(me: usize, n: usize, listener: TcpListener) -> io::Result<TcpTransport> {
        let local_addr = listener.local_addr()?;
        let (inbox_tx, inbox) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let bytes_in = Arc::new(AtomicU64::new(0));
        let accept_handle = {
            let inbox_tx = inbox_tx.clone();
            let stop = Arc::clone(&stop);
            let bytes_in = Arc::clone(&bytes_in);
            std::thread::spawn(move || {
                let mut readers: Vec<JoinHandle<()>> = Vec::new();
                for conn in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match conn {
                        Ok(c) => {
                            let inbox_tx = inbox_tx.clone();
                            let stop = Arc::clone(&stop);
                            let bytes_in = Arc::clone(&bytes_in);
                            readers.push(std::thread::spawn(move || {
                                reader_loop(c, inbox_tx, stop, bytes_in)
                            }));
                        }
                        Err(e) => {
                            crate::log_warn!("transport: accept failed: {e}");
                        }
                    }
                }
                for r in readers {
                    let _ = r.join();
                }
            })
        };
        Ok(TcpTransport {
            me,
            n,
            local_addr,
            inbox_tx,
            inbox,
            writers: (0..n).map(|_| None).collect(),
            writer_handles: Vec::new(),
            accept_handle: Some(accept_handle),
            stop,
            bytes_out: Arc::new(AtomicU64::new(0)),
            bytes_in,
            reconnect_min: TransportConfig::default().reconnect_min,
            reconnect_max: TransportConfig::default().reconnect_max,
        })
    }

    /// Override the reconnect backoff window (before `connect_peers`).
    pub fn set_backoff(&mut self, min: Duration, max: Duration) {
        self.reconnect_min = min;
        self.reconnect_max = max;
    }

    /// The address the accept loop is really listening on (resolves
    /// port 0 binds).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Start one writer thread per roster entry. Entries for `me` are
    /// ignored (self-sends loop back in-process). Connections are
    /// opened lazily by the writers, with backoff — a peer that has not
    /// bound yet just costs a few retries.
    pub fn connect_peers(&mut self, roster: &[(usize, String)]) {
        for (peer, addr) in roster {
            let peer = *peer;
            if peer == self.me {
                continue;
            }
            assert!(peer < self.n, "roster id {peer} out of range");
            assert!(self.writers[peer].is_none(), "duplicate roster id {peer}");
            let (tx, rx) = mpsc::channel();
            let addr = addr.clone();
            let stop = Arc::clone(&self.stop);
            let bytes_out = Arc::clone(&self.bytes_out);
            let (min_b, max_b) = (self.reconnect_min, self.reconnect_max);
            self.writer_handles.push(std::thread::spawn(move || {
                writer_loop(addr, rx, stop, bytes_out, min_b, max_b)
            }));
            self.writers[peer] = Some(tx);
        }
    }

    /// Total payload bytes successfully written to peers.
    pub fn bytes_out(&self) -> u64 {
        self.bytes_out.load(Ordering::Relaxed)
    }

    /// Total payload bytes decoded off accepted connections.
    pub fn bytes_in(&self) -> u64 {
        self.bytes_in.load(Ordering::Relaxed)
    }
}

impl Transport for TcpTransport {
    fn me(&self) -> usize {
        self.me
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, to: usize, frame: Frame) -> bool {
        if to == self.me {
            return self.inbox_tx.send(frame).is_ok();
        }
        match &self.writers[to] {
            Some(tx) => tx.send(WCmd::Frame(encode(&frame))).is_ok(),
            None => false,
        }
    }

    fn try_recv(&mut self) -> Option<Frame> {
        self.inbox.try_recv().ok()
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Option<Frame> {
        self.inbox.recv_timeout(timeout).ok()
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Stop sentinels ride behind everything already queued, so the
        // writers flush (or loudly drop) pending frames before exiting.
        for w in self.writers.iter().flatten() {
            let _ = w.send(WCmd::Stop);
        }
        for h in self.writer_handles.drain(..) {
            let _ = h.join();
        }
        // A throwaway connection unblocks the accept loop so it can see
        // the stop flag; its reader exits on the immediate EOF.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// Drain helper shared by bootstrap code: pop frames already buffered
/// locally before blocking on the socket. (The handshake reads frames
/// eagerly, so a `Welcome` and `Peers` can land in one TCP segment.)
pub struct FrameBuf {
    queue: VecDeque<Frame>,
}

impl FrameBuf {
    /// Empty buffer.
    pub fn new() -> FrameBuf {
        FrameBuf { queue: VecDeque::new() }
    }

    /// Queue a decoded frame.
    pub fn push(&mut self, f: Frame) {
        self.queue.push_back(f);
    }

    /// Pop the oldest buffered frame.
    pub fn pop(&mut self) -> Option<Frame> {
        self.queue.pop_front()
    }
}

impl Default for FrameBuf {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn rumor(origin: u32, seq: u32, ttl: u32, delta: &[f32]) -> Rumor {
        Rumor { origin, seq, ttl, delta: delta.to_vec().into() }
    }

    // -- known-answer vectors (mirrored in tools/verify_wire_port.py) --

    #[test]
    fn known_answer_done() {
        let f = Frame::Peer(PeerMsg::Done { from: 3, rumors: 7 });
        // len=9 | tag=3 | from=3 | rumors=7, all LE
        assert_eq!(hex(&encode(&f)), "09000000030300000007000000");
    }

    #[test]
    fn known_answer_gossip() {
        let f = Frame::Peer(PeerMsg::Gossip { rumors: vec![rumor(1, 2, 3, &[1.0, -2.5])] });
        let bytes = encode(&f);
        // split for readability: len | tag | count | origin seq ttl dim | f32s
        assert_eq!(
            hex(&bytes[..25]),
            "1d000000020100000001000000020000000300000002000000",
        );
        assert_eq!(hex(&bytes[25..]), "0000803f000020c0");
        assert_eq!(bytes.len(), 33);
    }

    #[test]
    fn known_answer_step() {
        let f = Frame::Step { from: 1, step: 5, beat: 9 };
        assert_eq!(
            hex(&encode(&f)),
            "15000000060100000005000000000000000900000000000000",
        );
    }

    // -- seeded frame generator (mirrored in tools/verify_wire_port.py) --

    const METHODS: [&str; 5] = ["asp", "bsp", "ssp:4", "pssp:3:2", "pquorum:6:4:80"];

    fn gen_f32(rng: &mut Rng) -> f32 {
        rng.next_f32() * 2.0 - 1.0
    }

    fn gen_delta(rng: &mut Rng) -> Vec<f32> {
        let dim = rng.next_below(5) as usize;
        (0..dim).map(|_| gen_f32(rng)).collect()
    }

    fn gen_rumor(rng: &mut Rng) -> Rumor {
        let origin = rng.next_below(64) as u32;
        let seq = rng.next_below(100) as u32;
        let ttl = rng.next_below(8) as u32;
        let delta: Arc<[f32]> = gen_delta(rng).into();
        Rumor { origin, seq, ttl, delta }
    }

    fn gen_rumors(rng: &mut Rng) -> Vec<Rumor> {
        let n = rng.next_below(4) as usize;
        (0..n).map(|_| gen_rumor(rng)).collect()
    }

    fn gen_addr(rng: &mut Rng) -> String {
        format!("127.0.0.1:{}", rng.next_below(65536))
    }

    fn gen_frame(rng: &mut Rng) -> Frame {
        match rng.next_below(9) {
            0 => Frame::Peer(PeerMsg::Delta { delta: gen_delta(rng) }),
            1 => Frame::Peer(PeerMsg::Gossip { rumors: gen_rumors(rng) }),
            2 => Frame::Peer(PeerMsg::Done {
                from: rng.next_below(64) as u32,
                rumors: rng.next_below(1000) as u32,
            }),
            3 => Frame::Peer(PeerMsg::Leave {
                from: rng.next_below(64) as u32,
                rumors: rng.next_below(1000) as u32,
            }),
            4 => Frame::Peer(PeerMsg::Repair {
                origin: rng.next_below(64) as u32,
                rumors: rng.next_below(1000) as u32,
                store: gen_rumors(rng),
            }),
            5 => Frame::Step {
                from: rng.next_below(64) as u32,
                step: rng.next_below(1 << 20),
                beat: rng.next_below(1 << 20),
            },
            6 => Frame::Join { addr: gen_addr(rng) },
            7 => Frame::Welcome(Welcome {
                id: rng.next_below(64) as u32,
                n: rng.next_below(64) as u32 + 1,
                seed: rng.next_u64(),
                steps: rng.next_below(1000),
                dim: rng.next_below(128) as u32 + 1,
                lr: gen_f32(rng),
                method: METHODS[rng.next_below(METHODS.len() as u64) as usize].to_string(),
                fanout: rng.next_below(8) as u32,
                flush: rng.next_below(8) + 1,
                ttl: rng.next_below(16) as u32,
            }),
            _ => {
                let n = rng.next_below(4) as usize;
                let peers = (0..n)
                    .map(|_| (rng.next_below(64) as u32, gen_addr(rng)))
                    .collect();
                Frame::Peers { peers }
            }
        }
    }

    #[test]
    fn codec_round_trips_and_wire_len_is_exact() {
        let mut rng = Rng::new(0x5EED_0000);
        for _ in 0..500 {
            let f = gen_frame(&mut rng);
            let bytes = encode(&f);
            assert_eq!(bytes.len(), wire_len(&f), "wire_len mismatch for {f:?}");
            let back = decode(&bytes).expect("round trip decodes");
            // Frame equality via canonical re-encoding: the codec has a
            // single encoding per value, so byte equality is value
            // equality without a PartialEq on PeerMsg.
            assert_eq!(encode(&back), bytes, "re-encode mismatch for {f:?}");
        }
    }

    #[test]
    fn decode_rejects_malformed_frames() {
        let good = encode(&Frame::Peer(PeerMsg::Done { from: 3, rumors: 7 }));
        // Truncated at every prefix length.
        for cut in 0..good.len() {
            assert!(
                matches!(decode(&good[..cut]), Err(WireError::Truncated)),
                "prefix of {cut} bytes must be truncated"
            );
        }
        // Trailing garbage after a complete frame.
        let mut extra = good.clone();
        extra.push(0xAA);
        assert!(matches!(decode(&extra), Err(WireError::TrailingBytes(1))));
        // Trailing bytes *inside* the declared body length: the body
        // decoder must notice the surplus too.
        let mut padded_body = vec![TAG_DONE];
        put_u32(&mut padded_body, 3);
        put_u32(&mut padded_body, 7);
        padded_body.push(0);
        assert!(matches!(
            decode_body(&padded_body),
            Err(WireError::TrailingBytes(1))
        ));
    }

    #[test]
    fn decode_rejects_unknown_tag_and_oversize() {
        // Unknown tag 0xFF with a well-formed length prefix.
        let bytes = [1u8, 0, 0, 0, 0xFF];
        assert!(matches!(decode(&bytes), Err(WireError::UnknownTag(0xFF))));
        // Length prefix beyond MAX_FRAME.
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes();
        let mut bytes = huge.to_vec();
        bytes.push(TAG_DONE);
        assert!(matches!(decode(&bytes), Err(WireError::Oversize(_))));
    }

    #[test]
    fn rumor_count_cannot_fake_a_huge_allocation() {
        // Gossip claiming u32::MAX rumors in a 12-byte body must fail
        // cleanly (Truncated), not attempt a giant Vec reservation.
        let mut bytes = Vec::new();
        let body = {
            let mut b = vec![TAG_GOSSIP];
            put_u32(&mut b, u32::MAX);
            b
        };
        put_u32(&mut bytes, body.len() as u32);
        bytes.extend_from_slice(&body);
        assert!(matches!(decode(&bytes), Err(WireError::Truncated)));
    }

    #[test]
    fn cross_language_digest_is_pinned() {
        // FNV-1a over the concatenated encodings of 40 seeded frames,
        // one per property case. tools/verify_wire_port.py regenerates
        // the same frames from a from-scratch Python port of the RNG
        // and codec and asserts this exact digest — bit-identical wire
        // bytes across both implementations.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for case in 0..40u64 {
            let seed = (0x5EED_0000u64.wrapping_add(case)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut rng = Rng::new(seed);
            for byte in encode(&gen_frame(&mut rng)) {
                h = (h ^ byte as u64).wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        assert_eq!(h, CROSS_DIGEST, "wire format drifted from the pinned digest");
    }

    /// Pinned by tools/verify_wire_port.py — regenerate there if the
    /// format changes on purpose.
    const CROSS_DIGEST: u64 = 0x1499_61E4_06FF_0717;

    // -- transports --

    #[test]
    fn channel_transport_delivers_and_self_sends() {
        let mut cluster = ChannelTransport::cluster(3);
        assert!(cluster[0].send(1, Frame::Step { from: 0, step: 4, beat: 1 }));
        assert!(cluster[2].send(2, Frame::Step { from: 2, step: 9, beat: 2 }));
        match cluster[1].recv_timeout(Duration::from_secs(1)) {
            Some(Frame::Step { from: 0, step: 4, beat: 1 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
        match cluster[2].try_recv() {
            Some(Frame::Step { from: 2, step: 9, beat: 2 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
        assert!(cluster[0].try_recv().is_none());
    }

    #[test]
    fn tcp_transport_round_trips_frames_between_two_nodes() {
        let mut a = TcpTransport::bind(0, 2, "127.0.0.1:0").unwrap();
        let mut b = TcpTransport::bind(1, 2, "127.0.0.1:0").unwrap();
        let roster_a = vec![(1usize, b.local_addr().to_string())];
        let roster_b = vec![(0usize, a.local_addr().to_string())];
        a.connect_peers(&roster_a);
        b.connect_peers(&roster_b);

        assert!(a.send(1, Frame::Peer(PeerMsg::Gossip {
            rumors: vec![rumor(0, 0, 3, &[0.5, -0.5])],
        })));
        assert!(b.send(0, Frame::Step { from: 1, step: 7, beat: 1 }));
        // Self-send loops back without touching the network.
        assert!(a.send(0, Frame::Step { from: 0, step: 1, beat: 1 }));

        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Frame::Peer(PeerMsg::Gossip { rumors })) => {
                assert_eq!(rumors.len(), 1);
                assert_eq!(rumors[0].origin, 0);
                assert_eq!(&rumors[0].delta[..], &[0.5, -0.5]);
            }
            other => panic!("unexpected: {other:?}"),
        }
        let mut got = Vec::new();
        for _ in 0..2 {
            match a.recv_timeout(Duration::from_secs(5)) {
                Some(Frame::Step { from, step, .. }) => got.push((from, step)),
                other => panic!("unexpected: {other:?}"),
            }
        }
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (1, 7)]);
        assert!(a.bytes_out() > 0 && b.bytes_in() > 0);
    }

    #[test]
    fn tcp_writer_survives_a_peer_that_binds_late() {
        // Writer starts before the peer listens: the frame must arrive
        // after reconnect/backoff, not be lost.
        let reserved = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = reserved.local_addr().unwrap();
        drop(reserved); // free the port; reuse it for the late binder
        let mut a = TcpTransport::bind(0, 2, "127.0.0.1:0").unwrap();
        a.set_backoff(Duration::from_millis(5), Duration::from_millis(40));
        a.connect_peers(&[(1usize, addr.to_string())]);
        assert!(a.send(1, Frame::Step { from: 0, step: 3, beat: 1 }));
        std::thread::sleep(Duration::from_millis(30));
        let mut b = TcpTransport::with_listener(1, 2, TcpListener::bind(addr).unwrap()).unwrap();
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Frame::Step { from: 0, step: 3, beat: 1 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }
}
